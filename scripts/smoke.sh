#!/usr/bin/env bash
# CI smoke: tier-1 test suite + ExperimentSpec JSON dry-runs end-to-end
# + the crash-inject/resume contract + the simulation-engine runtime
# benchmark.
#
#   bash scripts/smoke.sh            # from the repo root
#
# Step 2 loads the committed spec artifacts (one sync, one async, one
# carbon-aware on the diurnal grid, one streaming-telemetry population
# point at concurrency 10^5, one faulty async point with diurnal
# hazards + correlated bursts + retry/backoff recovery, and one
# availability-churn async point with diurnal eligibility curves +
# checkpoint/resume salvage), runs each, then
# re-serializes, reloads and re-runs, asserting both runs produce the
# identical Result.summary() — the repro.api reproducibility contract,
# exercised on ALL THREE event loops (and on the intensity_schedule,
# FaultModel, AvailabilityModel and telemetry round-trips).
#
# Step 3 proves the PR 9 resume contract on the availability-churn spec:
# run it uninterrupted, run it again with checkpointing while the crash
# injector kills the run mid-way, resume from the checkpoint, and assert
# the resumed summary is bit-identical to the uninterrupted one.
#
# Step 4 runs the quick fig5-style engine benchmark (columnar vs scalar),
# refreshes BENCH_runtime.json + BENCH_history.json, and FAILS if the
# columnar engine's quick sessions/sec regressed more than 2x against the
# recorded baseline — overall or in any mode (sync, async and
# carbon-aware are each gated separately, as are the fault_stress,
# churn_stress and carbon_aware_stress points — the last one keeps the
# precompiled schedule-segment screening honest with both diurnal grids
# live). The bench also runs the population_stress streaming-telemetry
# point (gated on peak RSS, streaming parity and throughput) and the
# checkpoint_overhead point (checkpointing every 50 windows must cost
# < 1.1x the plain wall).
#
# Step 5 runs the quick design-space sweep benchmark (lane-batched packs
# vs sweep(workers=1) serial; summaries must match seed-for-seed) and
# FAILS on a >2x lane-throughput regression against the recorded
# baseline under BENCH_runtime.json's "sweep" key.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== smoke 1/5: tier-1 test suite =="
python -m pytest -x -q

echo "== smoke 2/5: ExperimentSpec JSON dry-runs (with round-trip check) =="
python -m repro.api examples/specs/charlm_sync_small.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_async_small.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_carbonaware_small.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_streaming_pop.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_faulty_bursts.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_avail_churn.json \
    --roundtrip-check --quiet

echo "== smoke 3/5: crash-inject -> resume -> bit-identical summary =="
python - <<'PY'
import os
import tempfile

from repro.api import Experiment, ExperimentSpec
from repro.core.snapshot import InjectedCrash

spec = ExperimentSpec.load("examples/specs/charlm_avail_churn.json")
base = Experiment(spec).run().summary()
with tempfile.TemporaryDirectory() as td:
    path = os.path.join(td, "smoke_ckpt.npz")
    os.environ["REPRO_CRASH_ROUND"] = "60"
    os.environ["REPRO_CRASH_KIND"] = "raise"
    try:
        Experiment(spec).run(checkpoint_path=path,
                             checkpoint_every_rounds=25)
        raise SystemExit("crash injector did not fire")
    except InjectedCrash:
        pass
    finally:
        del os.environ["REPRO_CRASH_ROUND"], os.environ["REPRO_CRASH_KIND"]
    res = Experiment.resume(path, checkpoint_path=path)
resumed = res.summary()
assert resumed == base, (resumed, base)
print(f"resume contract OK: killed at round 60, resumed run matches "
      f"uninterrupted run exactly ({res.rounds} rounds, "
      f"{res.log.n_sessions} sessions)")
PY

echo "== smoke 4/5: runtime benchmark (quick, per-mode 2x regression gate) =="
python benchmarks/bench_runtime.py --quick --check

echo "== smoke 5/5: sweep benchmark (quick, lane 2x regression gate) =="
python benchmarks/bench_sweep.py --quick --check

echo "smoke OK"
