#!/usr/bin/env bash
# CI smoke: tier-1 test suite + an ExperimentSpec JSON dry-run end-to-end.
#
#   bash scripts/smoke.sh            # from the repo root
#
# Step 2 loads the committed spec artifact, runs it, then re-serializes,
# reloads and re-runs it, asserting both runs produce the identical
# Result.summary() — the repro.api reproducibility contract.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== smoke 1/2: tier-1 test suite =="
python -m pytest -x -q

echo "== smoke 2/2: ExperimentSpec JSON dry-run (with round-trip check) =="
python -m repro.api examples/specs/charlm_sync_small.json \
    --roundtrip-check --quiet

echo "smoke OK"
