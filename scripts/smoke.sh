#!/usr/bin/env bash
# CI smoke: tier-1 test suite + ExperimentSpec JSON dry-runs end-to-end
# + the simulation-engine runtime benchmark.
#
#   bash scripts/smoke.sh            # from the repo root
#
# Step 2 loads the committed spec artifacts (one sync, one async, one
# carbon-aware on the diurnal grid, one streaming-telemetry population
# point at concurrency 10^5, one faulty async point with diurnal
# hazards + correlated bursts + retry/backoff recovery, and one
# availability-churn async point with diurnal eligibility curves +
# checkpoint/resume salvage), runs each, then
# re-serializes, reloads and re-runs, asserting both runs produce the
# identical Result.summary() — the repro.api reproducibility contract,
# exercised on ALL THREE event loops (and on the intensity_schedule,
# FaultModel, AvailabilityModel and telemetry round-trips).
#
# Step 3 runs the quick fig5-style engine benchmark (columnar vs scalar),
# refreshes BENCH_runtime.json + BENCH_history.json, and FAILS if the
# columnar engine's quick sessions/sec regressed more than 2x against the
# recorded baseline — overall or in any mode (sync, async and
# carbon-aware are each gated separately). The bench also runs the
# population_stress streaming-telemetry point and FAILS if its peak RSS
# reaches 2 GB, if streaming falls more than 1.5x behind the
# materialized twin, or on a >2x throughput cliff.
#
# Step 4 runs the quick design-space sweep benchmark (lane-batched packs
# vs sweep(workers=1) serial; summaries must match seed-for-seed) and
# FAILS on a >2x lane-throughput regression against the recorded
# baseline under BENCH_runtime.json's "sweep" key.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "== smoke 1/4: tier-1 test suite =="
python -m pytest -x -q

echo "== smoke 2/4: ExperimentSpec JSON dry-runs (with round-trip check) =="
python -m repro.api examples/specs/charlm_sync_small.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_async_small.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_carbonaware_small.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_streaming_pop.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_faulty_bursts.json \
    --roundtrip-check --quiet
python -m repro.api examples/specs/charlm_avail_churn.json \
    --roundtrip-check --quiet

echo "== smoke 3/4: runtime benchmark (quick, per-mode 2x regression gate) =="
python benchmarks/bench_runtime.py --quick --check

echo "== smoke 4/4: sweep benchmark (quick, lane 2x regression gate) =="
python benchmarks/bench_sweep.py --quick --check

echo "smoke OK"
