"""Paper Figure 10: asynchronous design space — carbon vs time-to-target
scatter grouped by concurrency; same-concurrency points follow a linear
trajectory whose slope grows with concurrency."""
from __future__ import annotations

import numpy as np

from benchmarks.common import grid, run_points, write_csv
from repro.core.predictor import fit_linear


def run(fast: bool = False):
    concs = (100, 400) if fast else (100, 200, 400, 800)
    lrs = (0.03, 0.1) if fast else (0.01, 0.03, 0.1, 0.3)
    rows = run_points([dict(mode="async", **g) for g in
                       grid(concurrency=concs, client_lr=lrs,
                            local_epochs=(1, 5))])
    slopes = {}
    for c in concs:
        pts = [r for r in rows if r["concurrency"] == c
               and r["duration_h"] > 0.1]
        if len(pts) >= 3:
            f = fit_linear([p["duration_h"] for p in pts],
                           [p["carbon_total_kg"] for p in pts])
            slopes[c] = f.slope
    ordered = [slopes[c] for c in sorted(slopes)]
    derived = {
        "slope_increases_with_concurrency": float(
            all(np.diff(ordered) > 0)) if len(ordered) > 1 else 0.0,
        **{f"slope_conc_{c}": s for c, s in slopes.items()},
    }
    return rows, derived


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/fig10_async_design_space.csv"))
    print(d)
