"""Paper Figure 5: carbon of SyncFL vs AsyncFL to a target perplexity at
concurrency = aggregation goal = 1000 (both tuned). Expected: async reaches
the target faster (wall-clock) but emits MORE carbon; component shares
~46-50% client compute / 27-29% upload / 22-24% download / small server."""
from __future__ import annotations

from benchmarks.common import run_points, write_csv


def run(fast: bool = False):
    conc = 400 if fast else 1000
    rows = run_points([
        dict(mode="sync", concurrency=conc, aggregation_goal=conc),
        dict(mode="async", concurrency=conc, aggregation_goal=conc)])
    sync, asyn = rows
    derived = {
        "async_faster": float(asyn["duration_h"] < sync["duration_h"]),
        "async_more_carbon": float(
            asyn["carbon_total_kg"] > sync["carbon_total_kg"]),
        "carbon_ratio_async_over_sync":
            asyn["carbon_total_kg"] / max(sync["carbon_total_kg"], 1e-9),
        "sync_client_compute_share": sync["shares_client_compute"],
        "sync_upload_share": sync["shares_upload"],
        "sync_download_share": sync["shares_download"],
        "sync_server_share": sync["shares_server"],
    }
    return rows, derived


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/fig5_sync_vs_async.csv"))
    print(d)
