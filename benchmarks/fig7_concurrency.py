"""Paper Figure 7 + §5.3: concurrency drives carbon; time-to-target shows
diminishing returns (paper: no speedup past ~800)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import run_points, write_csv


def run(fast: bool = False):
    concs = (50, 200, 800) if fast else (50, 100, 200, 300, 800, 1000, 1300)
    rows = run_points([dict(mode="sync", concurrency=c) for c in concs])
    carbons = [r["carbon_total_kg"] for r in rows]
    times = [r["duration_h"] for r in rows]
    # 10x concurrency -> how much resource vs speedup (paper: ~10x vs 1.5-2x)
    lo = rows[0]
    hi = next(r for r in rows if r["concurrency"] >= 10 * lo["concurrency"])
    derived = {
        "carbon_monotone_in_concurrency": float(
            all(np.diff(carbons) > -1e-9)),
        "speedup_10x_concurrency": lo["duration_h"] / hi["duration_h"],
        "carbon_ratio_10x_concurrency":
            hi["carbon_total_kg"] / lo["carbon_total_kg"],
        "diminishing_returns": float(
            (lo["duration_h"] / hi["duration_h"]) < 5.0),
    }
    return rows, derived


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/fig7_concurrency.csv"))
    print(d)
