"""Paper §1/§5.1 headline table: component shares of total carbon.

Target (paper, measured at scale): client+comm = ~97%, client compute
~46-50%, upload ~27-29%, download ~22-24%, server ~1-2%.

``run_fleet_presets`` adds the device-heterogeneity companion point: the
same fig5-style breakdown under the ``Environment.preset`` fleets
("flagship-only" vs "entry-heavy" vs the default mix) — how the
compute/communication balance moves when the fleet's silicon changes."""
from __future__ import annotations

from typing import Dict, List, Tuple

from benchmarks.common import Environment, run_points, write_csv

PAPER = {"client_compute": (0.46, 0.50), "upload": (0.27, 0.29),
         "download": (0.22, 0.24), "server": (0.01, 0.02)}
SLACK = 0.07   # simulated fleet tolerance

FLEET_PRESETS = ("default", "flagship-only", "entry-heavy")


def run(fast: bool = False):
    conc = 400 if fast else 1000
    rows = run_points([dict(mode=mode, concurrency=conc,
                            aggregation_goal=conc)
                       for mode in ("sync", "async")])
    derived = {}
    for r, mode in zip(rows, ("sync", "async")):
        for comp, (lo, hi) in PAPER.items():
            share = r[f"shares_{comp}"]
            derived[f"{mode}_{comp}"] = round(share, 4)
            derived[f"{mode}_{comp}_in_band"] = float(
                lo - SLACK <= share <= hi + SLACK)
        derived[f"{mode}_client_plus_comm"] = round(
            1.0 - r["shares_server"], 4)
    return rows, derived


def run_fleet_presets(fast: bool = False) -> Tuple[List[Dict], Dict]:
    """One sync fig5 point per fleet preset; rows carry a ``fleet``
    label, ``derived`` the headline compute-share comparison."""
    conc = 400 if fast else 1000
    rows, derived = [], {}
    for name in FLEET_PRESETS:
        env = Environment() if name == "default" \
            else Environment.preset(name)
        (row,) = run_points([dict(mode="sync", concurrency=conc,
                                  aggregation_goal=conc)],
                            environment=env)
        row["fleet"] = name
        rows.append(row)
        derived[f"{name}_client_compute"] = round(
            row["shares_client_compute"], 4)
        derived[f"{name}_carbon_total_kg"] = row["carbon_total_kg"]
    return rows, derived


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/table_component_breakdown.csv"))
    print(d)
    frows, fd = run_fleet_presets()
    print(write_csv(frows, "results/table_fleet_presets.csv"))
    print(fd)
