"""Paper §1/§5.1 headline table: component shares of total carbon.

Target (paper, measured at scale): client+comm = ~97%, client compute
~46-50%, upload ~27-29%, download ~22-24%, server ~1-2%."""
from __future__ import annotations

from benchmarks.common import run_points, write_csv

PAPER = {"client_compute": (0.46, 0.50), "upload": (0.27, 0.29),
         "download": (0.22, 0.24), "server": (0.01, 0.02)}
SLACK = 0.07   # simulated fleet tolerance


def run(fast: bool = False):
    conc = 400 if fast else 1000
    rows = run_points([dict(mode=mode, concurrency=conc,
                            aggregation_goal=conc)
                       for mode in ("sync", "async")])
    derived = {}
    for r, mode in zip(rows, ("sync", "async")):
        for comp, (lo, hi) in PAPER.items():
            share = r[f"shares_{comp}"]
            derived[f"{mode}_{comp}"] = round(share, 4)
            derived[f"{mode}_{comp}_in_band"] = float(
                lo - SLACK <= share <= hi + SLACK)
        derived[f"{mode}_client_plus_comm"] = round(
            1.0 - r["shares_server"], 4)
    return rows, derived


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/table_component_breakdown.csv"))
    print(d)
