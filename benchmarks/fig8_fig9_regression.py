"""Paper Figures 8-9 + §5.3: the carbon PREDICTOR.

Sync: carbon ≈ a * (rounds x concurrency); async: carbon ≈ a * (hours x
concurrency). Fit per-component linear models and report R² (the paper
reports high goodness-of-fit for download / upload / client compute)."""
from __future__ import annotations

from benchmarks.common import grid, run_points, write_csv
from repro.core.predictor import fit_linear


def run(fast: bool = False):
    concs = (50, 200, 400) if fast else (50, 100, 200, 400, 800)
    lrs = (0.05, 0.1) if fast else (0.03, 0.05, 0.1, 0.2)
    rows = run_points([dict(mode=mode, **g) for mode in ("sync", "async")
                       for g in grid(concurrency=concs, client_lr=lrs)])
    derived = {}
    for mode, mcode in (("sync", 0.0), ("async", 1.0)):
        pts = [r for r in rows if r["mode"] == mcode and r["rounds"] > 1]
        x = [p["concurrency"] * (p["rounds"] if mode == "sync"
                                 else p["duration_h"]) for p in pts]
        for comp in ("client_compute_kg", "upload_kg", "download_kg",
                     "total_kg"):
            f = fit_linear(x, [p[f"carbon_{comp}" if comp == "total_kg"
                               else comp] for p in pts])
            derived[f"{mode}_r2_{comp}"] = f.r2
            if comp == "total_kg":
                derived[f"{mode}_slope_kg"] = f.slope
    return rows, derived


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/fig8_fig9_regression.csv"))
    print(d)
