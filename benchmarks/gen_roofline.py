"""Generate results/roofline_table.md from the three dry-run JSONs.

Run from anywhere; paths resolve against the repo root:

    PYTHONPATH=src python benchmarks/gen_roofline.py
"""
import json
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from benchmarks.roofline_report import markdown_table  # noqa: E402

out = []
for title, f in [("Single pod 16x16 (baseline)", "results/dryrun_single_pod.json"),
                 ("Two pods 2x16x16 (baseline)", "results/dryrun_multi_pod.json"),
                 ("Single pod 16x16 (OPTIMIZED serving: --variant flash_decode)",
                  "results/dryrun_single_pod_optimized.json")]:
    try:
        rows = json.load(open(os.path.join(_ROOT, f)))
    except FileNotFoundError:
        continue
    clean = []
    for r in rows:
        clean.append({k: v for k, v in r.items() if not isinstance(v, dict)})
    out.append(f"### {title}\n\n" + markdown_table(clean) + "\n")
open(os.path.join(_ROOT, "results/roofline_table.md"), "w").write("\n".join(out))
print("wrote results/roofline_table.md")
