"""Paper §6 compression estimate: if comm is a fraction c of total, int8
(4x) compression reduces total by 1/((1-c) + c/4) — the paper's example at
c=0.6 gives 1.82x. We MEASURE the factor end-to-end through the runtime
(bytes on the wire + on-device (de)quant overhead + unchanged convergence)."""
from __future__ import annotations

from benchmarks.common import run_points, write_csv


def run(fast: bool = False):
    conc = 200 if fast else 500
    base, comp = run_points([
        dict(mode="sync", concurrency=conc),
        dict(mode="sync", concurrency=conc, compression="int8")])
    c = base["shares_upload"] + base["shares_download"]
    analytic = 1.0 / ((1.0 - c) + c / 4.0)
    measured = base["carbon_total_kg"] / comp["carbon_total_kg"]
    rows = [dict(base, variant="none"), dict(comp, variant="int8")]
    derived = {
        "comm_share": c,
        "analytic_reduction": analytic,
        "measured_reduction": measured,
        "within_20pct_of_analytic": float(
            0.8 < measured / analytic < 1.25),
        "paper_example_at_c06": 1.0 / (0.4 + 0.6 / 4.0),
    }
    return rows, derived


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/table_compression.csv"))
    print(d)
