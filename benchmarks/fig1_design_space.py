"""Paper Figure 1 + §5.2: synchronous design space.

Sweep (concurrency x client_lr x local_epochs); each point is a training run
with carbon (Y) vs rounds-to-target (X), grouped by concurrency. Expected
paper relationships: both rounds and concurrency positively correlate with
carbon; fixing concurrency the relationship is near-linear.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import grid, run_points, write_csv
from repro.core.predictor import fit_linear


def run(fast: bool = False):
    concs = (50, 200) if fast else (50, 100, 200, 400)
    lrs = (0.03, 0.1) if fast else (0.01, 0.03, 0.1, 0.3)
    rows = run_points([dict(mode="sync", **g) for g in
                       grid(concurrency=concs, client_lr=lrs,
                            local_epochs=(1, 3))])
    # per-concurrency linearity of carbon vs rounds
    fits = {}
    for c in concs:
        pts = [r for r in rows if r["concurrency"] == c and r["rounds"] > 1]
        if len(pts) >= 3:
            f = fit_linear([p["rounds"] for p in pts],
                           [p["carbon_total_kg"] for p in pts])
            fits[c] = f.r2
    derived = float(np.mean(list(fits.values()))) if fits else 0.0
    return rows, {"per_concurrency_linearity_r2_mean": derived, **{
        f"r2_conc_{c}": v for c, v in fits.items()}}


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/fig1_design_space.csv"))
    print(d)
