"""Shared benchmark helpers: grid runner + CSV emission."""
from __future__ import annotations

import csv
import io
import itertools
import sys
import time
from typing import Dict, Iterable, List, Sequence

from repro.api import Environment, Experiment, ExperimentSpec, ModelRef
from repro.configs import FederatedConfig, RunConfig, get_config

CFG = get_config("paper-charlm")
MODEL = ModelRef("paper-charlm")


def run_point(run: RunConfig | None = None,
              environment: Environment | None = None,
              **fed_kw) -> Dict[str, float]:
    fed_kw.setdefault("aggregation_goal",
                      max(1, int(fed_kw.get("concurrency", 100) * 0.8)))
    fed = FederatedConfig(**fed_kw)
    run = run or RunConfig(target_perplexity=175.0)
    spec = ExperimentSpec(model=MODEL, federated=fed, run=run,
                          environment=environment or Environment(),
                          learner="surrogate")
    res = Experiment(spec).run()
    out = res.summary()
    out.update(concurrency=fed.concurrency, mode=0.0 if fed.mode == "sync" else 1.0,
               client_lr=fed.client_lr, server_lr=fed.server_lr,
               local_epochs=fed.local_epochs, batch=fed.client_batch_size)
    out["shares_client_compute"], out["shares_upload"], \
        out["shares_download"], out["shares_server"] = (
            res.carbon.shares()[k] for k in
            ("client_compute", "upload", "download", "server"))
    return out


def grid(**axes: Sequence) -> Iterable[Dict]:
    keys = list(axes)
    for vals in itertools.product(*axes.values()):
        yield dict(zip(keys, vals))


def write_csv(rows: List[Dict], path: str | None = None) -> str:
    if not rows:
        return ""
    keys = sorted({k for r in rows for k in r})
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=keys)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    text = buf.getvalue()
    if path:
        with open(path, "w") as f:
            f.write(text)
    return text


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
