"""Shared benchmark helpers: parallel grid runner + CSV emission.

Sweeps go through ``repro.api.sweep`` — build the specs with
``make_spec``, run them all with ``run_points(points, workers=N)``, and
get back the same flat summary rows ``run_point`` produces. By default
compatible points are lane-batched (``sweep(..., vectorize=True)``, PR
4) and the packs fan out across a process pool; results are
seed-for-seed identical to per-point serial runs either way. Set
``BENCH_VECTORIZE=0`` to force the pre-lane per-spec pool and
``BENCH_WORKERS=N`` to bound the pool."""
from __future__ import annotations

import csv
import io
import itertools
import os
import sys
import time
from typing import Dict, Iterable, List, Optional, Sequence

from repro.api import (Environment, Experiment, ExperimentSpec, ModelRef,
                       Result, sweep)
from repro.configs import FederatedConfig, RunConfig, get_config

CFG = get_config("paper-charlm")
MODEL = ModelRef("paper-charlm")

# benchmark-wide worker count: BENCH_WORKERS env var, default all cores
WORKERS = int(os.environ.get("BENCH_WORKERS", "0")) or None
# lane-batch compatible sweep points by default (BENCH_VECTORIZE=0 opts out)
VECTORIZE = os.environ.get("BENCH_VECTORIZE", "1").lower() \
    not in ("0", "false", "no")


def make_spec(run: RunConfig | None = None,
              environment: Environment | None = None,
              **fed_kw) -> ExperimentSpec:
    """One sweep point as a self-contained ExperimentSpec."""
    fed_kw.setdefault("aggregation_goal",
                      max(1, int(fed_kw.get("concurrency", 100) * 0.8)))
    return ExperimentSpec(
        model=MODEL, federated=FederatedConfig(**fed_kw),
        run=run or RunConfig(target_perplexity=175.0),
        environment=environment or Environment(), learner="surrogate")


def point_row(res: Result) -> Dict[str, float]:
    """Flatten a Result into the benchmark CSV row schema."""
    fed = res.spec.federated
    out = res.summary()
    out.update(concurrency=fed.concurrency,
               mode=0.0 if fed.mode == "sync" else 1.0,
               client_lr=fed.client_lr, server_lr=fed.server_lr,
               local_epochs=fed.local_epochs, batch=fed.client_batch_size)
    out["shares_client_compute"], out["shares_upload"], \
        out["shares_download"], out["shares_server"] = (
            res.carbon.shares()[k] for k in
            ("client_compute", "upload", "download", "server"))
    return out


def run_point(run: RunConfig | None = None,
              environment: Environment | None = None,
              **fed_kw) -> Dict[str, float]:
    return point_row(Experiment(make_spec(run, environment, **fed_kw)).run())


def run_points(points: Sequence[Dict], run: RunConfig | None = None,
               environment: Environment | None = None,
               workers: Optional[int] = WORKERS,
               vectorize: bool = VECTORIZE) -> List[Dict[str, float]]:
    """Run a list of sweep points (dicts of FederatedConfig overrides; a
    point may carry its own "run"=RunConfig) — lane-batched by default,
    with packs fanned out across a process pool."""
    specs = [make_spec(p.pop("run", None) or run, environment, **p)
             for p in (dict(p) for p in points)]
    return [point_row(r)
            for r in sweep(specs, workers=workers, vectorize=vectorize)]


def session_columns(log):
    """Session columns for plotting/inspection, streaming-aware: on a
    full-telemetry TaskLog this is every session; on a ``StreamedLog``
    (``run.telemetry="streaming"``) it is the seed-deterministic
    reservoir sample, and a one-line note says so — per-session scatter
    built from it is a uniform subsample, while the summary scalars
    (carbon, bytes, participation, staleness) remain exact either way."""
    if getattr(log, "sampled", False):
        print(f"note: streaming telemetry — plotting a reservoir sample "
              f"of {len(log.columns())}/{log.n_sessions} sessions "
              "(summary scalars are exact)", file=sys.stderr)
    return log.columns()


def grid(**axes: Sequence) -> Iterable[Dict]:
    keys = list(axes)
    for vals in itertools.product(*axes.values()):
        yield dict(zip(keys, vals))


def write_csv(rows: List[Dict], path: str | None = None) -> str:
    if not rows:
        return ""
    keys = sorted({k for r in rows for k in r})
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=keys)
    w.writeheader()
    for r in rows:
        w.writerow(r)
    text = buf.getvalue()
    if path:
        with open(path, "w") as f:
            f.write(text)
    return text


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.seconds = time.time() - self.t0
