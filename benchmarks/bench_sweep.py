"""Design-space sweep throughput: lane-batched engine vs serial sweep.

The paper's headline deliverables are design-space grids (Fig. 1 carbon
vs time, Fig. 7 concurrency, Fig. 10 async design space) — dozens of
*small* runs, the regime where per-call fixed costs dominate the
columnar engine and a process pool caps out near the core count. This
benchmark runs one quick fig1-style grid (concurrency x client_lr x
local_epochs, sync AND async so both lane engines are exercised) two
ways:

* **serial** — ``repro.api.sweep(specs, workers=1)``: one
  ``Experiment(spec).run()`` after another (the pre-lane baseline);
* **lane** — ``sweep(specs, vectorize=True, workers=1)``: the specs
  grouped into lane packs and advanced in lockstep as one columnar
  simulation per mode (PR 4).

The two sides must produce **identical** summaries (the lane engine is
seed-for-seed exact, enforced here and in tests/test_lanes.py), so
points/sec is an apples-to-apples measure of the same simulated sweep.
Results land under the ``"sweep"`` key of ``BENCH_runtime.json`` (see
``benchmarks/bench_runtime.py`` for both artifact schemas) and every
passing run appends a ``sweep-quick``/``sweep-full`` row to
``BENCH_history.json``. ``--check`` fails on a >2x lane-throughput
regression against the committed baseline (the same loose-cliff gate
the runtime bench uses).

    PYTHONPATH=src python benchmarks/bench_sweep.py [--quick] [--check]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

try:
    from benchmarks.bench_runtime import (BENCH_PATH, HISTORY_PATH,
                                          REGRESSION_FACTOR,
                                          append_history_row, host_meta)
except ImportError:      # run as `python benchmarks/bench_sweep.py`
    from bench_runtime import (BENCH_PATH, HISTORY_PATH, REGRESSION_FACTOR,
                               append_history_row, host_meta)
from repro.api import Environment, ExperimentSpec, ModelRef, sweep
from repro.configs import FederatedConfig, RunConfig, get_config


def grid_specs(quick: bool) -> List[ExperimentSpec]:
    """A fig1-style design grid over both event loops. Quick keeps the
    runs small (low concurrency, wide lr axis, capped rounds) so CI
    measures dispatch overhead — exactly the many-small-runs regime lane
    batching amortizes; full sweeps the paper-scale concurrencies to
    convergence. Half the points (local_epochs=3) run on the diurnal
    Environment, so every pack mixes static and time-varying intensity
    lanes and the sweep gate exercises the schedule lookup path in
    ``estimator.lane_carbon``."""
    concs = (25, 50) if quick else (50, 100, 200, 400)
    lrs = (0.003, 0.01, 0.03, 0.1, 0.3, 1.0) if quick \
        else (0.01, 0.03, 0.1, 0.3)
    run_kw: Dict = dict(target_perplexity=175.0)
    if quick:
        run_kw["max_rounds"] = 150
    envs = {1: Environment(), 3: Environment.preset("diurnal")}
    return [ExperimentSpec(
                model=ModelRef("paper-charlm"),
                federated=FederatedConfig(
                    mode=mode, concurrency=conc,
                    aggregation_goal=int(conc * 0.8),
                    client_lr=lr, local_epochs=ep),
                run=RunConfig(**run_kw), environment=envs[ep],
                learner="surrogate")
            for mode in ("sync", "async")
            for conc in concs
            for lr in lrs
            for ep in (1, 3)]


def run_bench(quick: bool) -> Dict:
    specs = grid_specs(quick)
    get_config("paper-charlm").param_count()   # warm the jax shape cache
    # warm both paths on a small prefix (allocator, import, lane buffers)
    # so the timed sections compare engines, not first-touch costs
    sweep(specs[:4], workers=1)
    sweep(specs[:4], workers=1, vectorize=True)
    # best-of-N walls: the lane side is sub-second, so a single stray
    # scheduler stall (shared CI hosts steal whole cores for stretches)
    # would dominate its measurement; both sides get the same treatment
    reps = 3 if quick else 1
    wall_serial = wall_lane = float("inf")
    for _ in range(reps):
        t0 = time.time()
        serial = sweep(specs, workers=1)
        wall_serial = min(wall_serial, time.time() - t0)
        t0 = time.time()
        lane = sweep(specs, workers=1, vectorize=True)
        wall_lane = min(wall_lane, time.time() - t0)
    # the lane engine must simulate the identical sweep, seed for seed
    for rs, rl in zip(serial, lane):
        assert rs.summary() == rl.summary(), (rs.spec.federated,
                                              rs.summary(), rl.summary())
    sessions = sum(r.log.n_sessions for r in serial)
    n = len(specs)
    return {
        "workload": {"style": "fig1+fig10 design grid", "quick": quick,
                     "points": n,
                     "modes": ["sync", "async"],
                     "environments": ["static", "diurnal"]},
        "points": n,
        "sessions": sessions,
        "serial": {"wall_s": round(wall_serial, 4),
                   "points_per_s": round(n / max(wall_serial, 1e-9), 3),
                   "sessions_per_s": round(sessions
                                           / max(wall_serial, 1e-9))},
        "lane": {"wall_s": round(wall_lane, 4),
                 "points_per_s": round(n / max(wall_lane, 1e-9), 3),
                 "sessions_per_s": round(sessions / max(wall_lane, 1e-9))},
        "speedup_vs_serial": round(wall_serial / max(wall_lane, 1e-9), 2),
    }


def check_regression(fresh: Dict, baseline: Dict) -> int:
    """Exit 1 if lane-batched sweep throughput regressed more than
    REGRESSION_FACTOR against the committed baseline for this grid."""
    old = baseline.get("lane", {}).get("points_per_s", 0)
    new = fresh["lane"]["points_per_s"]
    if old and new * REGRESSION_FACTOR < old:
        print(f"bench_sweep: REGRESSION — lane {new} points/s vs baseline "
              f"{old} (>{REGRESSION_FACTOR}x slower)")
        return 1
    print(f"bench_sweep: lane {new} points/s vs baseline {old} — ok "
          f"(speedup vs serial: {fresh['speedup_vs_serial']}x)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small grid for CI (conc<=100, capped rounds)")
    ap.add_argument("--check", action="store_true",
                    help="fail on >2x lane-throughput regression")
    ap.add_argument("--out", default=BENCH_PATH)
    ap.add_argument("--history", default=HISTORY_PATH)
    args = ap.parse_args()

    book: Dict = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            book = json.load(f)
    key = "quick" if args.quick else "full"
    fresh = run_bench(args.quick)
    baseline = book.get("sweep", {}).get(key, {})
    status = check_regression(fresh, baseline) if args.check else 0
    if status == 0:
        # a failed gate keeps the old baseline, so a rerun can't self-pass
        book.setdefault("sweep", {})[key] = fresh
        with open(args.out, "w") as f:
            json.dump(book, f, indent=1)
            f.write("\n")
        append_history_row({
            "ts": round(time.time(), 1),
            "workload": f"sweep-{key}",
            "host": host_meta(),
            "points": fresh["points"],
            "serial_points_per_s": fresh["serial"]["points_per_s"],
            "lane_points_per_s": fresh["lane"]["points_per_s"],
            "speedup_vs_serial": fresh["speedup_vs_serial"],
        }, args.history)
    print(json.dumps({k: fresh[k] for k in
                      ("points", "speedup_vs_serial")}, indent=1))
    wrote = f"wrote {os.path.relpath(args.out)}" if status == 0 \
        else "baseline kept (gate failed)"
    print(f"[sweep-{key}] lane: {fresh['lane']['points_per_s']} points/s "
          f"({fresh['speedup_vs_serial']}x vs serial) | {wrote}")
    return status


if __name__ == "__main__":
    raise SystemExit(main())
