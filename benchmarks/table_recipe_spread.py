"""Paper headline: iso-accuracy configurations can differ in carbon by up to
~200x. Sweep a wide config space, keep runs that reached the SAME target
perplexity, report max/min carbon spread + the Green-FL recipe winner."""
from __future__ import annotations

from benchmarks.common import grid, run_points, write_csv


def run(fast: bool = False):
    if fast:
        space = grid(concurrency=(50, 800), client_lr=(0.1, 0.01),
                     local_epochs=(1, 10))
    else:
        space = grid(concurrency=(50, 100, 300, 800, 1300, 1500),
                     client_lr=(0.003, 0.01, 0.1, 0.3),
                     local_epochs=(1, 3, 10, 20),
                     client_batch_size=(8, 16))
    rows = run_points([dict(mode="sync", **g) for g in space])
    reached = [r for r in rows if r["reached_target"] > 0]
    derived = {"n_reached": float(len(reached))}
    if len(reached) >= 2:
        kgs = sorted(r["carbon_total_kg"] for r in reached)
        best = min(reached, key=lambda r: r["carbon_total_kg"])
        derived.update(
            spread_max_over_min=kgs[-1] / max(kgs[0], 1e-9),
            greenest_kg=kgs[0], dirtiest_kg=kgs[-1],
            greenest_concurrency=best["concurrency"],
            greenest_epochs=best["local_epochs"],
            recipe_low_concurrency=float(best["concurrency"] <= 300),
            recipe_low_epochs=float(best["local_epochs"] <= 3),
        )
    return rows, derived


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/table_recipe_spread.csv"))
    print(d)
