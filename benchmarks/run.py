"""Benchmark entry point: one harness per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time of the
harness; derived = its headline reproduction metric). Full sweep artifacts
land in results/*.csv. ``--fast`` shrinks grids for CI.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

from benchmarks import (fig1_design_space, fig5_sync_vs_async, fig6_fixed_time,
                        fig7_concurrency, fig8_fig9_regression,
                        fig10_async_design_space, roofline_report,
                        table_component_breakdown, table_compression,
                        table_recipe_spread)
from benchmarks.common import write_csv

HARNESSES = [
    ("fig1_sync_design_space", fig1_design_space,
     "per_concurrency_linearity_r2_mean"),
    ("fig5_sync_vs_async", fig5_sync_vs_async,
     "carbon_ratio_async_over_sync"),
    ("fig6_fixed_time", fig6_fixed_time, "async_lower_ppl_at_4h"),
    ("fig7_concurrency", fig7_concurrency, "speedup_10x_concurrency"),
    ("fig8_fig9_predictor", fig8_fig9_regression, "sync_r2_total_kg"),
    ("fig10_async_design_space", fig10_async_design_space,
     "slope_increases_with_concurrency"),
    ("table_component_breakdown", table_component_breakdown,
     "sync_client_compute"),
    ("table_compression_int8", table_compression, "measured_reduction"),
    ("table_recipe_spread", table_recipe_spread, "spread_max_over_min"),
    ("roofline_dryrun", roofline_report, "n_pairs_ok"),
]


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--fast", action="store_true")
    p.add_argument("--only", default=None)
    args = p.parse_args()
    os.makedirs("results", exist_ok=True)

    print("name,us_per_call,derived")
    all_derived = {}
    for name, mod, key in HARNESSES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows, derived = mod.run(fast=args.fast)
            write_csv(rows, f"results/{name}.csv")
            us = (time.time() - t0) * 1e6
            val = derived.get(key, "")
            print(f"{name},{us:.0f},{val}")
            all_derived[name] = derived
        except Exception as e:  # keep the suite going
            print(f"{name},{(time.time()-t0)*1e6:.0f},ERROR:{e!r}")
    # full derived dump for EXPERIMENTS.md
    import json
    with open("results/benchmark_derived.json", "w") as f:
        json.dump(all_derived, f, indent=1, default=str)


if __name__ == "__main__":
    main()
