"""Roofline/bench harness for the assigned architectures: reads the dry-run
JSON artifacts (results/dryrun_*.json) and emits the per-(arch x shape x
mesh) roofline table used by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import write_csv

FILES = ("results/dryrun_single_pod.json", "results/dryrun_multi_pod.json")


def load_rows() -> List[Dict]:
    rows = []
    for f in FILES:
        if os.path.exists(f):
            for r in json.load(open(f)):
                if r.get("status") == "ok":
                    rows.append({k: v for k, v in r.items()
                                 if not isinstance(v, dict)})
                else:
                    rows.append({"arch": r["arch"], "shape": r["shape"],
                                 "mesh": r.get("mesh", ""),
                                 "status": r.get("status")})
    return rows


def run(fast: bool = False):
    rows = load_rows()
    ok = [r for r in rows if r.get("status") == "ok"]
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    derived = {
        "n_pairs_ok": float(len(ok)),
        "n_rows": float(len(rows)),
        **{f"dominant_{k}": float(v) for k, v in doms.items()},
    }
    return rows, derived


def markdown_table(rows: List[Dict]) -> str:
    cols = ("arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
            "dominant", "useful_ratio", "peak_mem_gb")
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in sorted(rows, key=lambda r: (r.get("mesh", ""), r.get("arch", ""),
                                         r.get("shape", ""))):
        if r.get("status") != "ok":
            out.append(f"| {r.get('arch')} | {r.get('shape')} | "
                       f"{r.get('mesh')} | - | - | - | "
                       f"{r.get('status')} | - | - |")
            continue
        vals = []
        for c in cols:
            v = r.get(c, "")
            if isinstance(v, float):
                v = f"{v:.4g}"
            vals.append(str(v))
        out.append("| " + " | ".join(vals) + " |")
    return "\n".join(out)


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/roofline.csv"))
    print(markdown_table(rows))
    print(d)
