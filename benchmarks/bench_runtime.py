"""Simulation-engine throughput benchmark: columnar vs scalar engine.

Runs a fixed fig5-style sweep (sync vs async FedBuff vs carbon-aware
FedBuff at matched concurrency = aggregation goal; the carbon-aware
point runs on the diurnal Environment so the time-resolved intensity
lookup and probe-screened selection are on the clock) through BOTH
engines:

* **columnar** — the production `repro.federated.runtime` strategies
  (vectorized `plan_batch`/`resolve_batch`, `SessionBatch` telemetry,
  vectorized estimator);
* **scalar** — the pre-columnar per-session reference loop preserved in
  `repro.federated.reference` (the seed engine's hot path).

Both engines produce seed-for-seed identical TaskLogs, so sessions/sec is
an apples-to-apples measure of the same simulated workload. Results land
in ``BENCH_runtime.json`` (committed at the repo root) so the speedup is
tracked across PRs, and every successful run appends a row to
``BENCH_history.json`` — the throughput trajectory across PRs/machines.
``--check`` compares the fresh numbers against the committed baseline and
fails on a >2x throughput regression, overall AND per mode (sync and
async are gated separately so one mode's win can't mask the other's
cliff). The gate is deliberately loose: baselines are wall-clock on
whatever machine last passed, so 2x absorbs hardware variance — and
because each passing run re-baselines, it catches cliffs, not slow drift
(BENCH_history.json is the record for drift).

    PYTHONPATH=src python benchmarks/bench_runtime.py [--quick] [--check]

Full (non-quick) runs also record an ``async_stress`` point — the async
engine alone at goal == concurrency == 1000 (the fig5 frontier point,
maximum chained-replacement pressure on the window-batched merge).
Both engines are fully vectorized: sync closes rounds with a partition
on end_t; async runs the window-batched merge over per-slot
replacement-id streams (PR 3) instead of a per-session event heap.

Artifact schemas
----------------

``BENCH_runtime.json`` (repo root) is a book with one section per
workload so CI quick runs never clobber the full baseline:

* ``"full"`` / ``"quick"`` — this benchmark: ``workload`` (the swept
  points), ``columnar`` and ``scalar`` engine sections (each with
  ``per_mode{sync,async} -> {sessions, wall_s, sessions_per_s, rounds,
  carbon_total_kg}`` plus the pooled ``sessions/wall_s/sessions_per_s``),
  ``speedup`` and ``speedup_per_mode``; full runs add ``async_stress``.
  ``fault_stress`` records the fault-injection point (PR 7): the async
  engine at fig5 scale with diurnal per-country failure hazards,
  correlated burst windows and retry/backoff re-dispatch all live —
  throughput of the fault weave + retry stream keying, gated at 2x like
  the per-mode points, with the outcome mix recorded for context.
  ``churn_stress`` records the availability point (PR 8): async at fig5
  scale with fine-grained per-country eligibility curves (288-segment
  admission + exit-time scans per resolve), mid-session churn
  interruptions, checkpoint/resume salvage on the retry stream, and the
  salvaged/lost waste split — gated at 2x with its own history column.
  ``carbon_aware_stress`` records the carbon-aware screening point
  (PR 10): carbon-aware at goal == concurrency == 1000 (200 quick) with
  BOTH diurnal grids live — time-resolved intensity schedules and
  per-country eligibility curves — so every replacement dispatch pays
  the full probe screen through the precompiled schedule-segment mask
  tables; gated at 2x with its own history column.
  ``checkpoint_overhead`` records the engine-snapshot point (PR 9): the
  async fig5-scale run with ``checkpoint_every_rounds=50``; the
  ``overhead_ratio`` (checkpointed wall over the same run's wall minus
  its measured save time, median of 5 runs) is gated under 1.1x and the
  checkpointed run's summary is asserted identical to the plain one
  (snapshots never perturb the simulation).
  ``population_stress`` records the streaming-telemetry scale point
  (async at concurrency 10^5 quick / 10^6 full, ≥10^7 sessions full):
  throughput, ``peak_rss_mb`` (process high-water mark, gated under
  2 GB) and ``slowdown_vs_materialized`` against a matched-concurrency
  materialized twin (gated at 1.5x; the matched pair's summaries are
  asserted bit-for-bit equal in-bench — at full scale the pair runs at
  10x fewer rounds so the materialized half fits in memory).
* ``"sweep"`` — ``benchmarks/bench_sweep.py``: per key ("quick"/"full")
  the design-space grid size (``points``), ``serial`` and ``lane``
  sections (``wall_s``, ``points_per_s``, ``sessions``) and
  ``speedup_vs_serial`` (lane-batched vs ``sweep(workers=1)``).

``BENCH_history.json`` (repo root) is the append-only trajectory: one
row per passing bench run, ``{ts, workload, host: {cpus, numpy},
...bench-specific throughput fields}`` — ``workload`` is
"quick"/"full" for this benchmark and "sweep-quick"/"sweep-full" for
the sweep benchmark. The per-run regression gates are deliberately
loose 2x cliffs (baselines are wall-clock on whatever box last passed);
the history rows, with their host metadata, are what make slow drift
visible and gates comparable across machines.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

from repro.api import Environment
from repro.configs import FederatedConfig, RunConfig, get_config
from repro.core.carbon import UTC_OFFSET_H
from repro.core.faults import FaultModel, wave_hazard_schedule
from repro.federated.reference import run_scalar
from repro.federated.runtime import get_strategy
from repro.federated.surrogate import SurrogateLearner

BENCH_PATH = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_runtime.json")
HISTORY_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "BENCH_history.json")
REGRESSION_FACTOR = 2.0
# population_stress gates: streaming peak RSS stays under this, and
# streaming throughput stays within this factor of the materialized twin
POPULATION_RSS_LIMIT_MB = 2048.0
POPULATION_SLOWDOWN_LIMIT = 1.5
# engine snapshots (PR 9): checkpointing every 50 windows must cost less
# than this factor of the no-checkpoint wall
CHECKPOINT_OVERHEAD_LIMIT = 1.1


def sweep_points(quick: bool) -> List[Dict]:
    conc = 200 if quick else 1000
    run_kw = dict(target_perplexity=175.0)
    if quick:
        run_kw["max_rounds"] = 80
    pts = [dict(mode=m, concurrency=conc, aggregation_goal=conc,
                run_kw=run_kw) for m in ("sync", "async")]
    # carbon-aware runs on the diurnal grid so the time-resolved lookup
    # and probe-screened selection are both inside the timed region
    pts.append(dict(mode="carbon-aware", concurrency=conc,
                    aggregation_goal=conc, run_kw=run_kw,
                    environment="diurnal"))
    return pts


def _run_engine(engine: str, points: List[Dict]) -> Dict:
    cfg = get_config("paper-charlm")
    cfg.param_count()   # warm the shared shape cache outside the timer
    out: Dict = {"per_mode": {}}
    total_sessions = 0
    total_wall = 0.0
    for p in points:
        fed = FederatedConfig(mode=p["mode"], concurrency=p["concurrency"],
                              aggregation_goal=p["aggregation_goal"])
        run = RunConfig(**p["run_kw"])
        env = Environment.preset(p["environment"]) \
            if p.get("environment") else Environment()
        learner = SurrogateLearner(cfg, fed, run)
        kw = dict(sampler=env.sampler(cfg, fed, 64),
                  estimator=env.estimator())
        t0 = time.time()
        if engine == "columnar":
            res = get_strategy(fed.mode).run(cfg, fed, run, learner, **kw)
        else:
            res = run_scalar(cfg, fed, run, learner, **kw)
        wall = time.time() - t0
        n = res.log.n_sessions
        out["per_mode"][p["mode"]] = {
            "sessions": n, "wall_s": round(wall, 4),
            "sessions_per_s": round(n / max(wall, 1e-9)),
            "rounds": res.rounds,
            "carbon_total_kg": res.carbon.total_kg,
        }
        total_sessions += n
        total_wall += wall
    out["sessions"] = total_sessions
    out["wall_s"] = round(total_wall, 4)
    out["sessions_per_s"] = round(total_sessions / max(total_wall, 1e-9))
    return out


def _run_async_stress() -> Dict:
    """Columnar-only async point at goal == concurrency == 1000: the fig5
    frontier workload with maximum chained-replacement pressure on the
    window-batched merge (the scalar engine would take ~10s here, and the
    per-mode gate already covers the comparison)."""
    cfg = get_config("paper-charlm")
    cfg.param_count()
    fed = FederatedConfig(mode="async", concurrency=1000,
                          aggregation_goal=1000)
    run = RunConfig(target_perplexity=175.0)
    learner = SurrogateLearner(cfg, fed, run)
    t0 = time.time()
    res = get_strategy("async").run(cfg, fed, run, learner)
    wall = time.time() - t0
    n = res.log.n_sessions
    return {"concurrency": 1000, "aggregation_goal": 1000,
            "sessions": n, "wall_s": round(wall, 4),
            "sessions_per_s": round(n / max(wall, 1e-9)),
            "rounds": res.rounds,
            "carbon_total_kg": res.carbon.total_kg}


def _run_fault_stress(quick: bool) -> Dict:
    """Columnar async point with the fault machinery fully live at fig5
    scale: diurnal per-country failure hazards (phase-shifted schedule
    lookups per resolve), correlated burst windows, and retry/backoff
    re-dispatch (retry_limit=2, every attempt charged). Gates the cost
    of the fault weave + retry stream keying in the hot loop."""
    import dataclasses
    cfg = get_config("paper-charlm")
    cfg.param_count()
    conc = 200 if quick else 1000
    fed = FederatedConfig(mode="async", concurrency=conc,
                          aggregation_goal=conc, retry_limit=2,
                          retry_backoff_s=30.0)
    run = RunConfig(target_perplexity=175.0,
                    max_rounds=80 if quick else 10_000)
    env = Environment()
    countries = tuple(env.country_mix)
    env = dataclasses.replace(env, fault=FaultModel(
        hazard_schedule=wave_hazard_schedule(countries, base=0.08),
        hazard_phase_h={c: UTC_OFFSET_H.get(c, 0.0) for c in countries},
        burst_rate_per_day=6.0, burst_duration_s=2400.0,
        burst_fail_prob=0.5, seed=7))
    learner = SurrogateLearner(cfg, fed, run)
    t0 = time.time()
    res = get_strategy("async").run(cfg, fed, run, learner,
                                    sampler=env.sampler(cfg, fed, 64),
                                    estimator=env.estimator())
    wall = time.time() - t0
    n = res.log.n_sessions
    parts = res.log.participation()
    return {"concurrency": conc, "aggregation_goal": conc,
            "retry_limit": 2, "sessions": n, "wall_s": round(wall, 4),
            "sessions_per_s": round(n / max(wall, 1e-9)),
            "rounds": res.rounds,
            "failed": parts.get("failed", 0),
            "retried": parts.get("retried", 0),
            "carbon_total_kg": res.carbon.total_kg,
            "wasted_kg": res.carbon.wasted_kg}


def _run_churn_stress(quick: bool) -> Dict:
    """Columnar async point with the availability machinery fully live at
    fig5 scale (PR 8): fine-grained alternating per-country eligibility
    curves (288 segments — every resolve walks the boundary scan and the
    admission draw), mid-session churn interruptions, checkpoint/resume
    salvage on the retry stream, and the salvaged/lost waste split in the
    estimator. Gates the cost of the availability weave in the hot
    loop."""
    import dataclasses
    from repro.core.availability import AvailabilityModel
    cfg = get_config("paper-charlm")
    cfg.param_count()
    conc = 200 if quick else 1000
    fed = FederatedConfig(mode="async", concurrency=conc,
                          aggregation_goal=conc, retry_limit=2,
                          retry_backoff_s=30.0, checkpoint_period_s=120.0)
    run = RunConfig(target_perplexity=175.0,
                    max_rounds=80 if quick else 10_000)
    env = Environment()
    env = dataclasses.replace(env, availability=AvailabilityModel(
        eligibility_schedule={c: (0.95, 0.45) * 144
                              for c in env.country_mix}))
    learner = SurrogateLearner(cfg, fed, run)
    t0 = time.time()
    res = get_strategy("async").run(cfg, fed, run, learner,
                                    sampler=env.sampler(cfg, fed, 64),
                                    estimator=env.estimator())
    wall = time.time() - t0
    n = res.log.n_sessions
    parts = res.log.participation()
    c = res.carbon
    assert c.wasted_kg == c.salvaged_kg + c.lost_kg     # the split is live
    return {"concurrency": conc, "aggregation_goal": conc,
            "retry_limit": 2, "checkpoint_period_s": 120.0,
            "sessions": n, "wall_s": round(wall, 4),
            "sessions_per_s": round(n / max(wall, 1e-9)),
            "rounds": res.rounds,
            "interrupted": parts.get("interrupted", 0),
            "carbon_total_kg": c.total_kg,
            "salvaged_kg": c.salvaged_kg, "lost_kg": c.lost_kg}


def _run_carbon_aware_stress(quick: bool) -> Dict:
    """Columnar carbon-aware point with BOTH diurnal grids live at fig5
    scale (PR 10): time-resolved intensity schedules on the clock AND
    diurnal per-country eligibility curves, so every replacement
    dispatch pays the full screen — probe stream, candidate country
    draws, compiled segment-mask gather for the top-k intensity filter,
    and the admission-uniform eligibility intersection. Gates the
    precompiled schedule-segment screening in the hot loop."""
    import dataclasses
    from repro.core.availability import diurnal_availability
    cfg = get_config("paper-charlm")
    cfg.param_count()
    conc = 200 if quick else 1000
    fed = FederatedConfig(mode="carbon-aware", concurrency=conc,
                          aggregation_goal=conc)
    run = RunConfig(target_perplexity=175.0,
                    max_rounds=80 if quick else 10_000)
    env = Environment.preset("diurnal")
    env = dataclasses.replace(env, availability=diurnal_availability(
        tuple(env.country_mix)))
    learner = SurrogateLearner(cfg, fed, run)
    t0 = time.time()
    res = get_strategy("carbon-aware").run(cfg, fed, run, learner,
                                           sampler=env.sampler(cfg, fed, 64),
                                           estimator=env.estimator())
    wall = time.time() - t0
    n = res.log.n_sessions
    parts = res.log.participation()
    return {"concurrency": conc, "aggregation_goal": conc,
            "sessions": n, "wall_s": round(wall, 4),
            "sessions_per_s": round(n / max(wall, 1e-9)),
            "rounds": res.rounds,
            "interrupted": parts.get("interrupted", 0),
            "carbon_total_kg": res.carbon.total_kg}


def _run_checkpoint_overhead(quick: bool) -> Dict:
    """Engine-snapshot cost (PR 9): the async fig5 point run through the
    `Experiment` surface with ``checkpoint_every_rounds=50``. A
    checkpoint is one window-boundary serialization of loop state (rows
    sidecar append + flight columns + header JSON behind an atomic
    tmp+rename); the hook reports what its saves cost
    (``Result.checkpoint_stats``), and the gated ``overhead_ratio`` is
    the checkpointed wall over that same run's wall minus its save time
    — median over 5 runs — kept under CHECKPOINT_OVERHEAD_LIMIT. The
    checkpointed summary is asserted equal to a plain run's — snapshots
    observe the loop, they never perturb it."""
    import gc
    import statistics
    import tempfile
    from repro.api import Experiment, ExperimentSpec, ModelRef
    conc = 200 if quick else 1000
    # quick keeps fig5 concurrency but runs 400 rounds so several
    # checkpoints land inside one run
    spec = ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(mode="async", concurrency=conc,
                                  aggregation_goal=conc),
        run=RunConfig(target_perplexity=175.0,
                      max_rounds=400 if quick else 10_000),
        learner="surrogate")

    def timed(**run_kw):
        # collector pauses scale with the whole bench process's heap, not
        # with this workload — keep them out of the timer (as timeit does)
        gc.collect()
        gc.disable()
        try:
            t0 = time.time()
            res = Experiment(spec).run(**run_kw)
            return time.time() - t0, res
        finally:
            gc.enable()

    # The gated ratio comes from WITHIN each checkpointed run: the hook
    # reports what its saves cost (Result.checkpoint_stats), so the
    # implied no-checkpoint wall is the same run minus that — numerator
    # and denominator share one machine-speed regime. Differencing two
    # separate runs is hopeless on a shared box whose effective CPU speed
    # drifts by tens of percent between half-second runs.
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "bench_ckpt.npz")
        ckpt_kw = dict(checkpoint_path=path, checkpoint_every_rounds=50)
        timed()                                 # warmup (shape caches etc.)
        wall_plain, res_plain = timed()         # reference run, info only
        ratios, walls_ckpt, save_walls = [], [], []
        for _ in range(5):
            w, res_ckpt = timed(**ckpt_kw)
            stats = res_ckpt.checkpoint_stats
            ratios.append(w / max(w - stats["save_wall_s"], 1e-9))
            walls_ckpt.append(w)
            save_walls.append(stats["save_wall_s"])
        ratio = statistics.median(ratios)
        size_kb = round((os.path.getsize(path)
                         + os.path.getsize(path + ".rows")) / 1024.0, 1)
    assert res_ckpt.summary() == res_plain.summary()
    n = res_plain.log.n_sessions
    return {"concurrency": conc, "checkpoint_every_rounds": 50,
            "rounds": res_plain.rounds, "sessions": n,
            "saves": res_ckpt.checkpoint_stats["saves"],
            "wall_s_plain": round(wall_plain, 4),
            "wall_s_checkpointed": round(min(walls_ckpt), 4),
            "save_wall_s": round(statistics.median(save_walls), 4),
            "checkpoint_file_kb": size_kb,
            "overhead_ratio": round(ratio, 3)}


def _run_population(quick: bool) -> Dict:
    """Population-scale async point through the streaming telemetry path
    (PR 6): quick = concurrency 10^5, full = concurrency 10^6 driven past
    10^7 sessions. The streaming run goes FIRST in the whole bench so
    ``ru_maxrss`` (a process-lifetime high-water mark) is attributable to
    it. The throughput yardstick is a matched-CONFIG materialized twin:
    per-window engine cost is O(concurrency), so a smaller-concurrency
    twin would just measure a cheaper workload. On quick the twin is the
    identical run; at full scale the big streaming run keeps all 1000
    rounds and the parity pair re-runs BOTH telemetries at 10x fewer
    rounds (the materialized half of a 10^7-row pair would be ~1.5 GB,
    which is the point of streaming). The pair's summaries are asserted
    bit-for-bit equal either way."""
    import resource
    cfg = get_config("paper-charlm")
    cfg.param_count()

    def point(conc: int, goal: int, rounds: int, telemetry: str):
        fed = FederatedConfig(mode="async", concurrency=conc,
                              aggregation_goal=goal)
        run = RunConfig(target_perplexity=1.0, max_rounds=rounds,
                        telemetry=telemetry)
        learner = SurrogateLearner(cfg, fed, run)
        t0 = time.time()
        res = get_strategy("async").run(cfg, fed, run, learner)
        return res, time.time() - t0

    conc, goal, rounds = (100_000, 2_000, 100) if quick \
        else (1_000_000, 10_000, 1_000)
    res_s, wall_s = point(conc, goal, rounds, "streaming")
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    n = res_s.log.n_sessions
    pair_rounds = rounds if quick else rounds // 10
    if pair_rounds == rounds:
        pres_s, pwall_s = res_s, wall_s
    else:
        pres_s, pwall_s = point(conc, goal, pair_rounds, "streaming")
    res_f, wall_f = point(conc, goal, pair_rounds, "full")
    nf = res_f.log.n_sessions
    # matched pair: streaming must reproduce materialized exactly
    assert pres_s.rounds == res_f.rounds
    assert pres_s.log.n_sessions == nf
    assert pres_s.carbon == res_f.carbon, (pres_s.carbon, res_f.carbon)
    assert pres_s.log.participation() == res_f.log.participation()
    assert pres_s.log.mean_staleness() == res_f.log.mean_staleness()
    sps = round(n / max(wall_s, 1e-9))
    sps_f = round(nf / max(wall_f, 1e-9))
    return {"concurrency": conc, "aggregation_goal": goal,
            "max_rounds": rounds, "sessions": n,
            "wall_s": round(wall_s, 4), "sessions_per_s": sps,
            "peak_rss_mb": round(rss_mb, 1),
            "sampled": bool(res_s.log.sampled),
            "materialized_twin": {
                "concurrency": conc, "aggregation_goal": goal,
                "max_rounds": pair_rounds, "sessions": nf,
                "wall_s": round(wall_f, 4), "sessions_per_s": sps_f},
            "slowdown_vs_materialized": round(
                pwall_s / max(wall_f, 1e-9), 3)}


def run_bench(quick: bool) -> Dict:
    # population stress runs first: ru_maxrss is a lifetime high-water
    # mark, so nothing bigger may precede the streaming run
    population = _run_population(quick)
    points = sweep_points(quick)
    columnar = _run_engine("columnar", points)
    scalar = _run_engine("scalar", points)
    result = {
        "workload": {"style": "fig5", "quick": quick, "points": points},
        "columnar": columnar,
        "scalar": scalar,
        "speedup": round(columnar["sessions_per_s"]
                         / max(scalar["sessions_per_s"], 1), 2),
        "speedup_per_mode": {
            m: round(columnar["per_mode"][m]["sessions_per_s"]
                     / max(scalar["per_mode"][m]["sessions_per_s"], 1), 2)
            for m in columnar["per_mode"]},
        "population_stress": population,
        "fault_stress": _run_fault_stress(quick),
        "churn_stress": _run_churn_stress(quick),
        "carbon_aware_stress": _run_carbon_aware_stress(quick),
        "checkpoint_overhead": _run_checkpoint_overhead(quick),
    }
    # the engines must simulate the identical workload (seed-for-seed)
    for m in columnar["per_mode"]:
        c, s = columnar["per_mode"][m], scalar["per_mode"][m]
        assert c["sessions"] == s["sessions"], (m, c, s)
        assert c["rounds"] == s["rounds"], (m, c, s)
        assert abs(c["carbon_total_kg"] - s["carbon_total_kg"]) \
            <= 1e-9 * abs(s["carbon_total_kg"]), (m, c, s)
    if not quick:
        result["async_stress"] = _run_async_stress()
    return result


def check_regression(fresh: Dict, baseline: Dict) -> int:
    """Exit status 1 if the columnar throughput regressed more than
    REGRESSION_FACTOR against the recorded baseline for this workload —
    overall, or in any individual mode (per-mode gates keep one mode's
    speedup from masking the other's regression)."""
    status = 0
    gates = [("columnar", baseline.get("columnar", {}).get("sessions_per_s", 0),
              fresh["columnar"]["sessions_per_s"])]
    for m, fm in fresh["columnar"]["per_mode"].items():
        old_m = baseline.get("columnar", {}).get("per_mode", {}) \
            .get(m, {}).get("sessions_per_s", 0)
        gates.append((f"columnar[{m}]", old_m, fm["sessions_per_s"]))
    flt = fresh.get("fault_stress")
    if flt:
        gates.append(("fault_stress",
                      baseline.get("fault_stress", {})
                      .get("sessions_per_s", 0), flt["sessions_per_s"]))
    chn = fresh.get("churn_stress")
    if chn:
        gates.append(("churn_stress",
                      baseline.get("churn_stress", {})
                      .get("sessions_per_s", 0), chn["sessions_per_s"]))
    cas = fresh.get("carbon_aware_stress")
    if cas:
        gates.append(("carbon_aware_stress",
                      baseline.get("carbon_aware_stress", {})
                      .get("sessions_per_s", 0), cas["sessions_per_s"]))
    cko = fresh.get("checkpoint_overhead")
    if cko:
        if cko["overhead_ratio"] > CHECKPOINT_OVERHEAD_LIMIT:
            print(f"bench: REGRESSION — checkpointing cost "
                  f"{cko['overhead_ratio']}x the plain wall "
                  f"(> {CHECKPOINT_OVERHEAD_LIMIT}x limit)")
            status = 1
        else:
            print(f"bench: checkpoint_overhead {cko['overhead_ratio']}x "
                  f"vs plain (limit {CHECKPOINT_OVERHEAD_LIMIT}x) — ok")
    pop = fresh.get("population_stress")
    if pop:
        gates.append(("population_stress",
                      baseline.get("population_stress", {})
                      .get("sessions_per_s", 0), pop["sessions_per_s"]))
        if pop["peak_rss_mb"] >= POPULATION_RSS_LIMIT_MB:
            print(f"bench: REGRESSION — population_stress peak RSS "
                  f"{pop['peak_rss_mb']} MB >= "
                  f"{POPULATION_RSS_LIMIT_MB} MB limit")
            status = 1
        else:
            print(f"bench: population_stress peak RSS "
                  f"{pop['peak_rss_mb']} MB < "
                  f"{POPULATION_RSS_LIMIT_MB} MB — ok")
        if pop["slowdown_vs_materialized"] > POPULATION_SLOWDOWN_LIMIT:
            print(f"bench: REGRESSION — streaming telemetry "
                  f"{pop['slowdown_vs_materialized']}x slower than the "
                  f"materialized twin (> {POPULATION_SLOWDOWN_LIMIT}x)")
            status = 1
        else:
            print(f"bench: population_stress "
                  f"{pop['slowdown_vs_materialized']}x vs materialized "
                  f"(limit {POPULATION_SLOWDOWN_LIMIT}x) — ok")
    for name, old, new in gates:
        if old and new * REGRESSION_FACTOR < old:
            print(f"bench: REGRESSION — {name} {new:,} sessions/s vs "
                  f"baseline {old:,} (>{REGRESSION_FACTOR}x slower)")
            status = 1
        else:
            print(f"bench: {name} {new:,} sessions/s vs baseline "
                  f"{old:,} — ok")
    return status


def host_meta() -> Dict:
    """Host metadata stamped on every history row, so throughput gates
    stay comparable across boxes (a 2-core CI runner and a 32-core dev
    machine should never be read as a regression of each other)."""
    import numpy
    return {"cpus": os.cpu_count(), "numpy": numpy.__version__}


def append_history_row(row: Dict, path: str) -> None:
    """Append one trajectory row (shared by bench_runtime/bench_sweep)."""
    history: List[Dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                history = json.load(f)
        except (json.JSONDecodeError, ValueError):
            # a run killed mid-rewrite leaves truncated JSON; restart the
            # trajectory rather than failing every future bench/smoke run
            print(f"bench: WARNING — {os.path.relpath(path)} was corrupt; "
                  "restarting the trajectory")
            history = []
    history.append(row)
    with open(path, "w") as f:
        json.dump(history, f, indent=1)
        f.write("\n")


def append_history(key: str, fresh: Dict, path: str) -> None:
    """One trajectory row per successful run: the per-mode throughputs and
    speedups, so regressions that stay inside the 2x gate are still
    visible across PRs."""
    row = {
        "ts": round(time.time(), 1),
        "workload": key,
        "host": host_meta(),
        "columnar_sessions_per_s": fresh["columnar"]["sessions_per_s"],
        "scalar_sessions_per_s": fresh["scalar"]["sessions_per_s"],
        "per_mode": {m: v["sessions_per_s"]
                     for m, v in fresh["columnar"]["per_mode"].items()},
        "speedup": fresh["speedup"],
        "speedup_per_mode": fresh["speedup_per_mode"],
    }
    if "async_stress" in fresh:
        row["async_stress_sessions_per_s"] = \
            fresh["async_stress"]["sessions_per_s"]
    if "population_stress" in fresh:
        pop = fresh["population_stress"]
        row["population_sessions_per_s"] = pop["sessions_per_s"]
        row["population_peak_rss_mb"] = pop["peak_rss_mb"]
    if "fault_stress" in fresh:
        row["fault_stress_sessions_per_s"] = \
            fresh["fault_stress"]["sessions_per_s"]
    if "churn_stress" in fresh:
        row["churn_stress_sessions_per_s"] = \
            fresh["churn_stress"]["sessions_per_s"]
    if "carbon_aware_stress" in fresh:
        row["carbon_aware_stress_sessions_per_s"] = \
            fresh["carbon_aware_stress"]["sessions_per_s"]
    if "checkpoint_overhead" in fresh:
        row["checkpoint_overhead_ratio"] = \
            fresh["checkpoint_overhead"]["overhead_ratio"]
    append_history_row(row, path)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small sweep for CI (conc=200, capped rounds)")
    ap.add_argument("--check", action="store_true",
                    help="fail on >2x regression vs committed baseline")
    ap.add_argument("--out", default=BENCH_PATH)
    ap.add_argument("--history", default=HISTORY_PATH)
    args = ap.parse_args()

    # BENCH_runtime.json holds one section per workload ("full" / "quick")
    # so CI quick runs never clobber the full-sweep baseline
    book: Dict = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            book = json.load(f)
    key = "quick" if args.quick else "full"
    fresh = run_bench(args.quick)
    status = check_regression(fresh, book.get(key, {})) if args.check else 0
    if status == 0:
        # a failed gate keeps the old baseline, so a rerun can't self-pass
        book[key] = fresh
        with open(args.out, "w") as f:
            json.dump(book, f, indent=1)
            f.write("\n")
        append_history(key, fresh, args.history)
    print(json.dumps({k: fresh[k] for k in
                      ("speedup", "speedup_per_mode")}, indent=1))
    print(f"[{key}] columnar: {fresh['columnar']['sessions_per_s']:,} "
          f"sessions/s | scalar: {fresh['scalar']['sessions_per_s']:,} "
          f"sessions/s | wrote {os.path.relpath(args.out)}")
    return status


if __name__ == "__main__":
    raise SystemExit(main())
