"""Paper Figure 6: fix the training TIME (4h / 10h), measure carbon and the
perplexity reached. Expected: async advances further early (lower ppl at 4h)
at higher carbon; by 10h sync catches up to a similar perplexity."""
from __future__ import annotations

from benchmarks.common import run_points, write_csv
from repro.configs import RunConfig


def run(fast: bool = False):
    conc = 400 if fast else 1000
    points = [dict(run=RunConfig(target_perplexity=1.0,  # unreachable
                                 max_hours=hours),
                   mode=mode, concurrency=conc, aggregation_goal=conc)
              for hours in (4.0, 10.0) for mode in ("sync", "async")]
    rows = run_points(points)
    for r, p in zip(rows, points):
        r["fixed_hours"] = p["run"].max_hours
    by = {(r["fixed_hours"], r["mode"]): r for r in rows}
    derived = {
        "async_lower_ppl_at_4h": float(
            by[(4.0, 1.0)]["perplexity"] < by[(4.0, 0.0)]["perplexity"]),
        "async_more_carbon_at_4h": float(
            by[(4.0, 1.0)]["carbon_total_kg"] > by[(4.0, 0.0)]["carbon_total_kg"]),
        "sync_catchup_ratio_10h":
            by[(10.0, 0.0)]["perplexity"] / max(by[(10.0, 1.0)]["perplexity"], 1e-9),
    }
    return rows, derived


if __name__ == "__main__":
    rows, d = run()
    print(write_csv(rows, "results/fig6_fixed_time.csv"))
    print(d)
