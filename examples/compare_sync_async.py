"""Reproduce the paper's Figure 5 story interactively: sync vs async FL at
equal tuning — async converges faster in wall-clock but burns more carbon.
Both arms are the same `repro.api.ExperimentSpec` with the strategy key
swapped.

  PYTHONPATH=src python examples/compare_sync_async.py
"""
from repro.api import Experiment, ExperimentSpec, ModelRef
from repro.configs import FederatedConfig, RunConfig

base = ExperimentSpec(model=ModelRef("paper-charlm"),
                      run=RunConfig(target_perplexity=175.0),
                      learner="surrogate")

print(f"{'mode':6s} {'rounds':>7s} {'hours':>7s} {'kgCO2e':>8s} "
      f"{'sessions':>9s} {'staleness':>9s}")
for mode in ("sync", "async"):
    spec = base.replace(federated=FederatedConfig(
        mode=mode, concurrency=1000, aggregation_goal=1000))
    res = Experiment(spec).run()
    print(f"{mode:6s} {res.rounds:7d} {res.duration_h:7.1f} "
          f"{res.carbon.total_kg:8.2f} {res.log.n_sessions:9d} "
          f"{res.log.mean_staleness():9.2f}")

print("\npaper finding: async advances the model faster (stragglers never "
      "block)\nbut keeps `concurrency` devices busy the whole time -> more "
      "sessions -> more CO2e.")
