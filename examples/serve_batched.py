"""Serve a reduced model with batched requests through prefill + decode —
the same step functions the decode_32k / long_500k dry-run shapes lower,
across three architecture families (dense / SSM / hybrid).

  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve

for arch in ("smollm-135m", "rwkv6-7b", "recurrentgemma-2b"):
    print(f"\n=== {arch} ===")
    serve.main(["--arch", arch, "--batch", "4", "--prompt-len", "12",
                "--gen", "6"])
