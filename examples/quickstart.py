"""Quickstart: train the paper's char-CNN-LSTM federatedly for a few rounds
and read its carbon bill — the Green-FL workflow, now one declarative
`repro.api.ExperimentSpec`.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Experiment, ExperimentSpec, ModelRef
from repro.configs import FederatedConfig, RunConfig

# 1. one spec describes the whole run: the paper's workload shrunk so a
#    laptop CPU trains it in ~1 min, a PAPAYA-shaped synchronous task
#    (8 users/round, 4-min timeout, FedAdam server / client SGD, §3.3),
#    and the real JAX learner on non-IID power-law federated data
spec = ExperimentSpec(
    model=ModelRef("paper-charlm", reduced=True,
                   reduced_kw=dict(layers=1, d_model=64, d_ff=64, vocab=256),
                   overrides=dict(lstm_hidden=64, max_context=16)),
    federated=FederatedConfig(mode="sync", concurrency=8, aggregation_goal=6,
                              client_lr=0.3, server_lr=0.02,
                              client_batch_size=8),
    run=RunConfig(target_perplexity=5.0, max_rounds=10, max_hours=1e6),
    learner="real", seq_len=16)

# 2. specs are shareable artifacts: JSON out == JSON in
assert ExperimentSpec.from_json(spec.to_json()) == spec

# 3. run it, streaming per-round progress
exp = Experiment(spec)
print(f"initial perplexity: {exp.build_learner().eval_perplexity():8.1f}")
result = exp.run(on_round=lambda ev: print(
    f"  round {ev.round_idx:2d} ppl={ev.perplexity:8.1f}"))
print(f"final perplexity:   {result.final_perplexity:8.1f} "
      f"after {result.rounds} rounds")

# 4. the carbon bill, by component (paper Fig. 5)
print(f"\ncarbon: {result.carbon.total_kg * 1000:.3f} g CO2e "
      f"across {result.log.n_sessions} client sessions")
for k, v in result.carbon.shares().items():
    print(f"  {k:16s} {v * 100:5.1f}%")
