"""Quickstart: train the paper's char-CNN-LSTM federatedly for a few rounds
and read its carbon bill — the Green-FL workflow in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.configs import FederatedConfig, RunConfig, get_config, reduced
from repro.data import FederatedDataset
from repro.federated import RealLearner, run_task

# 1. the paper's workload, shrunk so a laptop CPU trains it in ~1 min
cfg = dataclasses.replace(
    reduced(get_config("paper-charlm"), layers=1, d_model=64, d_ff=64,
            vocab=256),
    lstm_hidden=64, max_context=16)

# 2. non-IID power-law federated data (pushift-Reddit statistics)
data = FederatedDataset(vocab_size=cfg.vocab_size, seq_len=16,
                        char_vocab=cfg.char_vocab,
                        max_word_len=cfg.max_word_len)

# 3. a PAPAYA-shaped synchronous task: 8 users/round, 4-min timeout,
#    FedAdam server optimizer, client SGD (paper §3.3)
fed = FederatedConfig(mode="sync", concurrency=8, aggregation_goal=6,
                      client_lr=0.3, server_lr=0.02, client_batch_size=8)
run = RunConfig(target_perplexity=5.0, max_rounds=10, max_hours=1e6)

learner = RealLearner(cfg, fed, run, data)
print(f"initial perplexity: {learner.eval_perplexity():8.1f}")
result = run_task(cfg, fed, run, learner, seq_len=16)
print(f"final perplexity:   {result.final_perplexity:8.1f} "
      f"after {result.rounds} rounds")

# 4. the carbon bill, by component (paper Fig. 5)
print(f"\ncarbon: {result.carbon.total_kg * 1000:.3f} g CO2e "
      f"across {len(result.log.sessions)} client sessions")
for k, v in result.carbon.shares().items():
    print(f"  {k:16s} {v * 100:5.1f}%")
