"""Pre-deployment carbon planning (paper §5.3 + C4): search the config space
with the surrogate, print the (time, carbon) Pareto frontier and the
greenest config that meets a deadline, then fit the carbon predictor.

  PYTHONPATH=src python examples/green_advisor.py
"""
from repro.configs import RunConfig, get_config
from repro.core.advisor import GreenAdvisor
from repro.core.predictor import CarbonPredictor

cfg = get_config("paper-charlm")
advisor = GreenAdvisor(cfg, RunConfig(target_perplexity=175.0, max_hours=24.0))

grid = dict(mode=("sync",), concurrency=(50, 100, 200, 800),
            local_epochs=(1, 3), compression=("none", "int8"))
recs = advisor.search(grid=grid)

print("(time, carbon) Pareto frontier:")
for r in GreenAdvisor.pareto(recs):
    print("  " + r.why())

best = recs[0]
print("\ngreenest feasible config:\n  " + best.why())
deadline = advisor.search(grid=grid, max_hours=18.0)[0]
# recommendations carry a `feasible` flag: an impossible deadline returns
# the least-bad candidates explicitly marked [INFEASIBLE]
print("greenest under an 18h deadline:\n  " + deadline.why())
impossible = advisor.search(grid=grid, max_hours=0.01)[0]
print("under an impossible 36s deadline:\n  " + impossible.why())

# the paper's predictor: carbon ≈ a (concurrency x rounds) + b, fit on a
# dedicated calibration set (one wire format, tuned lrs, E=1 — the paper
# fits one line per task/format since int8 halves the slope)
from repro.configs import FederatedConfig
calib = [advisor.evaluate(FederatedConfig(
    mode="sync", concurrency=c, aggregation_goal=int(c * 0.8)))
    for c in (50, 100, 200, 400, 800)]
pred = CarbonPredictor.from_measurements(
    "sync", [r.fed.concurrency for r in calib],
    [r.rounds for r in calib], [r.carbon_kg for r in calib])
print(f"\npredictor fit: slope={pred.fit.slope:.3e} kg per client-round, "
      f"R^2={pred.fit.r2:.3f}")
print(f"forecast for concurrency=1000 x 250 rounds: "
      f"{pred.predict_kg(1000, 250):.1f} kg CO2e")
