"""Generate results/roofline_table.md from the three dry-run JSONs."""
import json, sys
sys.path.insert(0, "src")
from benchmarks.roofline_report import markdown_table

out = []
for title, f in [("Single pod 16x16 (baseline)", "results/dryrun_single_pod.json"),
                 ("Two pods 2x16x16 (baseline)", "results/dryrun_multi_pod.json"),
                 ("Single pod 16x16 (OPTIMIZED serving: --variant flash_decode)",
                  "results/dryrun_single_pod_optimized.json")]:
    try:
        rows = json.load(open(f))
    except FileNotFoundError:
        continue
    clean = []
    for r in rows:
        clean.append({k: v for k, v in r.items() if not isinstance(v, dict)})
    out.append(f"### {title}\n\n" + markdown_table(clean) + "\n")
open("results/roofline_table.md", "w").write("\n".join(out))
print("wrote results/roofline_table.md")
