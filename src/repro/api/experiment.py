"""`Experiment` — the one way to run an FL task — and its `Result`.

    spec = ExperimentSpec(model=ModelRef("paper-charlm"),
                          federated=FederatedConfig(concurrency=100, ...))
    result = Experiment(spec).run(on_round=print)
    result.summary()          # rounds / duration / per-component carbon

The runner resolves the model ref, builds the chosen learner, dispatches
`spec.federated.mode` through the strategy registry, and threads the
spec's `Environment` into both the session sampler and the carbon
estimator. Per-round `RoundEvent`s stream to callbacks while the task
runs; the returned `Result` subsumes the legacy TaskResult + its
CarbonBreakdown and records the spec that produced it.

Population-scale tasks keep the same surface with constant memory:

    spec = ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(mode="async", concurrency=1_000_000,
                                  aggregation_goal=10_000),
        run=RunConfig(max_rounds=1_000, telemetry="streaming"))
    res = Experiment(spec).run()
    res.summary()            # exact — bit-for-bit vs telemetry="full"
    res.log.columns()        # seed-deterministic reservoir sample

`telemetry="streaming"` swaps the materialized TaskLog for a
`repro.core.streaming.StreamedLog`: summary scalars (carbon, energy,
bytes, participation, staleness) fold into error-free running sums and
stay exactly equal to the materialized path, while per-session columns
are a `telemetry_sample`-row reservoir (`log.sampled` says whether the
population outgrew it).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

from repro.api.spec import ExperimentSpec
from repro.configs.base import ModelConfig
from repro.core.estimator import CarbonBreakdown
from repro.core.telemetry import TaskLog
from repro.federated.runtime import (RoundEvent, TaskResult, get_strategy)

RoundCallback = Callable[[RoundEvent], None]
StartCallback = Callable[[ExperimentSpec], None]
CompleteCallback = Callable[["Result"], None]


@dataclass(frozen=True)
class Result:
    """Everything a finished experiment produced: telemetry log, carbon
    breakdown, convergence verdict — plus the spec that generated it and
    the real wall-clock cost of running the simulation."""

    spec: ExperimentSpec
    log: TaskLog
    carbon: CarbonBreakdown
    reached_target: bool
    rounds: int
    duration_h: float
    final_perplexity: float
    smoothed_perplexity: float
    wall_s: float = 0.0
    aborted: bool = False   # starvation abort (sync graceful degradation)
    # when the run checkpointed: {"saves": n, "save_wall_s": s} — what the
    # snapshots cost this run (not part of summary(); summaries stay
    # bit-comparable across checkpointed and plain runs)
    checkpoint_stats: Optional[Dict[str, float]] = None

    @classmethod
    def from_task_result(cls, spec: ExperimentSpec, tr: TaskResult,
                         wall_s: float = 0.0,
                         checkpoint_stats: Optional[Dict[str, float]] = None
                         ) -> "Result":
        return cls(spec=spec, log=tr.log, carbon=tr.carbon,
                   reached_target=tr.reached_target, rounds=tr.rounds,
                   duration_h=tr.duration_h,
                   final_perplexity=tr.final_perplexity,
                   smoothed_perplexity=tr.smoothed_perplexity,
                   wall_s=wall_s, aborted=tr.aborted,
                   checkpoint_stats=checkpoint_stats)

    def summary(self) -> Dict[str, float]:
        """Same keys as the legacy TaskResult.summary() so downstream CSV
        tooling keeps working unchanged."""
        return {
            "rounds": self.rounds,
            "duration_h": self.duration_h,
            "reached_target": float(self.reached_target),
            "perplexity": self.final_perplexity,
            "carbon_total_kg": self.carbon.total_kg,
            **{k: v for k, v in self.carbon.as_dict().items()},
            "sessions": float(self.log.n_sessions),
            "aborted": float(self.aborted),
        }

    def to_dict(self) -> dict:
        return {
            "summary": self.summary(),
            "carbon_shares": self.carbon.shares(),
            "participation": self.log.participation(),
            "mean_staleness": self.log.mean_staleness(),
            "wall_s": self.wall_s,
            "spec": self.spec.to_dict(),
        }


class Experiment:
    """Runs an ExperimentSpec. `run()` uses the injected learner if one was
    given, else the learner pre-built with `build_learner()` (handy for
    inspecting initial state), else builds a fresh one — and a second
    `run()` always rebuilds a non-injected learner, so the same Experiment
    re-runs reproducibly."""

    def __init__(self, spec: ExperimentSpec, learner=None):
        self.spec = spec
        self._injected = learner is not None
        self.learner = learner            # the learner of the next/latest run
        self._consumed = False
        self._model_cfg: Optional[ModelConfig] = None

    @property
    def model_config(self) -> ModelConfig:
        if self._model_cfg is None:
            self._model_cfg = self.spec.model.resolve()
        return self._model_cfg

    def build_learner(self):
        """Build (and remember) the learner the next `run()` will use."""
        if not self._injected:
            self.learner = self._make_learner()
            self._consumed = False
        return self.learner

    def _make_learner(self):
        spec = self.spec
        cfg = self.model_config
        if spec.learner == "surrogate":
            from repro.federated.surrogate import SurrogateLearner
            return SurrogateLearner(cfg, spec.federated, spec.run)
        from repro.data.synthetic import FederatedDataset
        from repro.federated.real import RealLearner
        ds = FederatedDataset(vocab_size=cfg.vocab_size, seq_len=spec.seq_len,
                              char_vocab=cfg.char_vocab,
                              max_word_len=cfg.max_word_len)
        return RealLearner(cfg, spec.federated, spec.run, ds,
                           max_client_steps=spec.max_client_steps)

    def run(self, on_round: Optional[RoundCallback] = None,
            on_start: Optional[StartCallback] = None,
            on_complete: Optional[CompleteCallback] = None, *,
            checkpoint_path: Optional[str] = None,
            checkpoint_every_rounds: int = 0,
            resume_from: Optional[str] = None) -> Result:
        """Run the spec; optionally checkpoint and/or resume mid-run state.

        Snapshot contract (see ``repro.core.snapshot``): with
        ``checkpoint_path`` + ``checkpoint_every_rounds=N`` the engine
        writes a versioned checkpoint every N rounds (sync) / server
        versions (async), atomically. ``resume_from`` restores one and
        continues; a resumed run's ``summary()`` AND session columns are
        **bit-for-bit** identical to the uninterrupted run on every
        strategy × telemetry × schedule combination — that is what the
        counter-keyed randomness buys. NOT exact: ``wall_s`` (real time
        actually spent), and any work done after the last checkpoint is
        redone, not replayed. Snapshots cover the surrogate learner only
        (the real JAX learner carries unserialized params); lane-batched
        ``sweep(vectorize=True)`` packs resume at the sweep layer (retry/
        salvage) rather than through engine snapshots.
        """
        spec = self.spec
        cfg = self.model_config
        if self.learner is None or (self._consumed and not self._injected):
            self.build_learner()
        self._consumed = True
        strategy = get_strategy(spec.federated.mode)
        env = spec.environment
        snap = self._snapshot_hook(checkpoint_path, checkpoint_every_rounds,
                                   resume_from)
        if on_start is not None:
            on_start(spec)
        t0 = time.time()
        tr = strategy.run(
            cfg, spec.federated, spec.run, self.learner,
            seq_len=spec.seq_len,
            estimator=env.estimator(),
            sampler=env.sampler(cfg, spec.federated, spec.seq_len),
            on_round=on_round, snap=snap)
        stats = None
        if snap is not None and snap.saves:
            stats = {"saves": snap.saves,
                     "save_wall_s": round(snap.save_wall_s, 6)}
        result = Result.from_task_result(spec, tr, wall_s=time.time() - t0,
                                         checkpoint_stats=stats)
        if on_complete is not None:
            on_complete(result)
        return result

    def _snapshot_hook(self, checkpoint_path, checkpoint_every_rounds,
                       resume_from):
        from repro.core.snapshot import (SnapshotHook, _CrashInjector,
                                         load_snapshot)
        spec = self.spec
        crash = _CrashInjector.from_env(seed=spec.federated.seed)
        resume = None
        if resume_from is not None:
            resume = load_snapshot(resume_from)
            want, found = spec.content_hash(), resume.spec_hash
            if found != want:
                raise ValueError(
                    f"checkpoint {resume_from!r} was written by a "
                    f"different spec: its spec hash is {found}, this "
                    f"experiment's spec hash is {want} — refusing a "
                    f"wrong-spec resume")
        if (checkpoint_path or resume is not None) \
                and spec.learner != "surrogate":
            raise ValueError(
                "engine snapshots support learner='surrogate' only; the "
                "real JAX learner's parameters are not serialized")
        if checkpoint_path and checkpoint_every_rounds <= 0 \
                and resume is None:
            raise ValueError(
                "checkpoint_path requires checkpoint_every_rounds > 0")
        if checkpoint_path is None and resume is None and crash is None:
            return None
        path = checkpoint_path or resume_from
        every = checkpoint_every_rounds or (resume.every if resume else 0)
        return SnapshotHook(path=path, every=every, spec=spec,
                            mode=spec.federated.mode, crash=crash,
                            resume=resume)

    @classmethod
    def resume(cls, path: str, *,
               checkpoint_path: Optional[str] = None,
               checkpoint_every_rounds: int = 0,
               on_round: Optional[RoundCallback] = None,
               on_start: Optional[StartCallback] = None,
               on_complete: Optional[CompleteCallback] = None) -> Result:
        """Resume a checkpointed run from its snapshot file and run it to
        completion. The spec travels inside the checkpoint header, so the
        caller needs nothing but the path. By default the resumed run
        keeps checkpointing to the same file at the saved cadence;
        override with ``checkpoint_path``/``checkpoint_every_rounds``."""
        from repro.core.snapshot import load_snapshot
        snap = load_snapshot(path)
        exp = cls(snap.spec())
        return exp.run(on_round=on_round, on_start=on_start,
                       on_complete=on_complete,
                       checkpoint_path=checkpoint_path or path,
                       checkpoint_every_rounds=checkpoint_every_rounds,
                       resume_from=path)


def run_spec(spec: ExperimentSpec, **callbacks) -> Result:
    """One-liner convenience: `run_spec(ExperimentSpec(...))`."""
    return Experiment(spec).run(**callbacks)
