"""`repro.api` — the declarative Experiment layer (the ONE entry point).

    from repro.api import Environment, Experiment, ExperimentSpec, ModelRef

    spec = ExperimentSpec(model=ModelRef("paper-charlm"),
                          federated=FederatedConfig(mode="async", ...),
                          environment=Environment(download_bps=50e6))
    result = Experiment(spec).run(on_round=lambda ev: print(ev.round_idx))
    spec.save("exp.json")     # shareable artifact; reload reproduces result

Strategies ("sync", "async", ...) dispatch through the string-keyed
registry in `repro.federated.runtime`; carbon/energy/network models all
come from the spec's `Environment` rather than module defaults.
"""
from repro.api.environment import Environment
from repro.api.experiment import Experiment, Result, run_spec
from repro.api.spec import ExperimentSpec, ModelRef
from repro.api.sweep import sweep
from repro.federated.runtime import (STRATEGIES, LaneRunner, LaneTask,
                                     RoundEvent, Strategy, get_strategy,
                                     register_strategy)

__all__ = [
    "Environment", "Experiment", "ExperimentSpec", "LaneRunner", "LaneTask",
    "ModelRef", "Result", "RoundEvent", "STRATEGIES", "Strategy",
    "get_strategy", "register_strategy", "run_spec", "sweep",
]
