"""`repro.api.sweep` — run independent ExperimentSpecs across a process
pool, or lane-batched as a handful of columnar simulations.

    results = sweep([spec_a, spec_b, ...], workers=8)
    results = sweep(specs, vectorize=True)      # lane-batched packs

Every spec is self-contained and JSON-serializable (that was the point of
the `repro.api` layer), so a sweep is embarrassingly parallel: each worker
process runs `Experiment(spec).run()` and ships the whole `Result`
(columnar TaskLog included — NumPy columns pickle cheaply) back to the
parent. Results come back in spec order; `on_result` streams them to the
caller in completion order for progress display.

`workers=None` picks min(n_specs, cpu_count); `workers<=1` (or a single
spec) runs serially in-process — no pool, no pickling — which is also the
fallback when a pool cannot be spawned (restricted environments).

Lane-batched mode (``vectorize=True``)
--------------------------------------

Design-space sweeps are dozens-to-hundreds of *small* runs, exactly where
the per-call fixed cost of small columnar dispatches dominates and a
process pool caps out near the core count. ``vectorize=True`` groups
compatible specs into *lane packs* and advances each pack in lockstep as
ONE columnar simulation (`repro.federated.runtime.LaneRunner`): sampler
draws become (lane, batch)-shaped arrays keyed per lane, telemetry lands
in one lane-columnar store, and the estimator reduces per-lane segments.

Pack-compatibility rules — specs pack together iff they share:

* ``federated.mode`` (one lockstep window shape per pack), where the
  registered strategy implements ``lane_loop`` ("sync", "async" and
  "carbon-aware" do; custom strategies without it run per-spec);
* ``learner == "surrogate"`` (a real JAX learner gains nothing from
  lockstep batching; real-learner specs run per-spec).

Everything else may differ per lane: concurrency, aggregation goal,
seeds, model size, run budgets, and every ``Environment`` knob (fleet,
country mix, bandwidths, intensity tables, network model, PUE). Results
are **seed-for-seed identical** to per-spec serial runs — same summary
scalars, same session columns — because lanes share no RNG state (all
randomness is counter-keyed on each lane's own seed).

With ``workers > 1`` each pack is chunked into up to ``workers``
sub-packs that fan out across the process pool, so lane batching and
multi-core parallelism compose (a chunk still amortizes dispatch over
its lanes); pool failures fall back to running the remaining jobs
serially in-process, delivering ``on_result`` exactly once per spec
either way.
"""
from __future__ import annotations

import os
import time
from concurrent.futures import BrokenExecutor
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.api.experiment import Experiment, Result, run_spec
from repro.api.spec import ExperimentSpec

ResultCallback = Callable[[int, Result], None]

_POOL_ERRORS = (ImportError, OSError, PermissionError, BrokenExecutor)


class _TaskFailed(Exception):
    """Wraps an exception raised by a spec's own run inside a pool worker,
    so infrastructure failures (pool can't start) stay distinguishable
    from experiment failures (which must propagate as-is, not trigger the
    serial fallback)."""

    def __init__(self, error: BaseException):
        super().__init__(repr(error))
        self.error = error


def _n_workers(n_specs: int, workers: Optional[int]) -> int:
    if workers is None:
        workers = os.cpu_count() or 1
    return max(1, min(int(workers), n_specs))


def _annotate(e: BaseException, note: str) -> BaseException:
    """Prepend context to an exception's message in place (3.10-compatible
    stand-in for ``add_note``), preserving its type so callers' ``except``
    clauses and ``pytest.raises(..., match=...)`` searches still hit."""
    if e.args and isinstance(e.args[0], str):
        e.args = (f"{note}: {e.args[0]}",) + e.args[1:]
    else:
        e.args = (note,) + tuple(e.args)
    return e


# ---------------------------------------------------------------------------
# Lane packs
# ---------------------------------------------------------------------------

def _pack_key(spec: ExperimentSpec) -> Optional[str]:
    """Lane-pack compatibility key, or None when the spec must run
    per-spec (see the module docstring for the rules). A strategy joins
    packs only by defining ``lane_loop`` on ITSELF: a registered subclass
    that overrides ``_loop`` but inherits the parent's ``lane_loop``
    would be silently lane-batched with the parent's semantics, breaking
    the lane==serial invariant — so inheritance does not opt in."""
    if spec.learner != "surrogate":
        return None
    from repro.federated.runtime import STRATEGIES
    mode = spec.federated.mode
    cls = STRATEGIES.get(mode)
    if cls is None or "lane_loop" not in cls.__dict__:
        return None
    # streaming and full-telemetry lanes use different session stores
    # (StreamedLog folds vs one LaneAccumulator) — keep them in separate
    # packs so each pack's store is uniform
    return f"{mode}|{spec.run.telemetry}"


def _group_packs(specs: Sequence[ExperimentSpec]
                 ) -> List[Tuple[str, List[int]]]:
    """Partition spec indices into jobs: ("pack", [i...]) lane packs and
    ("spec", [i]) per-spec leftovers, preserving first-seen order."""
    packs: Dict[str, List[int]] = {}
    jobs: List[Tuple[str, List[int]]] = []
    for idx, spec in enumerate(specs):
        key = _pack_key(spec)
        if key is None:
            jobs.append(("spec", [idx]))
        elif key in packs:
            packs[key].append(idx)
        else:
            packs[key] = [idx]
            jobs.append(("pack", packs[key]))
    return jobs


def _chunk_packs(jobs: List[Tuple[str, List[int]]],
                 n_chunks: int) -> List[Tuple[str, List[int]]]:
    """Split each lane pack into up to ``n_chunks`` sub-packs so packs
    fan out across the process pool instead of pinning one core per mode
    (each chunk keeps enough lanes to amortize dispatch; lanes are
    independent, so any partition is equivalence-preserving)."""
    if n_chunks <= 1:
        return jobs
    out: List[Tuple[str, List[int]]] = []
    for kind, idxs in jobs:
        if kind != "pack" or len(idxs) <= 1:
            out.append((kind, idxs))
            continue
        size = -(-len(idxs) // min(n_chunks, len(idxs)))   # ceil division
        out.extend(("pack", idxs[i:i + size])
                   for i in range(0, len(idxs), size))
    return out


def _run_pack(specs: List[ExperimentSpec],
              idxs: Optional[List[int]] = None) -> List[Result]:
    """Run one lane pack through LaneRunner; Results in pack order.
    ``wall_s`` records each lane's amortized share of the pack wall.
    Failures are annotated with the lane (and sweep spec index) at fault
    so a 50-lane pack's traceback names the offending spec."""
    from repro.federated.runtime import LaneRunner, LaneTask
    t0 = time.time()
    tasks = []
    for lane, spec in enumerate(specs):
        try:
            exp = Experiment(spec)
            cfg = exp.model_config
            env = spec.environment
            tasks.append(LaneTask(
                model_cfg=cfg, fed=spec.federated, run=spec.run,
                learner=exp.build_learner(),
                sampler=env.sampler(cfg, spec.federated, spec.seq_len),
                estimator=env.estimator()))
        except Exception as e:                   # noqa: BLE001
            where = f"sweep lane {lane}" if idxs is None \
                else f"sweep lane {lane} (spec index {idxs[lane]})"
            raise _annotate(e, where)
    try:
        trs = LaneRunner(specs[0].federated.mode).run(tasks)
    except Exception as e:                       # noqa: BLE001
        where = f"sweep lane pack of {len(specs)} lanes" if idxs is None \
            else f"sweep lane pack (spec indices {list(idxs)})"
        raise _annotate(e, where)
    wall = (time.time() - t0) / len(specs)
    return [Result.from_task_result(spec, tr, wall_s=wall)
            for spec, tr in zip(specs, trs)]


def _run_job(kind: str, specs: List[ExperimentSpec],
             idxs: Optional[List[int]] = None) -> List[Result]:
    if kind == "pack":
        return _run_pack(specs, idxs)
    return [run_spec(specs[0])]


def _run_job_safe(kind: str, specs: List[ExperimentSpec],
                  idxs: Optional[List[int]] = None):
    try:
        return ("ok", _run_job(kind, specs, idxs))
    except Exception as e:                       # noqa: BLE001
        return ("err", e)


# ---------------------------------------------------------------------------
# The sweep entry point
# ---------------------------------------------------------------------------

def sweep(specs: Sequence[ExperimentSpec], workers: Optional[int] = None,
          on_result: Optional[ResultCallback] = None,
          vectorize: bool = False) -> List[Result]:
    """Run every spec; return Results in spec order.

    on_result(index, result) fires in completion order as workers finish
    (or after each run/pack when serial). ``vectorize=True`` lane-batches
    compatible specs into lockstep packs (see module docstring); the
    per-spec path is the degenerate one-spec-per-job case of the same
    machinery."""
    specs = list(specs)
    if not specs:
        return []
    if vectorize:
        jobs = _chunk_packs(_group_packs(specs),
                            _n_workers(len(specs), workers))
    else:
        jobs = [("spec", [i]) for i in range(len(specs))]
    results: List[Optional[Result]] = [None] * len(specs)

    def deliver(idxs: List[int], rs: List[Result]) -> None:
        for i, r in zip(idxs, rs):
            results[i] = r
            if on_result is not None:
                on_result(i, r)

    n = _n_workers(len(jobs), workers)
    if n > 1 and len(jobs) > 1:
        try:
            _sweep_pool(jobs, specs, n, deliver)
        except _TaskFailed as tf:
            raise tf.error                # an experiment itself failed
        except _POOL_ERRORS as e:
            # restricted environments (no /dev/shm, no fork / broken pool)
            # fall back to in-process — only for the jobs the pool never
            # finished, so on_result fires exactly once per spec
            import warnings
            pending = [i for i, r in enumerate(results) if r is None]
            warnings.warn(
                f"sweep: process pool unavailable ({e!r}); running the "
                f"remaining {len(pending)}/{len(specs)} specs "
                f"in-process (spec indices {pending})",
                RuntimeWarning, stacklevel=2)
    for kind, idxs in jobs:
        if results[idxs[0]] is None:      # packs deliver all-or-nothing
            deliver(idxs, _run_job(kind, [specs[i] for i in idxs], idxs))
    return results  # type: ignore[return-value]


def _sweep_pool(jobs: List[Tuple[str, List[int]]],
                specs: List[ExperimentSpec], n: int,
                deliver: Callable[[List[int], List[Result]], None]) -> None:
    from concurrent.futures import ProcessPoolExecutor, as_completed
    with ProcessPoolExecutor(max_workers=n) as pool:
        futures = {pool.submit(_run_job_safe, kind,
                               [specs[i] for i in idxs], idxs): idxs
                   for kind, idxs in jobs}
        for fut in as_completed(futures):
            status, payload = fut.result()
            if status == "err":
                raise _TaskFailed(payload)
            deliver(futures[fut], payload)
