"""`repro.api.sweep` — run independent ExperimentSpecs across a process
pool.

    results = sweep([spec_a, spec_b, ...], workers=8)

Every spec is self-contained and JSON-serializable (that was the point of
the `repro.api` layer), so a sweep is embarrassingly parallel: each worker
process runs `Experiment(spec).run()` and ships the whole `Result`
(columnar TaskLog included — NumPy columns pickle cheaply) back to the
parent. Results come back in spec order; `on_result` streams them to the
caller in completion order for progress display.

`workers=None` picks min(n_specs, cpu_count); `workers<=1` (or a single
spec) runs serially in-process — no pool, no pickling — which is also the
fallback when a pool cannot be spawned (restricted environments).
"""
from __future__ import annotations

import os
from concurrent.futures import BrokenExecutor
from typing import Callable, List, Optional, Sequence

from repro.api.experiment import Result, run_spec
from repro.api.spec import ExperimentSpec

ResultCallback = Callable[[int, Result], None]


class _TaskFailed(Exception):
    """Wraps an exception raised by a spec's own run inside a pool worker,
    so infrastructure failures (pool can't start) stay distinguishable
    from experiment failures (which must propagate as-is, not trigger the
    serial fallback)."""

    def __init__(self, error: BaseException):
        super().__init__(repr(error))
        self.error = error


def _run_spec_safe(spec: ExperimentSpec):
    try:
        return ("ok", run_spec(spec))
    except Exception as e:                       # noqa: BLE001
        return ("err", e)


def _n_workers(n_specs: int, workers: Optional[int]) -> int:
    if workers is None:
        workers = os.cpu_count() or 1
    return max(1, min(int(workers), n_specs))


def sweep(specs: Sequence[ExperimentSpec], workers: Optional[int] = None,
          on_result: Optional[ResultCallback] = None) -> List[Result]:
    """Run every spec; return Results in spec order.

    on_result(index, result) fires in completion order as workers finish
    (or after each run when serial)."""
    specs = list(specs)
    if not specs:
        return []
    results: List[Optional[Result]] = [None] * len(specs)
    n = _n_workers(len(specs), workers)
    if n > 1 and len(specs) > 1:
        try:
            _sweep_pool(specs, n, results, on_result)
        except _TaskFailed as tf:
            raise tf.error                # an experiment itself failed
        except (ImportError, OSError, PermissionError, BrokenExecutor) as e:
            # restricted environments (no /dev/shm, no fork / broken pool)
            # fall back to serial — only for the specs the pool never
            # finished, so on_result fires exactly once per spec
            import warnings
            done = sum(r is not None for r in results)
            warnings.warn(
                f"sweep: process pool unavailable ({e!r}); running the "
                f"remaining {len(specs) - done}/{len(specs)} specs serially",
                RuntimeWarning, stacklevel=2)
    for i, spec in enumerate(specs):
        if results[i] is None:
            results[i] = run_spec(spec)
            if on_result is not None:
                on_result(i, results[i])
    return results  # type: ignore[return-value]


def _sweep_pool(specs: List[ExperimentSpec], n: int,
                results: List[Optional[Result]],
                on_result: Optional[ResultCallback]) -> None:
    from concurrent.futures import ProcessPoolExecutor, as_completed
    with ProcessPoolExecutor(max_workers=n) as pool:
        futures = {pool.submit(_run_spec_safe, spec): i
                   for i, spec in enumerate(specs)}
        for fut in as_completed(futures):
            i = futures[fut]
            status, payload = fut.result()
            if status == "err":
                raise _TaskFailed(payload)
            results[i] = payload
            if on_result is not None:
                on_result(i, results[i])
