"""`repro.api.sweep` — run independent ExperimentSpecs across a process
pool, or lane-batched as a handful of columnar simulations.

    results = sweep([spec_a, spec_b, ...], workers=8)
    results = sweep(specs, vectorize=True)      # lane-batched packs

Every spec is self-contained and JSON-serializable (that was the point of
the `repro.api` layer), so a sweep is embarrassingly parallel: each worker
process runs `Experiment(spec).run()` and ships the whole `Result`
(columnar TaskLog included — NumPy columns pickle cheaply) back to the
parent. Results come back in spec order; `on_result` streams them to the
caller in completion order for progress display.

`workers=None` picks min(n_specs, cpu_count); `workers<=1` (or a single
spec) runs serially in-process — no pool, no pickling — which is also the
fallback when a pool cannot be spawned (restricted environments).

Lane-batched mode (``vectorize=True``)
--------------------------------------

Design-space sweeps are dozens-to-hundreds of *small* runs, exactly where
the per-call fixed cost of small columnar dispatches dominates and a
process pool caps out near the core count. ``vectorize=True`` groups
compatible specs into *lane packs* and advances each pack in lockstep as
ONE columnar simulation (`repro.federated.runtime.LaneRunner`): sampler
draws become (lane, batch)-shaped arrays keyed per lane, telemetry lands
in one lane-columnar store, and the estimator reduces per-lane segments.

Pack-compatibility rules — specs pack together iff they share:

* ``federated.mode`` (one lockstep window shape per pack), where the
  registered strategy implements ``lane_loop`` ("sync", "async" and
  "carbon-aware" do; custom strategies without it run per-spec);
* ``learner == "surrogate"`` (a real JAX learner gains nothing from
  lockstep batching; real-learner specs run per-spec).

Everything else may differ per lane: concurrency, aggregation goal,
seeds, model size, run budgets, and every ``Environment`` knob (fleet,
country mix, bandwidths, intensity tables, network model, PUE). Results
are **seed-for-seed identical** to per-spec serial runs — same summary
scalars, same session columns — because lanes share no RNG state (all
randomness is counter-keyed on each lane's own seed).

With ``workers > 1`` each pack is chunked into up to ``workers``
sub-packs that fan out across the process pool, so lane batching and
multi-core parallelism compose (a chunk still amortizes dispatch over
its lanes); pool failures fall back to running the remaining jobs
serially in-process, delivering ``on_result`` exactly once per spec
either way.
"""
from __future__ import annotations

import os
import time
from concurrent.futures import BrokenExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.api.experiment import Experiment, Result, run_spec
from repro.api.spec import ExperimentSpec

ResultCallback = Callable[[int, Result], None]
# on_failure(spec_index, error, attempt) — fires once per failed attempt
FailureCallback = Callable[[int, BaseException, int], None]

_POOL_ERRORS = (ImportError, OSError, PermissionError, BrokenExecutor)


class _TaskFailed(Exception):
    """Wraps an exception raised by a spec's own run inside a pool worker,
    so infrastructure failures (pool can't start) stay distinguishable
    from experiment failures (which must propagate as-is, not trigger the
    serial fallback)."""

    def __init__(self, error: BaseException):
        super().__init__(repr(error))
        self.error = error


def _n_workers(n_specs: int, workers: Optional[int]) -> int:
    if workers is None:
        workers = os.cpu_count() or 1
    return max(1, min(int(workers), n_specs))


def _annotate(e: BaseException, note: str) -> BaseException:
    """Prepend context to an exception's message in place (3.10-compatible
    stand-in for ``add_note``), preserving its type so callers' ``except``
    clauses and ``pytest.raises(..., match=...)`` searches still hit."""
    if e.args and isinstance(e.args[0], str):
        e.args = (f"{note}: {e.args[0]}",) + e.args[1:]
    else:
        e.args = (note,) + tuple(e.args)
    return e


# ---------------------------------------------------------------------------
# Lane packs
# ---------------------------------------------------------------------------

def _pack_key(spec: ExperimentSpec) -> Optional[str]:
    """Lane-pack compatibility key, or None when the spec must run
    per-spec (see the module docstring for the rules). A strategy joins
    packs only by defining ``lane_loop`` on ITSELF: a registered subclass
    that overrides ``_loop`` but inherits the parent's ``lane_loop``
    would be silently lane-batched with the parent's semantics, breaking
    the lane==serial invariant — so inheritance does not opt in."""
    if spec.learner != "surrogate":
        return None
    from repro.federated.runtime import STRATEGIES
    mode = spec.federated.mode
    cls = STRATEGIES.get(mode)
    if cls is None or "lane_loop" not in cls.__dict__:
        return None
    # streaming and full-telemetry lanes use different session stores
    # (StreamedLog folds vs one LaneAccumulator) — keep them in separate
    # packs so each pack's store is uniform
    return f"{mode}|{spec.run.telemetry}"


def _group_packs(specs: Sequence[ExperimentSpec]
                 ) -> List[Tuple[str, List[int]]]:
    """Partition spec indices into jobs: ("pack", [i...]) lane packs and
    ("spec", [i]) per-spec leftovers, preserving first-seen order."""
    packs: Dict[str, List[int]] = {}
    jobs: List[Tuple[str, List[int]]] = []
    for idx, spec in enumerate(specs):
        key = _pack_key(spec)
        if key is None:
            jobs.append(("spec", [idx]))
        elif key in packs:
            packs[key].append(idx)
        else:
            packs[key] = [idx]
            jobs.append(("pack", packs[key]))
    return jobs


def _chunk_packs(jobs: List[Tuple[str, List[int]]],
                 n_chunks: int) -> List[Tuple[str, List[int]]]:
    """Split each lane pack into up to ``n_chunks`` sub-packs so packs
    fan out across the process pool instead of pinning one core per mode
    (each chunk keeps enough lanes to amortize dispatch; lanes are
    independent, so any partition is equivalence-preserving)."""
    if n_chunks <= 1:
        return jobs
    out: List[Tuple[str, List[int]]] = []
    for kind, idxs in jobs:
        if kind != "pack" or len(idxs) <= 1:
            out.append((kind, idxs))
            continue
        size = -(-len(idxs) // min(n_chunks, len(idxs)))   # ceil division
        out.extend(("pack", idxs[i:i + size])
                   for i in range(0, len(idxs), size))
    return out


def _run_pack(specs: List[ExperimentSpec],
              idxs: Optional[List[int]] = None) -> List[Result]:
    """Run one lane pack through LaneRunner; Results in pack order.
    ``wall_s`` records each lane's amortized share of the pack wall.
    Failures are annotated with the lane (and sweep spec index) at fault
    so a 50-lane pack's traceback names the offending spec."""
    from repro.federated.runtime import LaneRunner, LaneTask
    t0 = time.time()
    tasks = []
    for lane, spec in enumerate(specs):
        try:
            exp = Experiment(spec)
            cfg = exp.model_config
            env = spec.environment
            tasks.append(LaneTask(
                model_cfg=cfg, fed=spec.federated, run=spec.run,
                learner=exp.build_learner(),
                sampler=env.sampler(cfg, spec.federated, spec.seq_len),
                estimator=env.estimator()))
        except Exception as e:                   # noqa: BLE001
            where = f"sweep lane {lane}" if idxs is None \
                else f"sweep lane {lane} (spec index {idxs[lane]})"
            if idxs is not None:
                e.spec_index = idxs[lane]   # culprit for pack salvage
            raise _annotate(e, where)
    try:
        trs = LaneRunner(specs[0].federated.mode).run(tasks)
    except Exception as e:                       # noqa: BLE001
        where = f"sweep lane pack of {len(specs)} lanes" if idxs is None \
            else f"sweep lane pack (spec indices {list(idxs)})"
        raise _annotate(e, where)
    wall = (time.time() - t0) / len(specs)
    return [Result.from_task_result(spec, tr, wall_s=wall)
            for spec, tr in zip(specs, trs)]


def _run_job(kind: str, specs: List[ExperimentSpec],
             idxs: Optional[List[int]] = None) -> List[Result]:
    if kind == "pack":
        return _run_pack(specs, idxs)
    try:
        return [run_spec(specs[0])]
    except Exception as e:                       # noqa: BLE001
        # same index context as pack-lane failures, on BOTH the pool path
        # and the serial(-fallback) rerun — a failing spec always names
        # its sweep index
        if idxs is not None:
            e.spec_index = idxs[0]
            raise _annotate(e, f"sweep spec index {idxs[0]}")
        raise


def _run_job_safe(kind: str, specs: List[ExperimentSpec],
                  idxs: Optional[List[int]] = None):
    try:
        return ("ok", _run_job(kind, specs, idxs))
    except Exception as e:                       # noqa: BLE001
        return ("err", e)


# ---------------------------------------------------------------------------
# Fault-tolerant execution: timeout / retry / worker death / pack salvage
# ---------------------------------------------------------------------------

@dataclass
class SpecReport:
    """Per-spec accounting of a fault-tolerant sweep.

    ``status``: "ok" (first attempt succeeded), "retried" (succeeded
    after >= 1 failed attempt), "timeout" / "failed" (exhausted
    ``retry_limit``; its ``results`` slot stays None). ``attempts``
    counts every attempt that included this spec (pack or per-spec);
    ``wall_s`` sums its amortized share of each attempt's wall clock;
    ``error`` keeps the last failure's message."""

    index: int
    status: str = "pending"
    attempts: int = 0
    wall_s: float = 0.0
    error: Optional[str] = None


@dataclass
class SweepReport:
    """What a fault-tolerant ``sweep`` did, spec by spec."""

    specs: List[SpecReport] = field(default_factory=list)

    @property
    def all_ok(self) -> bool:
        return all(s.status in ("ok", "retried") for s in self.specs)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for s in self.specs:
            out[s.status] = out.get(s.status, 0) + 1
        return out


class _WorkerDied(RuntimeError):
    """A sweep worker process exited without reporting a result."""


class _WorkerTimeout(RuntimeError):
    """A sweep worker exceeded ``timeout_s`` and was terminated."""


@dataclass
class _FTJob:
    kind: str                 # "pack" | "spec"
    idxs: List[int]
    ready_at: float = 0.0     # monotonic clock gate (retry backoff)


class _FTState:
    """Retry/salvage bookkeeping shared by the process and serial
    fault-tolerant schedulers: turns each job outcome into follow-up
    jobs and keeps the ``SweepReport`` truthful."""

    def __init__(self, n_specs: int, deliver, retry_limit: int,
                 retry_backoff_s: float,
                 on_failure: Optional[FailureCallback]):
        self.reports = [SpecReport(i) for i in range(n_specs)]
        self.deliver = deliver
        self.retry_limit = int(retry_limit)
        self.backoff = float(retry_backoff_s)
        self.on_failure = on_failure

    def start(self, job: _FTJob) -> None:
        for i in job.idxs:
            self.reports[i].attempts += 1

    def finalized(self, i: int) -> bool:
        return self.reports[i].status in ("ok", "retried", "timeout",
                                          "failed")

    def success(self, job: _FTJob, results: List[Result],
                wall: float) -> None:
        per = wall / max(len(job.idxs), 1)
        for i in job.idxs:
            rep = self.reports[i]
            rep.wall_s += per
            rep.status = "ok" if rep.attempts == 1 else "retried"
        self.deliver(job.idxs, results)

    def failure(self, job: _FTJob, error: BaseException, wall: float,
                why: str) -> List[_FTJob]:
        """Record one failed attempt; return the follow-up jobs. ``why``
        is "error" (the job raised), "died" or "timeout"."""
        per = wall / max(len(job.idxs), 1)
        for i in job.idxs:
            rep = self.reports[i]
            rep.wall_s += per
            rep.error = f"{type(error).__name__}: {error}"
            if self.on_failure is not None:
                self.on_failure(i, error, rep.attempts)
        culprit = getattr(error, "spec_index", None) if why == "error" \
            else None
        if job.kind == "pack" and len(job.idxs) > 1:
            if culprit in job.idxs:
                # salvage: the crash names one guilty lane — re-chunk the
                # surviving lanes into a fresh sub-pack (their work died
                # with the worker but their specs are fine) and isolate
                # the culprit under the retry budget
                survivors = [i for i in job.idxs if i != culprit]
                return [_FTJob("pack", survivors)] \
                    + self._retry(_FTJob("spec", [culprit]), why)
            # anonymous death/timeout: isolate every lane per-spec so one
            # bad spec cannot take the pack down again
            out: List[_FTJob] = []
            for i in job.idxs:
                out += self._retry(_FTJob("spec", [i]), why)
            return out
        return self._retry(job, why)

    def _retry(self, job: _FTJob, why: str) -> List[_FTJob]:
        tried = max(self.reports[i].attempts for i in job.idxs)
        if tried > self.retry_limit:
            final = "timeout" if why == "timeout" else "failed"
            for i in job.idxs:
                self.reports[i].status = final
            return []
        job.ready_at = time.monotonic() \
            + self.backoff * (2.0 ** (tried - 1))
        return [job]


def _ft_worker(conn, kind: str, specs: List[ExperimentSpec],
               idxs: List[int]) -> None:
    try:
        out = _run_job(kind, specs, idxs)
        conn.send(("ok", out))
    except BaseException as e:                   # noqa: BLE001
        try:
            conn.send(("err", e))
        except Exception:                        # unpicklable exception
            stub = RuntimeError(f"{type(e).__name__}: {e}")
            stub.spec_index = getattr(e, "spec_index", None)
            conn.send(("err", stub))
    finally:
        conn.close()


def _sweep_ft_pool(jobs: List[_FTJob], specs: List[ExperimentSpec], n: int,
                   st: _FTState, timeout_s: Optional[float]) -> None:
    """Fault-tolerant scheduler: one ``multiprocessing.Process`` + pipe
    per job (not a pool executor — per-job termination is the point).
    Detects three failure shapes: the job raised (error travels back over
    the pipe), the worker died silently (process exit without a result),
    and the worker wedged (``timeout_s`` elapsed; terminated)."""
    import multiprocessing as mp
    ctx = mp.get_context()
    pending = list(jobs)
    running: List[Tuple[_FTJob, object, object, float]] = []
    try:
        while pending or running:
            now = time.monotonic()
            i = 0
            while len(running) < n and i < len(pending):
                job = pending[i]
                if job.ready_at > now:
                    i += 1
                    continue
                pending.pop(i)
                parent, child = ctx.Pipe(duplex=False)
                proc = ctx.Process(
                    target=_ft_worker,
                    args=(child, job.kind, [specs[j] for j in job.idxs],
                          job.idxs),
                    daemon=True)
                st.start(job)
                proc.start()     # _POOL_ERRORS here -> serial fallback
                child.close()
                running.append((job, proc, parent, time.monotonic()))
            progressed = False
            for item in list(running):
                job, proc, conn, t0 = item
                now = time.monotonic()
                status = payload = None
                if conn.poll(0):
                    try:
                        status, payload = conn.recv()
                    except EOFError:
                        status = None            # died mid-send
                elif proc.is_alive():
                    if timeout_s is not None and now - t0 > timeout_s:
                        proc.terminate()
                        proc.join()
                        running.remove(item)
                        conn.close()
                        err = _WorkerTimeout(
                            f"sweep worker exceeded timeout_s="
                            f"{timeout_s} running spec indices "
                            f"{job.idxs}")
                        pending.extend(
                            st.failure(job, err, now - t0, "timeout"))
                        progressed = True
                    continue
                proc.join()
                running.remove(item)
                conn.close()
                wall = time.monotonic() - t0
                if status == "ok":
                    st.success(job, payload, wall)
                elif status == "err":
                    pending.extend(st.failure(job, payload, wall, "error"))
                else:
                    err = _WorkerDied(
                        f"sweep worker died (exit code {proc.exitcode}) "
                        f"running spec indices {job.idxs}")
                    pending.extend(st.failure(job, err, wall, "died"))
                progressed = True
            if not progressed:
                time.sleep(0.005)
    finally:
        for _, proc, conn, _ in running:
            proc.terminate()
            proc.join()
            conn.close()


def _sweep_ft_serial(jobs: List[_FTJob], specs: List[ExperimentSpec],
                     st: _FTState) -> None:
    """In-process fault-tolerant fallback (restricted environments):
    retries with backoff still work; ``timeout_s`` and worker-death
    detection need process isolation and do not apply here."""
    pending = list(jobs)
    while pending:
        now = time.monotonic()
        ready = next((j for j in pending if j.ready_at <= now), None)
        if ready is None:
            time.sleep(max(0.0, min(j.ready_at for j in pending) - now))
            continue
        pending.remove(ready)
        st.start(ready)
        t0 = time.monotonic()
        try:
            rs = _run_job(ready.kind, [specs[i] for i in ready.idxs],
                          ready.idxs)
        except Exception as e:                   # noqa: BLE001
            pending.extend(
                st.failure(ready, e, time.monotonic() - t0, "error"))
        else:
            st.success(ready, rs, time.monotonic() - t0)


# ---------------------------------------------------------------------------
# The sweep entry point
# ---------------------------------------------------------------------------

def sweep(specs: Sequence[ExperimentSpec], workers: Optional[int] = None,
          on_result: Optional[ResultCallback] = None,
          vectorize: bool = False, *,
          timeout_s: Optional[float] = None, retry_limit: int = 0,
          retry_backoff_s: float = 0.5,
          on_failure: Optional[FailureCallback] = None,
          return_report: bool = False):
    """Run every spec; return Results in spec order.

    on_result(index, result) fires in completion order as workers finish
    (or after each run/pack when serial). ``vectorize=True`` lane-batches
    compatible specs into lockstep packs (see module docstring); the
    per-spec path is the degenerate one-spec-per-job case of the same
    machinery.

    Fault tolerance — armed by passing any of ``timeout_s`` /
    ``retry_limit`` / ``on_failure`` / ``return_report``; without them
    the legacy all-or-nothing semantics (first failure propagates) are
    unchanged. In fault-tolerant mode every job runs in its own worker
    process (isolation is the point — a crashing spec cannot take the
    sweep down):

    * ``timeout_s`` — per job (spec or pack): a worker exceeding it is
      terminated and the job handled as a failure;
    * worker death (segfault, ``os._exit``, OOM-kill) is detected via
      the process exit code and handled as a failure;
    * failed jobs retry with exponential backoff
      (``retry_backoff_s * 2**(attempt-1)``) up to ``retry_limit``
      retries per spec;
    * a crashed *pack* whose error names a culprit lane is salvaged:
      surviving lanes re-chunk into a fresh sub-pack, the culprit
      retries alone; an anonymous pack death isolates every lane;
    * exhausted specs leave ``None`` in their results slot (partial
      results instead of all-or-nothing) with ``on_failure(index,
      error, attempt)`` fired once per failed attempt.

    With ``return_report=True`` returns ``(results, SweepReport)`` —
    per-spec status ("ok" / "retried" / "timeout" / "failed"),
    attempts, amortized wall seconds and last error (the schema is the
    :class:`SpecReport` dataclass).
    """
    specs = list(specs)
    fault_tolerant = (timeout_s is not None or retry_limit > 0
                      or on_failure is not None or return_report)
    if not specs:
        return ([], SweepReport()) if return_report else []
    if vectorize:
        jobs = _chunk_packs(_group_packs(specs),
                            _n_workers(len(specs), workers))
    else:
        jobs = [("spec", [i]) for i in range(len(specs))]
    results: List[Optional[Result]] = [None] * len(specs)

    def deliver(idxs: List[int], rs: List[Result]) -> None:
        for i, r in zip(idxs, rs):
            results[i] = r
            if on_result is not None:
                on_result(i, r)

    if fault_tolerant:
        st = _FTState(len(specs), deliver, retry_limit, retry_backoff_s,
                      on_failure)
        ft_jobs = [_FTJob(kind, list(idxs)) for kind, idxs in jobs]
        n = _n_workers(len(ft_jobs), workers)
        try:
            _sweep_ft_pool(ft_jobs, specs, n, st, timeout_s)
        except _POOL_ERRORS as e:
            import warnings
            remaining = [
                _FTJob(j.kind, [i for i in j.idxs if not st.finalized(i)])
                for j in ft_jobs]
            remaining = [j for j in remaining if j.idxs]
            warnings.warn(
                f"sweep: worker processes unavailable ({e!r}); running "
                f"the remaining jobs in-process — timeout_s and "
                f"worker-death detection are disabled, retries still "
                f"apply", RuntimeWarning, stacklevel=2)
            _sweep_ft_serial(remaining, specs, st)
        report = SweepReport(st.reports)
        return (results, report) if return_report else results

    n = _n_workers(len(jobs), workers)
    if n > 1 and len(jobs) > 1:
        try:
            _sweep_pool(jobs, specs, n, deliver)
        except _TaskFailed as tf:
            raise tf.error                # an experiment itself failed
        except _POOL_ERRORS as e:
            # restricted environments (no /dev/shm, no fork / broken pool)
            # fall back to in-process — only for the jobs the pool never
            # finished, so on_result fires exactly once per spec
            import warnings
            pending = [i for i, r in enumerate(results) if r is None]
            warnings.warn(
                f"sweep: process pool unavailable ({e!r}); running the "
                f"remaining {len(pending)}/{len(specs)} specs "
                f"in-process (spec indices {pending})",
                RuntimeWarning, stacklevel=2)
    for kind, idxs in jobs:
        if results[idxs[0]] is None:      # packs deliver all-or-nothing
            deliver(idxs, _run_job(kind, [specs[i] for i in idxs], idxs))
    return results  # type: ignore[return-value]


def _sweep_pool(jobs: List[Tuple[str, List[int]]],
                specs: List[ExperimentSpec], n: int,
                deliver: Callable[[List[int], List[Result]], None]) -> None:
    from concurrent.futures import ProcessPoolExecutor, as_completed
    with ProcessPoolExecutor(max_workers=n) as pool:
        futures = {pool.submit(_run_job_safe, kind,
                               [specs[i] for i in idxs], idxs): idxs
                   for kind, idxs in jobs}
        for fut in as_completed(futures):
            status, payload = fut.result()
            if status == "err":
                raise _TaskFailed(payload)
            deliver(futures[fut], payload)
