"""Declarative experiment descriptions: `ModelRef` + `ExperimentSpec`.

A spec is a frozen, JSON-round-trippable value: model reference (registry
arch id or inline config, plus reduced/override knobs), the federated and
run configs, the `Environment` bundle (including the time-varying
`intensity_schedule` / `intensity_phase_h` grid curves), and the learner
choice. Specs are shareable artifacts — serialize one, hand it to a
colleague (or a CI smoke job), and re-running it with the same seed
reproduces the same `Result.summary()`.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional

from repro.configs.base import (FederatedConfig, ModelConfig, RunConfig,
                                model_config_from_dict, model_config_to_dict,
                                normalize_model_kwargs)
from repro.configs.base import reduced as _reduced
from repro.api.environment import Environment

LEARNERS = ("surrogate", "real")


def _json_canon(d: Optional[Mapping]) -> Optional[dict]:
    """Canonicalize a mapping to its JSON form (tuples -> lists) so that a
    spec built in-process compares equal to itself after a JSON hop."""
    return None if d is None else json.loads(json.dumps(dict(d)))


@dataclass(frozen=True)
class ModelRef:
    """A model-zoo reference (``arch``) or an inline ``config`` dict, plus
    optional `reduced()` shrinking and field overrides, resolved lazily to a
    concrete ModelConfig."""

    arch: str = ""
    config: Optional[Mapping[str, Any]] = None   # inline ModelConfig dict
    reduced: bool = False
    reduced_kw: Mapping[str, int] = field(default_factory=dict)
    overrides: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        assert self.arch or self.config, "ModelRef needs arch or config"
        object.__setattr__(self, "config", _json_canon(self.config))
        object.__setattr__(self, "reduced_kw", _json_canon(self.reduced_kw))
        object.__setattr__(self, "overrides", _json_canon(self.overrides))

    @classmethod
    def from_config(cls, cfg: ModelConfig, **kw) -> "ModelRef":
        return cls(config=model_config_to_dict(cfg), **kw)

    def resolve(self) -> ModelConfig:
        if self.config is not None:
            base = model_config_from_dict(dict(self.config))
        else:
            from repro.configs.registry import get_config  # lazy: heavy dep
            base = get_config(self.arch)
        if self.reduced:
            base = _reduced(base, **dict(self.reduced_kw))
        if self.overrides:
            base = dataclasses.replace(
                base, **normalize_model_kwargs(dict(self.overrides)))
        return base

    def to_dict(self) -> dict:
        out: dict = {}
        if self.arch:
            out["arch"] = self.arch
        if self.config is not None:
            out["config"] = dict(self.config)
        if self.reduced:
            out["reduced"] = True
        if self.reduced_kw:
            out["reduced_kw"] = dict(self.reduced_kw)
        if self.overrides:
            out["overrides"] = dict(self.overrides)
        return out

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ModelRef":
        return cls(**dict(d))


@dataclass(frozen=True)
class ExperimentSpec:
    model: ModelRef = field(default_factory=lambda: ModelRef("paper-charlm"))
    federated: FederatedConfig = field(default_factory=FederatedConfig)
    run: RunConfig = field(default_factory=RunConfig)
    environment: Environment = field(default_factory=Environment)
    learner: str = "surrogate"          # "surrogate" | "real"
    seq_len: int = 64
    max_client_steps: int = 8           # real learner scan length

    def __post_init__(self):
        assert self.learner in LEARNERS, self.learner

    # ----------------------------------------------------------- plumbing
    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        return {
            "model": self.model.to_dict(),
            "federated": dataclasses.asdict(self.federated),
            "run": dataclasses.asdict(self.run),
            "environment": self.environment.to_dict(),
            "learner": self.learner,
            "seq_len": self.seq_len,
            "max_client_steps": self.max_client_steps,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        d = dict(d)
        return cls(
            model=ModelRef.from_dict(d.get("model", {"arch": "paper-charlm"})),
            federated=FederatedConfig(**d.get("federated", {})),
            run=RunConfig(**d.get("run", {})),
            environment=Environment.from_dict(d.get("environment")),
            learner=d.get("learner", "surrogate"),
            seq_len=int(d.get("seq_len", 64)),
            max_client_steps=int(d.get("max_client_steps", 8)),
        )

    def to_json(self, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    def content_hash(self) -> str:
        """Short sha256 of the canonical JSON form — what checkpoint
        headers record, so a resume against a *different* spec (other
        seed, other environment, other budgets) fails loudly instead of
        silently continuing the wrong run."""
        canon = json.dumps(self.to_dict(), sort_keys=True,
                           separators=(",", ":"))
        return hashlib.sha256(canon.encode()).hexdigest()[:16]

    @classmethod
    def from_json(cls, text: str) -> "ExperimentSpec":
        return cls.from_dict(json.loads(text))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_json(f.read())
