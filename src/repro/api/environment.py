"""The `Environment` bundle: every externally-given model the carbon
accounting depends on — network energy-per-bit, grid carbon intensities,
datacenter fleet + PUE, device fleet, participation country mix, and link
bandwidths — as one swappable, JSON-serializable value.

The seed codebase hard-wired all of these as module-level defaults; an
`Environment` threads them explicitly through `SessionSampler` and
`CarbonEstimator`, which is what makes scenarios like geographically
shifted intensity (CAFE) or a device-heterogeneous fleet expressible as
config rather than code forks.

Time is first-class: ``intensity_schedule`` maps countries to
piecewise-constant diurnal gCO2e/kWh curves (equal segments over a 24 h
cycle; ``intensity_phase_h`` carries per-country UTC offsets so the shared
task clock lines up with local solar time). An empty/constant schedule is
the degenerate static case and stays bit-for-bit identical to the plain
table. ``Environment.preset`` ships the named scenario bundles: the
"diurnal" grid and the device-heterogeneous "flagship-only" /
"entry-heavy" fleets.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence, Tuple

from repro.configs.base import FederatedConfig, ModelConfig
from repro.core.availability import AvailabilityModel
from repro.core.carbon import (CARBON_INTENSITY, DATACENTER_LOCATIONS, PUE,
                               UTC_OFFSET_H, IntensityModel,
                               diurnal_schedule)
from repro.core.energy import SERVER_TASK_POWER_W
from repro.core.estimator import CarbonEstimator
from repro.core.faults import FaultModel
from repro.core.network import NetworkEnergyModel
from repro.core.profiles import (COUNTRY_MIX, DOWNLOAD_BPS, FLEET, UPLOAD_BPS,
                                 DeviceProfile)
from repro.federated.events import SessionSampler

_FLAGSHIP_GFLOPS = 5.0    # flagship cut line for the fleet presets
_ENTRY_GFLOPS = 2.0


@dataclass(frozen=True)
class Environment:
    network: NetworkEnergyModel = field(default_factory=NetworkEnergyModel)
    carbon_intensity: Mapping[str, float] = field(
        default_factory=lambda: dict(CARBON_INTENSITY))
    datacenter_locations: Mapping[str, int] = field(
        default_factory=lambda: dict(DATACENTER_LOCATIONS))
    pue: float = PUE
    fleet: Tuple[DeviceProfile, ...] = FLEET
    country_mix: Mapping[str, float] = field(
        default_factory=lambda: dict(COUNTRY_MIX))
    download_bps: float = DOWNLOAD_BPS
    upload_bps: float = UPLOAD_BPS
    server_power_w: float = SERVER_TASK_POWER_W
    # time-varying grid: country -> per-segment gCO2e/kWh over a 24 h
    # cycle (empty = static), country -> phase offset in hours
    intensity_schedule: Mapping[str, Sequence[float]] = field(
        default_factory=dict)
    intensity_phase_h: Mapping[str, float] = field(default_factory=dict)
    # failure process: per-country (time-varying) hazards + correlated
    # burst outages; the all-zero default is the fault-free engine
    fault: FaultModel = field(default_factory=FaultModel)
    # device availability: per-country (time-varying) eligibility curves
    # gating admission + mid-session churn; the all-available default is
    # the availability-blind engine (see repro.core.availability —
    # ``diurnal_availability(countries)`` builds the canonical
    # anti-correlated evening-charging-peak model)
    availability: AvailabilityModel = field(
        default_factory=AvailabilityModel)

    def __post_init__(self):
        if self.download_bps <= 0 or self.upload_bps <= 0:
            raise ValueError(
                "Environment link bandwidths must be > 0, got "
                f"download_bps={self.download_bps!r} "
                f"upload_bps={self.upload_bps!r}")
        if self.pue < 1.0:
            raise ValueError(f"Environment.pue must be >= 1.0 "
                             f"(it multiplies IT power), got {self.pue!r}")
        if self.server_power_w < 0:
            raise ValueError("Environment.server_power_w must be >= 0, "
                             f"got {self.server_power_w!r}")
        if not self.fleet:
            raise ValueError("Environment.fleet must name at least one "
                             "device profile")
        if self.country_mix:
            bad = {c: w for c, w in self.country_mix.items() if w < 0}
            if bad:
                raise ValueError(
                    f"Environment.country_mix has negative weights: {bad}")
            if not sum(self.country_mix.values()) > 0:
                raise ValueError("Environment.country_mix weights must "
                                 "sum to > 0")

    # ------------------------------------------------------------ presets
    @classmethod
    def preset(cls, name: str, **overrides) -> "Environment":
        """Named scenario bundles (further ``Environment`` kwargs may be
        layered on top):

        * ``"diurnal"`` — every country's intensity swings through the
          default diurnal shape (midday solar dip, evening peak) around
          its static mean, phased by UTC offset. The canonical
          time-varying grid for carbon-aware scheduling experiments.
        * ``"flagship-only"`` — the device fleet restricted to flagship
          SoCs (>= ~5 effective GFLOP/s): short sessions, high power.
        * ``"entry-heavy"`` — fleet popularity reweighted toward
          entry-level devices (3x weight under ~2 GFLOP/s, flagships
          halved): long sessions on low-power silicon.
        """
        if name == "diurnal":
            base = dict(intensity_schedule=diurnal_schedule(),
                        intensity_phase_h=dict(UTC_OFFSET_H))
        elif name == "flagship-only":
            base = dict(fleet=tuple(
                p for p in FLEET if p.train_gflops >= _FLAGSHIP_GFLOPS))
        elif name == "entry-heavy":
            base = dict(fleet=tuple(
                dataclasses.replace(
                    p, weight=p.weight * (
                        3.0 if p.train_gflops < _ENTRY_GFLOPS else
                        0.5 if p.train_gflops >= _FLAGSHIP_GFLOPS else 1.0))
                for p in FLEET))
        else:
            raise ValueError(
                f"unknown Environment preset {name!r}; known: "
                "'diurnal', 'flagship-only', 'entry-heavy'")
        base.update(overrides)
        return cls(**base)

    # ------------------------------------------------------------ wiring
    def intensity_model(self) -> IntensityModel:
        return IntensityModel(table=dict(self.carbon_intensity),
                              datacenter_locations=dict(
                                  self.datacenter_locations),
                              pue=self.pue,
                              schedule={c: tuple(v) for c, v in
                                        self.intensity_schedule.items()},
                              phase_h=dict(self.intensity_phase_h))

    def estimator(self) -> CarbonEstimator:
        return CarbonEstimator(network=self.network,
                               profiles={p.name: p for p in self.fleet},
                               intensity=self.intensity_model(),
                               server_power_w=self.server_power_w)

    def sampler(self, model_cfg: ModelConfig, fed: FederatedConfig,
                seq_len: int) -> SessionSampler:
        return SessionSampler(model_cfg, fed, seq_len,
                              fleet=self.fleet,
                              country_mix=self.country_mix,
                              download_bps=self.download_bps,
                              upload_bps=self.upload_bps,
                              fault=self.fault,
                              availability=self.availability)

    # ------------------------------------------------- JSON round-tripping
    def to_dict(self) -> dict:
        out = {
            "network": dataclasses.asdict(self.network),
            "carbon_intensity": dict(self.carbon_intensity),
            "datacenter_locations": dict(self.datacenter_locations),
            "pue": self.pue,
            "fleet": [dataclasses.asdict(p) for p in self.fleet],
            "country_mix": dict(self.country_mix),
            "download_bps": self.download_bps,
            "upload_bps": self.upload_bps,
            "server_power_w": self.server_power_w,
            "intensity_schedule": {c: list(v) for c, v in
                                   self.intensity_schedule.items()},
            "intensity_phase_h": dict(self.intensity_phase_h),
        }
        fd = self.fault.to_dict()
        if fd:                      # default (fault-free) stays implicit
            out["fault"] = fd
        ad = self.availability.to_dict()
        if ad:                      # default (all-available) stays implicit
            out["availability"] = ad
        return out

    @classmethod
    def from_dict(cls, d: Optional[Mapping]) -> "Environment":
        if not d:
            return cls()
        d = dict(d)
        if isinstance(d.get("network"), Mapping):
            d["network"] = NetworkEnergyModel(**d["network"])
        if d.get("fleet") is not None:
            d["fleet"] = tuple(
                p if isinstance(p, DeviceProfile) else DeviceProfile(**p)
                for p in d["fleet"])
        if not isinstance(d.get("fault"), FaultModel):
            d["fault"] = FaultModel.from_dict(d.get("fault"))
        if not isinstance(d.get("availability"), AvailabilityModel):
            d["availability"] = AvailabilityModel.from_dict(
                d.get("availability"))
        return cls(**d)
