"""The `Environment` bundle: every externally-given model the carbon
accounting depends on — network energy-per-bit, grid carbon intensities,
datacenter fleet + PUE, device fleet, participation country mix, and link
bandwidths — as one swappable, JSON-serializable value.

The seed codebase hard-wired all of these as module-level defaults; an
`Environment` threads them explicitly through `SessionSampler` and
`CarbonEstimator`, which is what makes scenarios like geographically
shifted intensity (CAFE) or a device-heterogeneous fleet expressible as
config rather than code forks.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Mapping, Optional, Sequence, Tuple

from repro.configs.base import FederatedConfig, ModelConfig
from repro.core.carbon import (CARBON_INTENSITY, DATACENTER_LOCATIONS, PUE,
                               IntensityModel)
from repro.core.energy import SERVER_TASK_POWER_W
from repro.core.estimator import CarbonEstimator
from repro.core.network import NetworkEnergyModel
from repro.core.profiles import (COUNTRY_MIX, DOWNLOAD_BPS, FLEET, UPLOAD_BPS,
                                 DeviceProfile)
from repro.federated.events import SessionSampler


@dataclass(frozen=True)
class Environment:
    network: NetworkEnergyModel = field(default_factory=NetworkEnergyModel)
    carbon_intensity: Mapping[str, float] = field(
        default_factory=lambda: dict(CARBON_INTENSITY))
    datacenter_locations: Mapping[str, int] = field(
        default_factory=lambda: dict(DATACENTER_LOCATIONS))
    pue: float = PUE
    fleet: Tuple[DeviceProfile, ...] = FLEET
    country_mix: Mapping[str, float] = field(
        default_factory=lambda: dict(COUNTRY_MIX))
    download_bps: float = DOWNLOAD_BPS
    upload_bps: float = UPLOAD_BPS
    server_power_w: float = SERVER_TASK_POWER_W

    # ------------------------------------------------------------ wiring
    def intensity_model(self) -> IntensityModel:
        return IntensityModel(table=dict(self.carbon_intensity),
                              datacenter_locations=dict(
                                  self.datacenter_locations),
                              pue=self.pue)

    def estimator(self) -> CarbonEstimator:
        return CarbonEstimator(network=self.network,
                               profiles={p.name: p for p in self.fleet},
                               intensity=self.intensity_model(),
                               server_power_w=self.server_power_w)

    def sampler(self, model_cfg: ModelConfig, fed: FederatedConfig,
                seq_len: int) -> SessionSampler:
        return SessionSampler(model_cfg, fed, seq_len,
                              fleet=self.fleet,
                              country_mix=self.country_mix,
                              download_bps=self.download_bps,
                              upload_bps=self.upload_bps)

    # ------------------------------------------------- JSON round-tripping
    def to_dict(self) -> dict:
        return {
            "network": dataclasses.asdict(self.network),
            "carbon_intensity": dict(self.carbon_intensity),
            "datacenter_locations": dict(self.datacenter_locations),
            "pue": self.pue,
            "fleet": [dataclasses.asdict(p) for p in self.fleet],
            "country_mix": dict(self.country_mix),
            "download_bps": self.download_bps,
            "upload_bps": self.upload_bps,
            "server_power_w": self.server_power_w,
        }

    @classmethod
    def from_dict(cls, d: Optional[Mapping]) -> "Environment":
        if not d:
            return cls()
        d = dict(d)
        if isinstance(d.get("network"), Mapping):
            d["network"] = NetworkEnergyModel(**d["network"])
        if d.get("fleet") is not None:
            d["fleet"] = tuple(
                p if isinstance(p, DeviceProfile) else DeviceProfile(**p)
                for p in d["fleet"])
        return cls(**d)
