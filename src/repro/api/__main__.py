"""Run an ExperimentSpec JSON from the command line.

  PYTHONPATH=src python -m repro.api examples/specs/charlm_sync_small.json
  PYTHONPATH=src python -m repro.api spec.json --roundtrip-check --out r.json

--roundtrip-check re-serializes the loaded spec, reloads it and re-runs,
asserting both runs produce an identical Result.summary() — the
reproducibility contract CI smoke relies on.
"""
from __future__ import annotations

import argparse
import json

from repro.api import Experiment, ExperimentSpec


def main(argv=None) -> int:
    p = argparse.ArgumentParser(prog="repro.api")
    p.add_argument("spec", help="path to an ExperimentSpec JSON file")
    p.add_argument("--out", default="", help="write Result.to_dict() JSON")
    p.add_argument("--roundtrip-check", action="store_true",
                   help="serialize->reload->rerun and compare summaries")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)

    spec = ExperimentSpec.load(args.spec)
    on_round = None
    if not args.quiet:
        on_round = lambda ev: print(  # noqa: E731
            f"[api] round {ev.round_idx:5d} t={ev.t_s/3600.0:7.2f}h "
            f"ppl={ev.perplexity:8.1f} sessions={ev.n_sessions}")
    res = Experiment(spec).run(on_round=on_round)
    s = res.summary()
    print(f"[api] {spec.federated.mode} rounds={s['rounds']:.0f} "
          f"ppl={s['perplexity']:.1f} duration={s['duration_h']:.2f}h "
          f"carbon={s['carbon_total_kg']*1000:.2f} gCO2e "
          f"sessions={s['sessions']:.0f} (wall {res.wall_s:.1f}s)")

    if args.roundtrip_check:
        respec = ExperimentSpec.from_json(spec.to_json())
        s2 = Experiment(respec).run().summary()
        assert s == s2, f"round-trip mismatch:\n{s}\n{s2}"
        print("[api] roundtrip-check OK: reloaded spec reproduced the "
              "identical summary")

    if args.out:
        with open(args.out, "w") as f:
            json.dump(res.to_dict(), f, indent=1)
        print(f"[api] result -> {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
