from repro.models.registry import (attention_flops, get_model, param_count,
                                   param_shapes_and_axes, step_bytes_min,
                                   step_flops)
