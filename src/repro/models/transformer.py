"""Decoder-only transformer covering the dense / MoE / VLM families.

Dense:  mistral-nemo-12b, smollm-135m, stablelm-3b, stablelm-1.6b
MoE:    mixtral-8x22b (SWA), granite-moe-1b-a400m
VLM:    internvl2-2b (precomputed patch embeddings prepended — frontend stub)

Pre-norm RMSNorm blocks, RoPE GQA attention (full or sliding-window),
SwiGLU FFN or capacity-based top-k MoE. Layer stack runs under lax.scan.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import sharding as _sh
from repro.configs.base import ModelConfig
from repro.models import common as cm


class DecoderLM:
    def __init__(self, cfg: ModelConfig, *, decode_window: int = 0,
                 remat: bool = False, serve_replicated_ffn: bool = False):
        """decode_window > 0 enables the sliding-window ring-buffer decode
        variant (used for long_500k on otherwise full-attention archs).
        remat recomputes each layer in the backward pass (train shapes)."""
        self.cfg = cfg
        self.decode_window = decode_window or cfg.sliding_window
        self.is_moe = cfg.moe is not None
        self.remat = remat
        # GShard-style expert capacity for train/prefill (documented
        # deviation from Mixtral's dropless routing — DESIGN.md §4);
        # decode runs dropless (capacity = tokens x top_k).
        self.capacity_factor = 1.25
        # §Perf H1.3: replicate (tiny) decode activations across the data
        # axis for the FFN/unembed segment so 2D-resident weights are
        # matmul'd locally (partial-sum all-reduce) instead of gathered.
        self.serve_replicated_ffn = serve_replicated_ffn
        # §Perf H1.4: explicit shard_map flash-decoding (cache sharded along
        # its length over "model"; (B,H)-sized combine collectives).
        self.flash_decode = False
        # §Perf H1.6 (experimental): int8 KV cache (per-token symmetric
        # scales) — 2.2x less cache HBM; requires flash_decode.
        self.kv_quant = False

    # ---------------------------------------------------------------- init
    def init(self, rng, dtype=jnp.float32) -> Tuple[cm.Params, cm.Axes]:
        cfg = self.cfg
        b = cm.ParamBuilder(rng, dtype)
        d, hd = cfg.d_model, cfg.resolved_head_dim
        H, Hkv, L = cfg.num_heads, cfg.num_kv_heads, cfg.num_layers
        b.param("embed", (cfg.vocab_size, d), ("vocab", "embed"),
                scale=1.0 / math.sqrt(d))
        if not cfg.tie_embeddings:
            b.param("unembed", (d, cfg.vocab_size), ("embed", "vocab"))
        b.param("final_norm", (d,), ("embed",), init="ones")
        # stacked per-layer params
        b.param("blocks/attn_norm", (L, d), ("layers", "embed"), init="ones")
        b.param("blocks/wq", (L, d, H, hd), ("layers", "embed", "heads", "head_dim"))
        b.param("blocks/wk", (L, d, Hkv, hd), ("layers", "embed", "kv_heads", "head_dim"))
        b.param("blocks/wv", (L, d, Hkv, hd), ("layers", "embed", "kv_heads", "head_dim"))
        b.param("blocks/wo", (L, H, hd, d), ("layers", "heads", "head_dim", "embed"),
                scale=1.0 / math.sqrt(H * hd))
        b.param("blocks/ffn_norm", (L, d), ("layers", "embed"), init="ones")
        if self.is_moe:
            E, f = cfg.moe.num_experts, cfg.d_ff
            b.param("blocks/router", (L, d, E), ("layers", "embed", "experts"))
            b.param("blocks/w_gate", (L, E, d, f), ("layers", "experts", "embed", "ffn"))
            b.param("blocks/w_up", (L, E, d, f), ("layers", "experts", "embed", "ffn"))
            b.param("blocks/w_down", (L, E, f, d), ("layers", "experts", "ffn", "embed"))
        else:
            f = cfg.d_ff
            b.param("blocks/w_gate", (L, d, f), ("layers", "embed", "ffn"))
            b.param("blocks/w_up", (L, d, f), ("layers", "embed", "ffn"))
            b.param("blocks/w_down", (L, f, d), ("layers", "ffn", "embed"))
        return b.build()

    # ------------------------------------------------------------- forward
    def _layer(self, lp: Dict[str, jnp.ndarray], x: jnp.ndarray,
               positions_offset: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """One block on (B, S, d). Returns (x_out, k, v) (k/v for cache)."""
        cfg = self.cfg
        B, S, d = x.shape
        h = cm.rms_norm(x, lp["attn_norm"])
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        pos = positions_offset + jnp.arange(S)
        cos, sin = cm.rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
        q = cm.apply_rope(q, cos, sin)
        k = cm.apply_rope(k, cos, sin)
        attn = cm.flash_attention(q, k, v, causal=True,
                                  window=cfg.sliding_window,
                                  block_q=min(512, S), block_kv=min(512, S))
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["wo"])

        h = cm.rms_norm(x, lp["ffn_norm"])
        if self.is_moe:
            out, aux = cm.moe_block(
                h.reshape(B * S, d), lp["router"], lp["w_gate"], lp["w_up"],
                lp["w_down"], top_k=cfg.moe.top_k,
                capacity_factor=self.capacity_factor)
            x = x + out.reshape(B, S, d)
            return x, (k, v), aux
        x = x + cm.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
        return x, (k, v), jnp.zeros((), jnp.float32)

    def _stack(self, params: cm.Params, x: jnp.ndarray,
               positions_offset: int = 0, collect_kv: bool = True):
        """Scan the layer stack; returns (x, stacked (k, v), aux_sum).
        collect_kv=False (train path) drops the per-layer KV scan outputs —
        they are only needed to build a prefill cache and would otherwise
        dominate activation memory under autodiff."""
        blocks = {k.split("/", 1)[1]: v for k, v in params.items()
                  if k.startswith("blocks/")}

        def body(x, lp):
            x, kv, aux = self._layer(lp, x, positions_offset)
            x = _sh.constrain_batch(x)
            return x, ((kv if collect_kv else None), aux)

        if self.remat:
            body = jax.checkpoint(body)
        x, (kvs, auxs) = lax.scan(body, x, blocks)
        return x, kvs, jnp.sum(auxs)

    def _embed(self, params, tokens, frontend=None):
        x = _sh.constrain_batch(params["embed"][tokens])
        if self.cfg.num_frontend_tokens and frontend is not None:
            x = jnp.concatenate([frontend.astype(x.dtype), x], axis=1)
        return x

    def logits(self, params, x):
        x = cm.rms_norm(x, params["final_norm"])
        w = params["embed"].T if self.cfg.tie_embeddings else params["unembed"]
        return jnp.einsum("bsd,dv->bsv", x, w)

    # ----------------------------------------------------------- train api
    def loss(self, params: cm.Params, batch: Dict[str, jnp.ndarray]
             ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        tokens = batch["tokens"]
        x = self._embed(params, tokens, batch.get("frontend"))
        x, _, aux = self._stack(params, x, collect_kv=False)
        nf = self.cfg.num_frontend_tokens if "frontend" in batch else 0
        x = cm.rms_norm(x[:, nf:], params["final_norm"])
        w = params["embed"].T if self.cfg.tie_embeddings else params["unembed"]
        loss = cm.lm_loss(x, w, batch["labels"], batch.get("mask", None))
        total = loss
        if self.is_moe:
            total = loss + self.cfg.moe.router_aux_weight * aux
        return total, {"xent": loss, "aux": aux}

    # ----------------------------------------------------------- serve api
    def init_cache(self, B: int, cache_len: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        C = min(cache_len, self.decode_window) if self.decode_window else cache_len
        shape = (cfg.num_layers, B, C, cfg.num_kv_heads, cfg.resolved_head_dim)
        axes = ("layers", "batch", "cache", "kv_heads", "head_dim")
        if self.kv_quant:
            cache = {
                "k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.ones(shape[:-1], jnp.float32),
                "v_scale": jnp.ones(shape[:-1], jnp.float32),
                "pos": jnp.zeros((), jnp.int32),
            }
            cache_axes = {"k": axes, "v": axes, "k_scale": axes[:-1],
                          "v_scale": axes[:-1], "pos": ()}
            return cache, cache_axes
        cache = {
            "k": jnp.zeros(shape, dtype),
            "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
        cache_axes = {"k": axes, "v": axes, "pos": ()}
        return cache, cache_axes

    def prefill(self, params, tokens, frontend=None, pad_to: int = 0):
        """Run the prompt; return (last-position logits, cache).
        pad_to > prompt length reserves cache slots for decode_step."""
        x = self._embed(params, tokens, frontend)
        x, (ks, vs), _ = self._stack(params, x)
        lg = self.logits(params, x[:, -1:, :])[:, 0]
        C = x.shape[1]
        if self.decode_window and C > self.decode_window:
            ks = ks[:, :, -self.decode_window:]
            vs = vs[:, :, -self.decode_window:]
            C = self.decode_window
        if pad_to > C:
            pad = [(0, 0), (0, 0), (0, pad_to - C), (0, 0), (0, 0)]
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        cache = {"k": ks, "v": vs, "pos": jnp.asarray(x.shape[1], jnp.int32)}
        return lg, cache

    def decode_step(self, params, cache, tokens: jnp.ndarray):
        """tokens: (B,) int32. One autoregressive step."""
        cfg = self.cfg
        x = params["embed"][tokens][:, None, :]          # (B, 1, d)
        pos = cache["pos"]
        C = cache["k"].shape[2]
        # ring buffer for SWA variants; append (cache pre-sized) otherwise
        write_idx = pos % C if self.decode_window else jnp.minimum(pos, C - 1)
        blocks = {k.split("/", 1)[1]: v for k, v in params.items()
                  if k.startswith("blocks/")}
        if self.kv_quant:
            return self._decode_step_q8(params, cache, tokens, blocks)

        def body(x, per_layer):
            lp, kc, vc = per_layer
            h = cm.rms_norm(x, lp["attn_norm"])
            q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
            k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
            cos, sin = cm.rope_angles(pos[None], cfg.resolved_head_dim,
                                      cfg.rope_theta)
            q = cm.apply_rope(q, cos[None], sin[None])
            k = cm.apply_rope(k, cos[None], sin[None])
            valid = jnp.minimum(pos + 1, C)
            if self.flash_decode:
                attn, kc, vc = cm.flash_decode_attention(
                    q[:, 0], kc, vc, k[:, 0], v[:, 0], write_idx, valid)
            else:
                kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), write_idx, axis=1)
                vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), write_idx, axis=1)
                kc = _sh.constrain_batch(kc)
                vc = _sh.constrain_batch(vc)
                attn = cm.decode_attention(q[:, 0], kc, vc, valid)
            x = x + jnp.einsum("bhk,hkd->bd", attn, lp["wo"])[:, None, :]
            h = cm.rms_norm(x, lp["ffn_norm"])
            if self.serve_replicated_ffn:
                h = _sh.constrain_replicated(h)
            if self.is_moe:
                out, _ = cm.moe_block(h[:, 0], lp["router"], lp["w_gate"],
                                      lp["w_up"], lp["w_down"],
                                      top_k=cfg.moe.top_k,
                                      capacity_factor=float(cfg.moe.num_experts))
                x = x + out[:, None, :]
            else:
                x = x + cm.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
            return x, (kc, vc)

        x, (ks, vs) = lax.scan(body, x, (blocks, cache["k"], cache["v"]))
        lg = self.logits(params, x)[:, 0]
        new_cache = {"k": ks, "v": vs, "pos": pos + 1}
        return lg, new_cache

    def _decode_step_q8(self, params, cache, tokens, blocks):
        """int8-KV flash-decode step (§Perf H1.6)."""
        cfg = self.cfg
        x = params["embed"][tokens][:, None, :]
        pos = cache["pos"]
        C = cache["k"].shape[2]
        write_idx = pos % C if self.decode_window else jnp.minimum(pos, C - 1)

        def body(x, per_layer):
            lp, kc, vc, ks_, vs_ = per_layer
            h = cm.rms_norm(x, lp["attn_norm"])
            q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
            k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
            cos, sin = cm.rope_angles(pos[None], cfg.resolved_head_dim,
                                      cfg.rope_theta)
            q = cm.apply_rope(q, cos[None], sin[None])
            k = cm.apply_rope(k, cos[None], sin[None])
            valid = jnp.minimum(pos + 1, C)
            attn, kc, vc, ks_, vs_ = cm.flash_decode_attention_q8(
                q[:, 0], kc, vc, ks_, vs_, k[:, 0], v[:, 0], write_idx, valid)
            x = x + jnp.einsum("bhk,hkd->bd", attn, lp["wo"])[:, None, :]
            h = cm.rms_norm(x, lp["ffn_norm"])
            if self.serve_replicated_ffn:
                h = _sh.constrain_replicated(h)
            if self.is_moe:
                out, _ = cm.moe_block(h[:, 0], lp["router"], lp["w_gate"],
                                      lp["w_up"], lp["w_down"],
                                      top_k=cfg.moe.top_k,
                                      capacity_factor=float(cfg.moe.num_experts))
                x = x + out[:, None, :]
            else:
                x = x + cm.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
            return x, (kc, vc, ks_, vs_)

        x, (ks, vs, kss, vss) = lax.scan(
            body, x, (blocks, cache["k"], cache["v"],
                      cache["k_scale"], cache["v_scale"]))
        lg = self.logits(params, x)[:, 0]
        return lg, {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss,
                    "pos": pos + 1}
