"""Shared functional layer library for the model zoo.

Conventions
-----------
* Params are FLAT dicts: ``{"path/to/weight": jnp.ndarray}``. A parallel
  dict of *logical axes* (tuple of axis names per dim) is built at init time
  and consumed by ``repro.sharding`` to derive PartitionSpecs.
* Per-layer parameters are STACKED on a leading ``"layers"`` axis and the
  layer stack runs under ``lax.scan`` (small HLO, fast compiles).
* Attention uses blocked online-softmax ("flash") formulations so that
  prefill at 32k–500k never materializes an (S, S) score matrix. The blocked
  schedule is a scan over (q_block, kv_block) pairs; causal / sliding-window
  variants simply enumerate different pair lists (exact-FLOPs banded
  schedule — see kernels/swa_attention for the TPU Pallas twin).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import sharding as _sh

Params = Dict[str, jnp.ndarray]
Axes = Dict[str, Tuple[Optional[str], ...]]


# ---------------------------------------------------------------------------
# Param construction
# ---------------------------------------------------------------------------

class ParamBuilder:
    """Collects params + their logical sharding axes."""

    def __init__(self, rng: jax.Array, dtype=jnp.float32):
        self.rng = rng
        self.dtype = dtype
        self.params: Params = {}
        self.axes: Axes = {}

    def _next(self) -> jax.Array:
        self.rng, sub = jax.random.split(self.rng)
        return sub

    def param(self, path: str, shape: Sequence[int],
              axes: Sequence[Optional[str]], init: str = "normal",
              scale: Optional[float] = None) -> None:
        assert len(shape) == len(axes), (path, shape, axes)
        assert path not in self.params, path
        shape = tuple(int(s) for s in shape)
        if init == "normal":
            if scale is None:
                scale = 1.0 / math.sqrt(shape[-2] if len(shape) >= 2 else shape[-1])
            w = jax.random.normal(self._next(), shape, self.dtype) * scale
        elif init == "zeros":
            w = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            w = jnp.ones(shape, self.dtype)
        elif init == "uniform":
            w = jax.random.uniform(self._next(), shape, self.dtype,
                                   -(scale or 1.0), scale or 1.0)
        else:
            raise ValueError(init)
        self.params[path] = w
        self.axes[path] = tuple(axes)

    def build(self) -> Tuple[Params, Axes]:
        return self.params, self.axes


def stack_layer_params(per_layer: List[Params]) -> Params:
    """Stack identical per-layer param dicts on a leading 'layers' axis."""
    out = {}
    for k in per_layer[0]:
        out[k] = jnp.stack([p[k] for p in per_layer], axis=0)
    return out


# ---------------------------------------------------------------------------
# Norms / activations / rope
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(jnp.square(x), axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def swish(x):
    return x * jax.nn.sigmoid(x)


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, H, D); cos/sin: (..., S, D//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # insert head dim
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Blocked flash attention (pure JAX oracle; Pallas twin in kernels/)
# ---------------------------------------------------------------------------

def _block_pairs(n_q: int, n_kv: int, window_blocks: Optional[int]) -> List[Tuple[int, int]]:
    """Lower-triangle (banded, if windowed) (q_block, kv_block) schedule."""
    pairs = []
    for qi in range(n_q):
        lo = 0 if window_blocks is None else max(0, qi - window_blocks)
        for ki in range(lo, min(qi + 1, n_kv)):
            pairs.append((qi, ki))
    return pairs


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    *, causal: bool = True, window: int = 0,
                    block_q: int = 512, block_kv: int = 512,
                    positions_offset: int = 0) -> jnp.ndarray:
    """Blocked online-softmax attention with exact banded FLOPs.

    q: (B, S, Hq, D);  k, v: (B, S, Hkv, D)  (GQA: Hq % Hkv == 0).
    window > 0 => sliding-window causal attention of that width.
    Returns (B, S, Hq, D).
    """
    B, S, Hq, D = q.shape
    Skv = k.shape[1]
    Hkv = k.shape[2]
    assert Hq % Hkv == 0
    group = Hq // Hkv
    block_q = min(block_q, S)
    block_kv = min(block_kv, Skv)
    while S % block_q:            # e.g. VLM S = text + 256 visual tokens
        block_q //= 2
    while Skv % block_kv:
        block_kv //= 2
    assert block_q >= 1 and block_kv >= 1
    n_q, n_kv = S // block_q, Skv // block_kv
    wb = None
    if window:
        wb = max(1, math.ceil(window / block_kv))
    pairs = _block_pairs(n_q, n_kv, wb) if causal else \
        [(qi, ki) for qi in range(n_q) for ki in range(n_kv)]
    pair_arr = jnp.asarray(pairs, dtype=jnp.int32)  # (P, 2)

    scale = 1.0 / math.sqrt(D)
    # layout: (B, Hkv, group, n_q, block_q, D)
    qr = q.reshape(B, n_q, block_q, Hkv, group, D).transpose(0, 3, 4, 1, 2, 5)
    kr = k.reshape(B, n_kv, block_kv, Hkv, D).transpose(0, 3, 1, 2, 4)
    vr = v.reshape(B, n_kv, block_kv, Hkv, D).transpose(0, 3, 1, 2, 4)

    o = jnp.zeros_like(qr, dtype=jnp.float32)
    m = jnp.full(qr.shape[:-1], -jnp.inf, dtype=jnp.float32)
    l = jnp.zeros(qr.shape[:-1], dtype=jnp.float32)

    q_pos = positions_offset + jnp.arange(S).reshape(n_q, block_q)
    k_pos = jnp.arange(Skv).reshape(n_kv, block_kv)

    def step(carry, pair):
        o, m, l = carry
        qi, ki = pair[0], pair[1]
        qb = lax.dynamic_index_in_dim(qr, qi, axis=3, keepdims=False)   # (B,Hkv,g,bq,D)
        kb = lax.dynamic_index_in_dim(kr, ki, axis=2, keepdims=False)   # (B,Hkv,bk,D)
        vb = lax.dynamic_index_in_dim(vr, ki, axis=2, keepdims=False)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qb.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        qp = lax.dynamic_index_in_dim(q_pos, qi, axis=0, keepdims=False)  # (bq,)
        kp = lax.dynamic_index_in_dim(k_pos, ki, axis=0, keepdims=False)  # (bk,)
        if causal:
            mask = kp[None, :] <= qp[:, None]
        else:
            mask = jnp.ones((qp.shape[0], kp.shape[0]), bool)
        if window:
            mask &= kp[None, :] > (qp[:, None] - window)
        s = jnp.where(mask, s, -jnp.inf)
        mb = lax.dynamic_index_in_dim(m, qi, axis=3, keepdims=False)
        lb = lax.dynamic_index_in_dim(l, qi, axis=3, keepdims=False)
        ob = lax.dynamic_index_in_dim(o, qi, axis=3, keepdims=False)
        m_new = jnp.maximum(mb, jnp.max(s, axis=-1))
        # guard fully-masked rows (only possible in ragged windows)
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isfinite(mb), jnp.exp(mb - m_safe), 0.0)
        l_new = lb * corr + jnp.sum(p, axis=-1)
        o_new = ob * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        o = lax.dynamic_update_index_in_dim(o, o_new, qi, axis=3)
        m = lax.dynamic_update_index_in_dim(m, m_new, qi, axis=3)
        l = lax.dynamic_update_index_in_dim(l, l_new, qi, axis=3)
        return (o, m, l), None

    # checkpoint: backward recomputes s/p per block instead of stacking
    # (P, B, H, g, bq, bk) f32 residuals — the flash-attention bwd scheme.
    (o, m, l), _ = lax.scan(jax.checkpoint(step), (o, m, l), pair_arr)
    o = o / jnp.maximum(l[..., None], 1e-30)
    out = o.transpose(0, 3, 4, 1, 2, 5).reshape(B, S, Hq, D)
    return out.astype(q.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     valid_len) -> jnp.ndarray:
    """Single-position GQA attention against a KV cache.

    q: (B, Hq, D); k_cache/v_cache: (B, C, Hkv, D); valid_len: () or (B,)
    int32 — number of valid cache slots (ring buffers pass capacity).
    Returns (B, Hq, D). Pure-jnp oracle; Pallas twin in kernels/decode_attention.
    """
    B, C, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    group = Hq // Hkv
    qr = q.reshape(B, Hkv, group, D)
    # keep the cache in its storage dtype; accumulate in f32 via
    # preferred_element_type so XLA cannot hoist an f32 copy of the whole
    # stacked cache out of the layer scan (a 2x HBM + collective blowup).
    s = jnp.einsum("bhgd,bchd->bhgc", qr, k_cache,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    idx = jnp.arange(C)
    vl = jnp.asarray(valid_len)
    mask = idx[None, :] < (vl[:, None] if vl.ndim else vl[None, None])
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bchd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, D).astype(q.dtype)


def quantize_kv(x, axis: int = -1):
    """Symmetric per-token-per-head int8 KV quantization.
    x: (..., D) -> (q int8 same shape, scale f32 shape[:-1])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    qv = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                  -127, 127).astype(jnp.int8)
    return qv, scale


def dequantize_kv(qv, scale):
    return qv.astype(jnp.float32) * scale[..., None]


def flash_decode_attention_q8(q, k_cache, v_cache, k_scale, v_scale,
                              k_new, v_new, write_pos, valid_len):
    """§Perf H1.6 (experimental): flash-decoding over an int8 KV cache.
    Caches: int8 (B,C,Hkv,D) + f32 scales (B,C,Hkv) — 2.2x less cache HBM
    than bf16 (incl. scales at D=128). Numerics: per-token symmetric int8;
    max |error| on attention outputs bounded by the softmax-weighted
    per-token quantization error (tested vs the bf16 path)."""
    from repro import sharding as _sh2
    mesh = _sh2.current_mesh()
    B, C, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    g = Hq // Hkv
    kq_new, ks_new = quantize_kv(k_new)
    vq_new, vs_new = quantize_kv(v_new)

    def _plain():
        kc = lax.dynamic_update_slice_in_dim(k_cache, kq_new[:, None],
                                             write_pos, axis=1)
        vc = lax.dynamic_update_slice_in_dim(v_cache, vq_new[:, None],
                                             write_pos, axis=1)
        ks = lax.dynamic_update_slice_in_dim(k_scale, ks_new[:, None],
                                             write_pos, axis=1)
        vs = lax.dynamic_update_slice_in_dim(v_scale, vs_new[:, None],
                                             write_pos, axis=1)
        o = decode_attention(q,
                             dequantize_kv(kc, ks).astype(q.dtype),
                             dequantize_kv(vc, vs).astype(q.dtype), valid_len)
        return o, kc, vc, ks, vs

    if mesh is None or "model" not in mesh.shape or C % mesh.shape["model"]:
        return _plain()
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    n = mesh.shape["model"]
    C_loc = C // n
    bt = None
    for cand in (("pod", "data"), ("data",)):
        if all(a in mesh.shape for a in cand):
            sz = 1
            for a in cand:
                sz *= mesh.shape[a]
            if B % sz == 0:
                bt = cand if len(cand) > 1 else cand[0]
                break

    def inner(q, kc, vc, ks, vs, kn, vn, ksn, vsn, wp, vl):
        wp, vl = wp[0], vl[0]
        ax = lax.axis_index("model")
        start = ax * C_loc
        li = jnp.clip(wp - start, 0, C_loc - 1)
        in_rng = (wp >= start) & (wp < start + C_loc)

        def upd(buf, new):
            b2 = lax.dynamic_update_slice_in_dim(
                buf, new[:, None].astype(buf.dtype), li, axis=1)
            return jnp.where(in_rng, b2, buf)

        kc, vc, ks, vs = upd(kc, kn), upd(vc, vn), upd(ks, ksn), upd(vs, vsn)
        kf = (kc.astype(jnp.float32) * ks[..., None]).astype(q.dtype)
        vf = (vc.astype(jnp.float32) * vs[..., None]).astype(q.dtype)
        qr = q.reshape(q.shape[0], Hkv, g, D)
        sc = jnp.einsum("bhgd,bchd->bhgc", qr, kf,
                        preferred_element_type=jnp.float32) / math.sqrt(D)
        gpos = start + jnp.arange(C_loc)
        mask = gpos[None, None, None, :] < vl
        sc = jnp.where(mask, sc, -jnp.inf)
        m = jnp.max(sc, axis=-1)
        m_g = lax.pmax(m, "model")
        m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        pr = jnp.where(mask, jnp.exp(sc - m_safe[..., None]), 0.0)
        l = jnp.sum(pr, axis=-1)
        l_g = lax.psum(l, "model")
        o = jnp.einsum("bhgc,bchd->bhgd", pr.astype(vf.dtype), vf,
                       preferred_element_type=jnp.float32)
        o_g = lax.psum(o, "model") / jnp.maximum(l_g, 1e-30)[..., None]
        return (o_g.reshape(q.shape[0], Hq, D).astype(q.dtype),
                kc, vc, ks, vs)

    cspec = P(bt, "model", None, None)
    sspec = P(bt, "model", None)
    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(P(bt, None, None), cspec, cspec, sspec, sspec,
                  P(bt, None, None), P(bt, None, None),
                  P(bt, None), P(bt, None), P(None), P(None)),
        out_specs=(P(bt, None, None), cspec, cspec, sspec, sspec),
        check_rep=False)
    wp = jnp.asarray(write_pos, jnp.int32).reshape(1)
    vl = jnp.asarray(valid_len, jnp.int32).reshape(1)
    return fn(q, k_cache, v_cache, k_scale, v_scale,
              kq_new, vq_new, ks_new, vs_new, wp, vl)


def flash_decode_attention(q, k_cache, v_cache, k_new, v_new, write_pos,
                           valid_len):
    """Distributed flash-decoding with an explicit collective schedule.

    The KV cache is sharded along its LENGTH over the "model" mesh axis
    (batch over "data"); each shard appends the new token locally iff the
    write position falls in its range, computes a local online-softmax over
    its cache chunk, and the shards combine with (B, H)-sized pmax/psum —
    ~2 MB/layer of collectives instead of GSPMD's cache gathers (§Perf H1).

    q: (B, Hq, D); caches: (B, C, Hkv, D); k_new/v_new: (B, Hkv, D);
    write_pos, valid_len: scalars. Returns (o, kc_updated, vc_updated).
    Falls back to the dense path outside a mesh context.
    """
    from repro import sharding as _sh2
    mesh = _sh2.current_mesh()

    def _plain():
        kc = lax.dynamic_update_slice_in_dim(
            k_cache, k_new[:, None].astype(k_cache.dtype), write_pos, axis=1)
        vc = lax.dynamic_update_slice_in_dim(
            v_cache, v_new[:, None].astype(v_cache.dtype), write_pos, axis=1)
        return decode_attention(q, kc, vc, valid_len), kc, vc

    if mesh is None or "model" not in mesh.shape:
        return _plain()
    B, C, Hkv, D = k_cache.shape
    n = mesh.shape["model"]
    if C % n != 0:
        return _plain()
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    Hq = q.shape[1]
    g = Hq // Hkv
    C_loc = C // n
    bt = None
    for cand in (("pod", "data"), ("data",)):
        if all(a in mesh.shape for a in cand):
            sz = 1
            for a in cand:
                sz *= mesh.shape[a]
            if B % sz == 0:
                bt = cand if len(cand) > 1 else cand[0]
                break

    def inner(q, kc, vc, kn, vn, wp, vl):
        wp, vl = wp[0], vl[0]
        ax = lax.axis_index("model")
        start = ax * C_loc
        li = jnp.clip(wp - start, 0, C_loc - 1)
        in_rng = (wp >= start) & (wp < start + C_loc)
        kc2 = lax.dynamic_update_slice_in_dim(
            kc, kn[:, None].astype(kc.dtype), li, axis=1)
        vc2 = lax.dynamic_update_slice_in_dim(
            vc, vn[:, None].astype(vc.dtype), li, axis=1)
        kc = jnp.where(in_rng, kc2, kc)
        vc = jnp.where(in_rng, vc2, vc)
        qr = q.reshape(q.shape[0], Hkv, g, D)
        s = jnp.einsum("bhgd,bchd->bhgc", qr, kc,
                       preferred_element_type=jnp.float32) / math.sqrt(D)
        gpos = start + jnp.arange(C_loc)
        mask = gpos[None, None, None, :] < vl
        s = jnp.where(mask, s, -jnp.inf)
        m = jnp.max(s, axis=-1)
        m_g = lax.pmax(m, "model")
        m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
        p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
        l = jnp.sum(p, axis=-1)
        l_g = lax.psum(l, "model")
        o = jnp.einsum("bhgc,bchd->bhgd", p.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
        o_g = lax.psum(o, "model") / jnp.maximum(l_g, 1e-30)[..., None]
        return o_g.reshape(q.shape[0], Hq, D).astype(q.dtype), kc, vc

    cache_spec = P(bt, "model", None, None)
    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(P(bt, None, None), cache_spec, cache_spec,
                  P(bt, None, None), P(bt, None, None), P(None), P(None)),
        out_specs=(P(bt, None, None), cache_spec, cache_spec),
        check_rep=False)
    wp = jnp.asarray(write_pos, jnp.int32).reshape(1)
    vl = jnp.asarray(valid_len, jnp.int32).reshape(1)
    return fn(q, k_cache, v_cache, k_new, v_new, wp, vl)


# ---------------------------------------------------------------------------
# FFN blocks
# ---------------------------------------------------------------------------

def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    h = swish(x @ w_gate) * (x @ w_up)
    return h @ w_down


def moe_block(x: jnp.ndarray, router_w, w_gate, w_up, w_down, *,
              top_k: int, capacity_factor: float = 1.25
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based top-k MoE with SORT-BASED dispatch.

    x: (T, d). Expert weights: (E, d, f) / (E, f, d). Returns (out, aux_loss).

    Dispatch/combine are pure row GATHERS over a stable argsort of the
    (token, slot) -> expert assignment — no scatters. The scatter-based
    GShard formulation made XLA materialize (T*k, d)-wide u32 index maps
    (~10 GiB/device on granite train_4k; §Perf H3). Stable sort preserves
    token order within an expert, so the drop policy (and outputs) match
    the cumsum/position formulation exactly.
    """
    T, d = x.shape
    E = router_w.shape[1]
    logits = (x.astype(jnp.float32) @ router_w.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, top_k)                  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    capacity = max(1, int(capacity_factor * T * top_k / E))
    capacity = min(capacity, T)
    N = T * top_k

    e_flat = expert_idx.reshape(N)
    g_flat = gate_vals.reshape(N)
    tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), top_k)
    order = jnp.argsort(e_flat, stable=True)                         # (N,)
    e_sorted = e_flat[order]
    counts = jnp.bincount(e_flat, length=E)                          # (E,)
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_sorted = jnp.arange(N) - starts[e_sorted]                    # 0..cnt-1

    # dispatch: expert e's tokens live at sorted rows [starts[e], +capacity)
    slot_rows = starts[:, None] + jnp.arange(capacity)[None, :]      # (E, C)
    slot_valid = jnp.arange(capacity)[None, :] < counts[:, None]
    rows = tok_ids[order]                                            # (N,)
    expert_tok = rows[jnp.clip(slot_rows, 0, N - 1)]                 # (E, C)
    expert_in = x[expert_tok] * slot_valid[..., None].astype(x.dtype)

    h = jnp.einsum("ecd,edf->ecf", expert_in, w_gate)
    h = swish(h) * jnp.einsum("ecd,edf->ecf", expert_in, w_up)
    expert_out = jnp.einsum("ecf,efd->ecd", h, w_down)               # (E, C, d)

    # combine: row-gather each kept slot's output, un-sort, weighted sum
    kept = pos_sorted < capacity
    pos_c = jnp.clip(pos_sorted, 0, capacity - 1)
    out_sorted = expert_out[e_sorted, pos_c]                         # (N, d)
    out_sorted = out_sorted * (kept.astype(jnp.float32)
                               * g_flat[order])[:, None].astype(out_sorted.dtype)
    inv = jnp.argsort(order)
    out = jnp.sum(out_sorted[inv].reshape(T, top_k, d), axis=1)

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)                                     # (T,E)->(E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce)
    return out.astype(x.dtype), aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(x: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
            mask: Optional[jnp.ndarray] = None, chunk: int = 256
            ) -> jnp.ndarray:
    """Fused next-token cross-entropy WITHOUT materializing (B, S, V) logits.

    x: (B, S, d) final hidden states (already norm'd); w: (d, V) unembedding;
    labels: (B, S). Computes mean nll of labels[:, 1:] given x[:, :-1],
    scanning the sequence in `chunk`-sized slices so peak logits memory is
    (B, chunk, V) — essential for the 100k-256k vocab architectures.
    mask: optional (B, S-1) validity mask.
    """
    B, S, d = x.shape
    xs = x[:, :-1, :]
    ys = labels[:, 1:]
    n = S - 1
    m = mask if mask is not None else jnp.ones((B, n), jnp.float32)
    chunk = min(chunk, n)
    pad = (-n) % chunk
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        ys = jnp.pad(ys, ((0, 0), (0, pad)))
        m = jnp.pad(m, ((0, 0), (0, pad)))
    nc = (n + pad) // chunk
    xs = xs.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    ys = ys.reshape(B, nc, chunk).transpose(1, 0, 2)
    m = m.reshape(B, nc, chunk).transpose(1, 0, 2).astype(jnp.float32)

    def body(carry, inp):
        tot, cnt = carry
        xc, yc, mc = inp
        lg = (xc.astype(jnp.float32) @ w.astype(jnp.float32))   # (B, c, V)
        logz = jax.scipy.special.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, yc[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((logz - gold) * mc)
        cnt = cnt + jnp.sum(mc)
        return (tot, cnt), None

    (tot, cnt), _ = lax.scan(jax.checkpoint(body),
                             (jnp.zeros(()), jnp.zeros(())), (xs, ys, m))
    return tot / jnp.maximum(cnt, 1.0)


def next_token_loss(logits: jnp.ndarray, labels: jnp.ndarray,
                    mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean cross-entropy; logits (B, S, V), labels (B, S) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
