"""SeamlessM4T-medium text backbone (arXiv:2308.11596): encoder-decoder.

The speech frontend (mel filterbank + conformer feature extractor) is a STUB
per the assignment: the encoder consumes precomputed frame embeddings
(B, T_enc, d) supplied by ``input_specs``. Encoder: bidirectional pre-norm
transformer. Decoder: causal self-attention + cross-attention + SwiGLU FFN.
Decode cache: self-attn KV ring + precomputed cross-attn K/V (encoder memory).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import sharding as _sh
from repro.configs.base import ModelConfig
from repro.models import common as cm


class EncDecLM:
    def __init__(self, cfg: ModelConfig, *, remat: bool = False, **_):
        self.cfg = cfg
        self.remat = remat

    # ---------------------------------------------------------------- init
    def init(self, rng, dtype=jnp.float32) -> Tuple[cm.Params, cm.Axes]:
        cfg = self.cfg
        d, H, Hkv, hd, f = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
            cfg.resolved_head_dim, cfg.d_ff
        Le, Ld = cfg.encoder_layers, cfg.num_layers
        b = cm.ParamBuilder(rng, dtype)
        b.param("embed", (cfg.vocab_size, d), ("vocab", "embed"),
                scale=1.0 / math.sqrt(d))
        b.param("unembed", (d, cfg.vocab_size), ("embed", "vocab"))
        b.param("final_norm", (d,), ("embed",), init="ones")
        b.param("enc_final_norm", (d,), ("embed",), init="ones")

        def attn_params(pfx, n):
            b.param(f"{pfx}/norm", (n, d), ("layers", "embed"), init="ones")
            b.param(f"{pfx}/wq", (n, d, H, hd), ("layers", "embed", "heads", "head_dim"))
            b.param(f"{pfx}/wk", (n, d, Hkv, hd), ("layers", "embed", "kv_heads", "head_dim"))
            b.param(f"{pfx}/wv", (n, d, Hkv, hd), ("layers", "embed", "kv_heads", "head_dim"))
            b.param(f"{pfx}/wo", (n, H, hd, d), ("layers", "heads", "head_dim", "embed"),
                    scale=1.0 / math.sqrt(H * hd))

        def ffn_params(pfx, n):
            b.param(f"{pfx}/ffn_norm", (n, d), ("layers", "embed"), init="ones")
            b.param(f"{pfx}/w_gate", (n, d, f), ("layers", "embed", "ffn"))
            b.param(f"{pfx}/w_up", (n, d, f), ("layers", "embed", "ffn"))
            b.param(f"{pfx}/w_down", (n, f, d), ("layers", "ffn", "embed"))

        attn_params("enc/self", Le)
        ffn_params("enc", Le)
        attn_params("dec/self", Ld)
        attn_params("dec/cross", Ld)
        ffn_params("dec", Ld)
        return b.build()

    def _split(self, params, prefix):
        return {k[len(prefix) + 1:]: v for k, v in params.items()
                if k.startswith(prefix + "/")}

    # -------------------------------------------------------------- encoder
    def encode(self, params, frames):
        """frames: (B, T, d) precomputed frontend embeddings -> memory."""
        cfg = self.cfg
        enc = self._split(params, "enc")

        def body(x, lp):
            h = cm.rms_norm(x, lp["self/norm"])
            q = jnp.einsum("bsd,dhk->bshk", h, lp["self/wq"])
            k = jnp.einsum("bsd,dhk->bshk", h, lp["self/wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, lp["self/wv"])
            pos = jnp.arange(x.shape[1])
            cos, sin = cm.rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
            q, k = cm.apply_rope(q, cos, sin), cm.apply_rope(k, cos, sin)
            a = cm.flash_attention(q, k, v, causal=False,
                                   block_q=min(512, x.shape[1]),
                                   block_kv=min(512, x.shape[1]))
            x = x + jnp.einsum("bshk,hkd->bsd", a, lp["self/wo"])
            h = cm.rms_norm(x, lp["ffn_norm"])
            x = _sh.constrain_batch(
                x + cm.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"]))
            return x, None

        if self.remat:
            body = jax.checkpoint(body)
        x, _ = lax.scan(body, frames, enc)
        return cm.rms_norm(x, params["enc_final_norm"])

    # -------------------------------------------------------------- decoder
    def _dec_stack(self, params, x, memory, pos0=0, collect_kv: bool = True):
        cfg = self.cfg
        dec = self._split(params, "dec")
        S, T = x.shape[1], memory.shape[1]

        def body(x, lp):
            h = cm.rms_norm(x, lp["self/norm"])
            q = jnp.einsum("bsd,dhk->bshk", h, lp["self/wq"])
            k = jnp.einsum("bsd,dhk->bshk", h, lp["self/wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, lp["self/wv"])
            pos = pos0 + jnp.arange(S)
            cos, sin = cm.rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
            q, k = cm.apply_rope(q, cos, sin), cm.apply_rope(k, cos, sin)
            a = cm.flash_attention(q, k, v, causal=True,
                                   block_q=min(512, S), block_kv=min(512, S))
            x = x + jnp.einsum("bshk,hkd->bsd", a, lp["self/wo"])
            h = cm.rms_norm(x, lp["cross/norm"])
            qc = jnp.einsum("bsd,dhk->bshk", h, lp["cross/wq"])
            kc = jnp.einsum("btd,dhk->bthk", memory, lp["cross/wk"])
            vc = jnp.einsum("btd,dhk->bthk", memory, lp["cross/wv"])
            ac = cm.flash_attention(qc, kc, vc, causal=False,
                                    block_q=min(512, S), block_kv=min(512, T))
            x = x + jnp.einsum("bshk,hkd->bsd", ac, lp["cross/wo"])
            h = cm.rms_norm(x, lp["ffn_norm"])
            x = _sh.constrain_batch(
                x + cm.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"]))
            return x, ((k, v) if collect_kv else None)

        if self.remat:
            body = jax.checkpoint(body)
        x, kvs = lax.scan(body, x, dec)
        return x, kvs

    # ----------------------------------------------------------- train api
    def loss(self, params, batch):
        frames = batch["frontend"]
        memory = self.encode(params, frames)
        x = params["embed"][batch["tokens"]]
        x, _ = self._dec_stack(params, x, memory, collect_kv=False)
        x = cm.rms_norm(x, params["final_norm"])
        loss = cm.lm_loss(x, params["unembed"], batch["labels"],
                          batch.get("mask", None))
        return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}

    # ----------------------------------------------------------- serve api
    def init_cache(self, B, cache_len, dtype=jnp.bfloat16):
        cfg = self.cfg
        Ld, T = cfg.num_layers, cfg.num_frontend_tokens
        hd, Hkv = cfg.resolved_head_dim, cfg.num_kv_heads
        axes = ("layers", "batch", "cache", "kv_heads", "head_dim")
        cache = {
            "k": jnp.zeros((Ld, B, cache_len, Hkv, hd), dtype),
            "v": jnp.zeros((Ld, B, cache_len, Hkv, hd), dtype),
            "xk": jnp.zeros((Ld, B, T, Hkv, hd), dtype),
            "xv": jnp.zeros((Ld, B, T, Hkv, hd), dtype),
            "pos": jnp.zeros((), jnp.int32),
        }
        cache_axes = {"k": axes, "v": axes, "xk": axes, "xv": axes, "pos": ()}
        return cache, cache_axes

    def prefill(self, params, tokens, frontend=None, pad_to: int = 0):
        """tokens: decoder prompt; frontend: audio frames."""
        memory = self.encode(params, frontend)
        x = params["embed"][tokens]
        x, (ks, vs) = self._dec_stack(params, x, memory)
        dec = self._split(params, "dec")
        xks = jnp.einsum("btd,ldhk->lbthk", memory, dec["cross/wk"])
        xvs = jnp.einsum("btd,ldhk->lbthk", memory, dec["cross/wv"])
        xl = cm.rms_norm(x[:, -1:, :], params["final_norm"])
        lg = jnp.einsum("bsd,dv->bsv", xl, params["unembed"])[:, 0]
        if pad_to > ks.shape[2]:
            pad = [(0, 0), (0, 0), (0, pad_to - ks.shape[2]), (0, 0), (0, 0)]
            ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
        cache = {"k": ks, "v": vs, "xk": xks.astype(ks.dtype),
                 "xv": xvs.astype(vs.dtype),
                 "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
        return lg, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"][tokens][:, None, :]
        pos = cache["pos"]
        dec = self._split(params, "dec")
        C = cache["k"].shape[2]

        def body(x, per):
            lp, kc, vc, xk, xv = per
            h = cm.rms_norm(x, lp["self/norm"])
            q = jnp.einsum("bsd,dhk->bshk", h, lp["self/wq"])
            k = jnp.einsum("bsd,dhk->bshk", h, lp["self/wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, lp["self/wv"])
            cos, sin = cm.rope_angles(pos[None], cfg.resolved_head_dim,
                                      cfg.rope_theta)
            q, k = cm.apply_rope(q, cos[None], sin[None]), \
                cm.apply_rope(k, cos[None], sin[None])
            idx = jnp.minimum(pos, C - 1)
            kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), idx, 1)
            vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), idx, 1)
            kc = _sh.constrain_batch(kc)
            vc = _sh.constrain_batch(vc)
            o = cm.decode_attention(q[:, 0], kc, vc, jnp.minimum(pos + 1, C))
            x = x + jnp.einsum("bhk,hkd->bd", o, lp["self/wo"])[:, None]
            h = cm.rms_norm(x, lp["cross/norm"])
            qc = jnp.einsum("bsd,dhk->bshk", h, lp["cross/wq"])
            oc = cm.decode_attention(qc[:, 0], xk, xv, xk.shape[1])
            x = x + jnp.einsum("bhk,hkd->bd", oc, lp["cross/wo"])[:, None]
            h = cm.rms_norm(x, lp["ffn_norm"])
            x = x + cm.swiglu(h, lp["w_gate"], lp["w_up"], lp["w_down"])
            return x, (kc, vc)

        x, (ks, vs) = lax.scan(body, x, (dec, cache["k"], cache["v"],
                                         cache["xk"], cache["xv"]))
        xl = cm.rms_norm(x, params["final_norm"])
        lg = jnp.einsum("bsd,dv->bsv", xl, params["unembed"])[:, 0]
        return lg, {"k": ks, "v": vs, "xk": cache["xk"], "xv": cache["xv"],
                    "pos": pos + 1}
