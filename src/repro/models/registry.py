"""Model construction + analytic parameter/FLOP accounting.

Accounting feeds (a) the Green-FL energy model (client FLOPs -> duration ->
energy) and (b) the roofline's MODEL_FLOPS and scan-undercount corrections
(layer stacks / attention block schedules / time recurrences run under
``lax.scan``, whose body XLA's cost model counts once — see DESIGN.md §5).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (AUDIO, CHARLM, DENSE, HYBRID, MOE, SSM, VLM,
                                ModelConfig)


def get_model(cfg: ModelConfig, *, decode_window: int = 0,
              remat: bool = False):
    from repro.models.charlm import CharLM
    from repro.models.encdec import EncDecLM
    from repro.models.griffin import Griffin
    from repro.models.rwkv import RWKV6
    from repro.models.transformer import DecoderLM

    fam = cfg.family
    if fam in (DENSE, MOE, VLM):
        return DecoderLM(cfg, decode_window=decode_window, remat=remat)
    if fam == SSM:
        return RWKV6(cfg, remat=remat)
    if fam == HYBRID:
        return Griffin(cfg, remat=remat)
    if fam == AUDIO:
        return EncDecLM(cfg, remat=remat)
    if fam == CHARLM:
        return CharLM(cfg, remat=remat)
    raise ValueError(fam)


@functools.lru_cache(maxsize=64)
def param_shapes_and_axes(cfg: ModelConfig):
    """Exact param ShapeDtypeStructs + logical axes, with no allocation."""
    model = get_model(cfg)
    axes_box = {}

    def initf(r):
        params, axes = model.init(r, dtype=jnp.bfloat16)
        axes_box.update(axes)
        return params

    shapes = jax.eval_shape(initf, jax.ShapeDtypeStruct((2,), jnp.uint32))
    return shapes, dict(axes_box)


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes, axes = param_shapes_and_axes(cfg)
    total = 0.0
    for k, s in shapes.items():
        n = 1
        for d in s.shape:
            n *= d
        if active_only and cfg.moe is not None and "experts" in axes[k]:
            n *= cfg.moe.top_k / cfg.moe.num_experts
        total += n
    return int(total)


# ---------------------------------------------------------------------------
# Analytic FLOPs (exact-schedule attention / recurrence corrections)
# ---------------------------------------------------------------------------

def _attn_pairs(S: int, window: int) -> float:
    """Number of (q, kv) attended pairs under causal (banded) masking."""
    if not window or window >= S:
        return S * (S + 1) / 2.0
    w = window
    return w * S - w * (w - 1) / 2.0 - w  # ramp-up + band (approx exact)


def attention_flops(cfg: ModelConfig, batch: int, seq: int,
                    n_attn_layers: Optional[int] = None,
                    window: Optional[int] = None) -> float:
    """Forward FLOPs of score+value matmuls across attention layers."""
    if cfg.family == SSM:
        # WKV state update+readout: ~4 mults per (token, head, hd, hd)
        hd = cfg.resolved_head_dim
        H = cfg.d_model // hd
        return 4.0 * batch * seq * H * hd * hd * cfg.num_layers
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    w = cfg.sliding_window if window is None else window
    if n_attn_layers is None:
        if cfg.family == HYBRID:
            n_attn_layers = cfg.num_layers // 3
            # plus RG-LRU elementwise recurrence (~6 flops/elem)
            extra = 6.0 * batch * seq * (cfg.lru_width or cfg.d_model) \
                * (cfg.num_layers - n_attn_layers)
        else:
            n_attn_layers = cfg.num_layers
            extra = 0.0
    else:
        extra = 0.0
    pairs = _attn_pairs(seq, w)
    per_layer = 4.0 * batch * pairs * H * hd          # qk^T + pv, 2 flops/mac
    total = per_layer * n_attn_layers + extra
    if cfg.is_encoder_decoder:
        T = cfg.num_frontend_tokens
        enc = 4.0 * batch * T * T * H * hd * cfg.encoder_layers
        cross = 4.0 * batch * seq * T * H * hd * cfg.num_layers
        total = total + enc + cross
    return total


def step_flops(cfg: ModelConfig, batch: int, seq: int, kind: str) -> float:
    """Analytic FLOPs of one train/prefill/decode step (whole step)."""
    n_active = param_count(cfg, active_only=True)
    if kind == "train":
        matmul = 6.0 * n_active * batch * seq
        attn = 3.0 * attention_flops(cfg, batch, seq)   # fwd + 2x bwd
    elif kind == "prefill":
        matmul = 2.0 * n_active * batch * seq
        attn = attention_flops(cfg, batch, seq)
    elif kind == "decode":
        matmul = 2.0 * n_active * batch
        if cfg.family == SSM:
            attn = attention_flops(cfg, batch, 1)
        else:
            # one query against the full cache
            attn = 4.0 * batch * min(seq, cfg.sliding_window or seq) \
                * cfg.num_heads * cfg.resolved_head_dim * cfg.num_layers
    else:
        raise ValueError(kind)
    return matmul + attn


def step_bytes_min(cfg: ModelConfig, batch: int, seq: int, kind: str) -> float:
    """Lower-bound HBM traffic (params once + activations/cache once, bf16)."""
    n = param_count(cfg)
    if kind == "train":
        # params + grads + adam m,v (f32) + activations
        return 2.0 * n * 4 + batch * seq * cfg.d_model * 2 * cfg.num_layers
    if kind == "prefill":
        return 2.0 * n + batch * seq * cfg.d_model * 2 * cfg.num_layers
    # decode: params + full KV cache read
    cache = 2 * batch * min(seq, cfg.sliding_window or seq) * \
        max(cfg.num_kv_heads, 1) * max(cfg.resolved_head_dim, 1) * 2 * cfg.num_layers
    if cfg.family == SSM:
        hd = cfg.resolved_head_dim
        cache = batch * (cfg.d_model // hd) * hd * hd * 4 * cfg.num_layers
    return 2.0 * n + cache
