"""The paper's FL workload: character-aware CNN-LSTM next-word LM
(Kim et al. 2016; Green Federated Learning §3.2).

    e_i = CNN(chars of word i)          (multi-width char convs + max-pool)
    c_i, h_i = LSTM(h_{i-1}, c_{i-1}, e_i)
    p(w_{i+1} | w_{<=i}) = softmax(W^T h_i)        (MLP decoder + softmax)

Batch layout: tokens are WORDS; ``batch["chars"]`` is (B, S, W) char ids
per word (W = max_word_len). Perplexity = exp(mean nll) as the paper's
target metric (target 175).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common as cm


class CharLM:
    def __init__(self, cfg: ModelConfig, *, remat: bool = False, **_):
        self.cfg = cfg
        self.remat = remat
        self.cnn_out = sum(n for _, n in cfg.cnn_filters)

    # ---------------------------------------------------------------- init
    def init(self, rng, dtype=jnp.float32) -> Tuple[cm.Params, cm.Axes]:
        cfg = self.cfg
        b = cm.ParamBuilder(rng, dtype)
        b.param("char_embed", (cfg.char_vocab, cfg.char_emb), ("vocab", "embed"),
                scale=0.1)
        for w, n in cfg.cnn_filters:
            b.param(f"cnn/w{w}", (w, cfg.char_emb, n), (None, "embed", "ffn"))
            b.param(f"cnn/b{w}", (n,), ("ffn",), init="zeros")
        # highway layer over CNN features
        b.param("highway/wt", (self.cnn_out, self.cnn_out), ("ffn", "ffn_out"))
        b.param("highway/bt", (self.cnn_out,), ("ffn",), init="zeros")
        b.param("highway/wh", (self.cnn_out, self.cnn_out), ("ffn", "ffn_out"))
        b.param("highway/bh", (self.cnn_out,), ("ffn",), init="zeros")
        b.param("proj_in", (self.cnn_out, cfg.d_model), ("ffn", "embed"))
        L, d, Hd = cfg.num_layers, cfg.d_model, cfg.lstm_hidden
        # LSTM: input->gates and hidden->gates (i, f, g, o)
        b.param("lstm/wx", (L, d, 4 * Hd), ("layers", "embed", "ffn"))
        b.param("lstm/wh", (L, Hd, 4 * Hd), ("layers", "embed", "ffn"))
        b.param("lstm/bias", (L, 4 * Hd), ("layers", "ffn"), init="zeros")
        b.param("mlp/w1", (Hd, cfg.d_ff), ("embed", "ffn"))
        b.param("mlp/b1", (cfg.d_ff,), ("ffn",), init="zeros")
        b.param("unembed", (cfg.d_ff, cfg.vocab_size), ("embed", "vocab"))
        return b.build()

    # ------------------------------------------------------------- word enc
    def word_embed(self, params, chars):
        """chars: (..., W) int32 -> (..., d_model)."""
        cfg = self.cfg
        x = params["char_embed"][chars]                    # (..., W, ce)
        feats = []
        for w, n in cfg.cnn_filters:
            ker = params[f"cnn/w{w}"]                      # (w, ce, n)
            # valid conv over the W axis
            conv = sum(jnp.einsum("...wc,cn->...wn",
                                  x[..., i:x.shape[-2] - w + 1 + i, :], ker[i])
                       for i in range(w))
            conv = jnp.tanh(conv + params[f"cnn/b{w}"])
            feats.append(jnp.max(conv, axis=-2))           # max over positions
        f = jnp.concatenate(feats, axis=-1)                # (..., cnn_out)
        t = jax.nn.sigmoid(f @ params["highway/wt"] + params["highway/bt"])
        h = jax.nn.relu(f @ params["highway/wh"] + params["highway/bh"])
        f = t * h + (1.0 - t) * f
        return f @ params["proj_in"]

    # ------------------------------------------------------------- lstm
    def _lstm_layer(self, wx, wh, bias, x, h0, c0):
        """x: (B, S, d); returns (out (B,S,Hd), h_last, c_last)."""
        Hd = wh.shape[0]
        xg = jnp.einsum("bsd,dg->bsg", x, wx) + bias

        def step(carry, xg_t):
            h, c = carry
            g = xg_t + h @ wh
            i, f, gg, o = jnp.split(g, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(gg)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h

        (h, c), hs = lax.scan(step, (h0, c0), jnp.moveaxis(xg, 1, 0))
        return jnp.moveaxis(hs, 0, 1), h, c

    def _stack(self, params, x, states):
        """states: dict h/c (L, B, Hd)."""
        L = self.cfg.num_layers

        def body(x, per):
            wx, wh, bias, h0, c0 = per
            out, h, c = self._lstm_layer(wx, wh, bias, x, h0, c0)
            return out, (h, c)

        x, (hs, cs) = lax.scan(
            body, x, (params["lstm/wx"], params["lstm/wh"], params["lstm/bias"],
                      states["h"], states["c"]))
        return x, {"h": hs, "c": cs}

    def logits(self, params, x):
        h = jax.nn.relu(x @ params["mlp/w1"] + params["mlp/b1"])
        return h @ params["unembed"]

    def _zero_states(self, B, dtype):
        L, Hd = self.cfg.num_layers, self.cfg.lstm_hidden
        st = {"h": jnp.zeros((L, B, Hd), dtype), "c": jnp.zeros((L, B, Hd), dtype)}
        axes = {"h": ("layers", "batch", "embed"), "c": ("layers", "batch", "embed")}
        return st, axes

    # ----------------------------------------------------------- train api
    def loss(self, params, batch):
        chars = batch["chars"]                             # (B, S, W)
        x = self.word_embed(params, chars)
        states, _ = self._zero_states(chars.shape[0], x.dtype)
        x, _ = self._stack(params, x, states)
        h = jax.nn.relu(x @ params["mlp/w1"] + params["mlp/b1"])
        loss = cm.lm_loss(h, params["unembed"], batch["labels"],
                          batch.get("mask", None))
        return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32),
                      "perplexity": jnp.exp(loss)}

    # ----------------------------------------------------------- serve api
    def init_cache(self, B, cache_len, dtype=jnp.float32):
        st, axes = self._zero_states(B, dtype)
        st["pos"] = jnp.zeros((), jnp.int32)
        axes["pos"] = ()
        return st, axes

    def prefill(self, params, tokens, frontend=None, chars=None, pad_to: int = 0):
        chars = chars if chars is not None else tokens
        x = self.word_embed(params, chars)
        states, _ = self._zero_states(chars.shape[0], x.dtype)
        x, states = self._stack(params, x, states)
        lg = self.logits(params, x[:, -1])
        states["pos"] = jnp.asarray(chars.shape[1], jnp.int32)
        return lg, states

    def decode_step(self, params, cache, chars):
        """chars: (B, W) — the chars of the latest word."""
        x = self.word_embed(params, chars)[:, None, :]
        states = {k: v for k, v in cache.items() if k != "pos"}
        x, states = self._stack(params, x, states)
        lg = self.logits(params, x[:, 0])
        states["pos"] = cache["pos"] + 1
        return lg, states
