"""RecurrentGemma / Griffin (arXiv:2402.19427): RG-LRU recurrent blocks +
local (sliding-window) MQA attention in a 2:1 pattern, each followed by a
gated-MLP block.

Layer plan: layers are grouped as (recurrent, recurrent, local_attn) triples
scanned together (uniform scan body), with `num_layers % 3` trailing
recurrent layers in a second scan. Decode state: per recurrent layer an
RG-LRU hidden h (B, D) + temporal-conv tail (B, 3, D); per attention layer a
ring-buffer KV of the window size — O(window), the hybrid's long-context
advantage.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import sharding as _sh
from repro.configs.base import ModelConfig
from repro.models import common as cm

_C = 8.0           # RG-LRU decay sharpness constant (paper §2.4)
_CONV_W = 4        # temporal conv width


class Griffin:
    def __init__(self, cfg: ModelConfig, *, remat: bool = False, **_):
        self.cfg = cfg
        self.remat = remat
        self.n_tri = cfg.num_layers // 3
        self.n_rem = cfg.num_layers % 3          # trailing recurrent layers
        self.n_rec = 2 * self.n_tri + self.n_rem
        self.n_attn = self.n_tri
        self.window = cfg.sliding_window or 2048
        self.d_rnn = cfg.lru_width or cfg.d_model

    # ---------------------------------------------------------------- init
    def _rec_params(self, b: cm.ParamBuilder, n: int):
        d, D = self.cfg.d_model, self.d_rnn
        f = self.cfg.d_ff
        la = ("layers",)
        b.param("rec/norm", (n, d), la + ("embed",), init="ones")
        b.param("rec/w_in_a", (n, d, D), la + ("embed", "rnn"))
        b.param("rec/w_in_b", (n, d, D), la + ("embed", "rnn"))
        b.param("rec/conv_w", (n, _CONV_W, D), la + (None, "rnn"))
        b.param("rec/conv_b", (n, D), la + ("rnn",), init="zeros")
        b.param("rec/w_gate_a", (n, D), la + ("rnn",), init="zeros")   # recurrence gate diag-ish
        b.param("rec/w_gate_x", (n, D), la + ("rnn",), init="zeros")   # input gate
        b.param("rec/lambda", (n, D), la + ("rnn",), init="uniform", scale=1.0)
        b.param("rec/w_out", (n, D, d), la + ("rnn", "embed"),
                scale=1.0 / math.sqrt(D))
        b.param("rec/mlp_norm", (n, d), la + ("embed",), init="ones")
        b.param("rec/mlp_gate", (n, d, f), la + ("embed", "ffn"))
        b.param("rec/mlp_up", (n, d, f), la + ("embed", "ffn"))
        b.param("rec/mlp_down", (n, f, d), la + ("ffn", "embed"))

    def _attn_params(self, b: cm.ParamBuilder, n: int):
        cfg = self.cfg
        d, H, Hkv, hd, f = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
            cfg.resolved_head_dim, cfg.d_ff
        la = ("layers",)
        b.param("attn/norm", (n, d), la + ("embed",), init="ones")
        b.param("attn/wq", (n, d, H, hd), la + ("embed", "heads", "head_dim"))
        b.param("attn/wk", (n, d, Hkv, hd), la + ("embed", "kv_heads", "head_dim"))
        b.param("attn/wv", (n, d, Hkv, hd), la + ("embed", "kv_heads", "head_dim"))
        b.param("attn/wo", (n, H, hd, d), la + ("heads", "head_dim", "embed"),
                scale=1.0 / math.sqrt(H * hd))
        b.param("attn/mlp_norm", (n, d), la + ("embed",), init="ones")
        b.param("attn/mlp_gate", (n, d, f), la + ("embed", "ffn"))
        b.param("attn/mlp_up", (n, d, f), la + ("embed", "ffn"))
        b.param("attn/mlp_down", (n, f, d), la + ("ffn", "embed"))

    def init(self, rng, dtype=jnp.float32) -> Tuple[cm.Params, cm.Axes]:
        cfg = self.cfg
        b = cm.ParamBuilder(rng, dtype)
        d = cfg.d_model
        b.param("embed", (cfg.vocab_size, d), ("vocab", "embed"),
                scale=1.0 / math.sqrt(d))
        if not cfg.tie_embeddings:
            b.param("unembed", (d, cfg.vocab_size), ("embed", "vocab"))
        b.param("final_norm", (d,), ("embed",), init="ones")
        self._rec_params(b, self.n_rec)
        if self.n_attn:
            self._attn_params(b, self.n_attn)
        return b.build()

    # ------------------------------------------------------------- blocks
    def _rg_lru(self, lp, x, h0):
        """x: (B, S, D) conv output; h0: (B, D). Returns (y, h_last)."""
        r = jax.nn.sigmoid(x * lp["w_gate_a"])
        i = jax.nn.sigmoid(x * lp["w_gate_x"])
        log_a = -_C * jax.nn.softplus(lp["lambda"]) * r        # (B,S,D) <= 0
        a = jnp.exp(log_a.astype(jnp.float32))
        gated = (i * x).astype(jnp.float32) * jnp.sqrt(
            jnp.maximum(1.0 - jnp.square(a), 1e-12))

        def step(h, av):
            a_t, v_t = av
            h = a_t * h + v_t
            return h, h

        a_s = jnp.moveaxis(a, 1, 0)
        v_s = jnp.moveaxis(gated, 1, 0)
        h_last, ys = lax.scan(step, h0.astype(jnp.float32), (a_s, v_s))
        return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_last

    def _recurrent_block(self, lp, x, state):
        """Griffin recurrent mixing block + MLP block."""
        h = cm.rms_norm(x, lp["norm"])
        xa = jnp.einsum("bsd,dD->bsD", h, lp["w_in_a"])
        xb = cm.swish(jnp.einsum("bsd,dD->bsD", h, lp["w_in_b"]))
        # temporal conv over (prev conv tail ++ xa)
        tail = state["conv"]                                   # (B, 3, D)
        xc = jnp.concatenate([tail.astype(xa.dtype), xa], axis=1)
        w = lp["conv_w"]                                       # (4, D)
        conv = sum(xc[:, i:i + xa.shape[1], :] * w[i] for i in range(_CONV_W))
        conv = conv + lp["conv_b"]
        y, h_last = self._rg_lru(lp, conv, state["h"])
        y = y * xb
        x = x + jnp.einsum("bsD,Dd->bsd", y, lp["w_out"])
        h = cm.rms_norm(x, lp["mlp_norm"])
        x = x + cm.swiglu(h, lp["mlp_gate"], lp["mlp_up"], lp["mlp_down"])
        new_state = {"h": h_last, "conv": xc[:, -(_CONV_W - 1):, :].astype(tail.dtype)}
        return _sh.constrain_batch(x), new_state

    def _attn_block(self, lp, x, kv_state, pos0):
        cfg = self.cfg
        h = cm.rms_norm(x, lp["norm"])
        q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
        S = x.shape[1]
        pos = pos0 + jnp.arange(S)
        cos, sin = cm.rope_angles(pos, cfg.resolved_head_dim, cfg.rope_theta)
        q = cm.apply_rope(q, cos, sin)
        k = cm.apply_rope(k, cos, sin)
        attn = cm.flash_attention(q, k, v, causal=True, window=self.window,
                                  block_q=min(512, S), block_kv=min(512, S))
        x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["wo"])
        h = cm.rms_norm(x, lp["mlp_norm"])
        x = _sh.constrain_batch(
            x + cm.swiglu(h, lp["mlp_gate"], lp["mlp_up"], lp["mlp_down"]))
        W = min(self.window, S)
        return x, {"k": k[:, -W:], "v": v[:, -W:]}

    # ------------------------------------------------------------- forward
    def _unembed(self, params):
        return params["embed"].T if self.cfg.tie_embeddings else params["unembed"]

    def _split(self, params, prefix):
        return {k.split("/", 1)[1]: v for k, v in params.items()
                if k.startswith(prefix + "/")}

    def _stack(self, params, x, rec_states, pos0=0, collect: bool = True):
        """Runs triples via scan + trailing recurrent layers via scan.
        rec_states: stacked (n_rec, ...) dict. Returns x, new rec states,
        per-attn-layer kv (stacked python list)."""
        rec = self._split(params, "rec")
        attn = self._split(params, "attn") if self.n_attn else None
        kv_out = []
        new_rec = None

        if self.n_tri:
            rec_tri = {k: v[: 2 * self.n_tri].reshape(
                (self.n_tri, 2) + v.shape[1:]) for k, v in rec.items()}
            st_tri = jax.tree.map(lambda s: s[: 2 * self.n_tri].reshape(
                (self.n_tri, 2) + s.shape[1:]), rec_states)

            def tri_body(x, per):
                lp_r, st, lp_a = per
                outs = []
                for j in range(2):
                    lpj = {k: v[j] for k, v in lp_r.items()}
                    stj = {k: v[j] for k, v in st.items()}
                    x, ns = self._recurrent_block(lpj, x, stj)
                    outs.append(ns)
                x, kv = self._attn_block(lp_a, x, None, pos0)
                if not collect:
                    return x, (None, None)
                ns = jax.tree.map(lambda a, b: jnp.stack([a, b]), *outs)
                return x, (ns, kv)

            if self.remat:
                tri_body = jax.checkpoint(tri_body)
            x, (ns_tri, kvs) = lax.scan(tri_body, x, (rec_tri, st_tri, attn))
            kv_out = kvs  # stacked (n_attn, B, W, Hkv, hd)
            if collect:
                new_rec = jax.tree.map(
                    lambda s: s.reshape((2 * self.n_tri,) + s.shape[2:]), ns_tri)

        if self.n_rem:
            rec_rem = {k: v[2 * self.n_tri:] for k, v in rec.items()}
            st_rem = jax.tree.map(lambda s: s[2 * self.n_tri:], rec_states)

            def rem_body(x, per):
                lp, st = per
                x, ns = self._recurrent_block(lp, x, st)
                return x, (ns if collect else None)

            if self.remat:
                rem_body = jax.checkpoint(rem_body)
            x, ns_rem = lax.scan(rem_body, x, (rec_rem, st_rem))
            if collect:
                new_rec = ns_rem if new_rec is None else jax.tree.map(
                    lambda a, b: jnp.concatenate([a, b], 0), new_rec, ns_rem)
        return x, new_rec, kv_out

    def _zero_rec_states(self, B, dtype):
        D = self.d_rnn
        states = {
            "h": jnp.zeros((self.n_rec, B, D), jnp.float32),
            "conv": jnp.zeros((self.n_rec, B, _CONV_W - 1, D), dtype),
        }
        axes = {"h": ("layers", "batch", "rnn"),
                "conv": ("layers", "batch", None, "rnn")}
        return states, axes

    def loss(self, params, batch):
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        states, _ = self._zero_rec_states(tokens.shape[0], x.dtype)
        x, _, _ = self._stack(params, x, states, collect=False)
        x = cm.rms_norm(x, params["final_norm"])
        loss = cm.lm_loss(x, self._unembed(params), batch["labels"],
                          batch.get("mask", None))
        return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}

    # ----------------------------------------------------------- serve api
    def init_cache(self, B, cache_len, dtype=jnp.bfloat16):
        W = min(self.window, cache_len)
        states, axes = self._zero_rec_states(B, dtype)
        cache = dict(states)
        cache_axes = dict(axes)
        if self.n_attn:
            shape = (self.n_attn, B, W, self.cfg.num_kv_heads,
                     self.cfg.resolved_head_dim)
            cache["k"] = jnp.zeros(shape, dtype)
            cache["v"] = jnp.zeros(shape, dtype)
            cache_axes["k"] = ("layers", "batch", "cache", "kv_heads", "head_dim")
            cache_axes["v"] = cache_axes["k"]
        cache["pos"] = jnp.zeros((), jnp.int32)
        cache_axes["pos"] = ()
        return cache, cache_axes

    def prefill(self, params, tokens, frontend=None, pad_to: int = 0):
        x = params["embed"][tokens]
        states, _ = self._zero_rec_states(tokens.shape[0], x.dtype)
        x, new_rec, kvs = self._stack(params, x, states)
        xl = cm.rms_norm(x[:, -1:, :], params["final_norm"])
        lg = jnp.einsum("bsd,dv->bsv", xl, self._unembed(params))[:, 0]
        cache = dict(new_rec)
        if self.n_attn:
            ks, vs = kvs["k"], kvs["v"]
            W = min(self.window, max(pad_to, ks.shape[2]))
            if W > ks.shape[2]:
                pad = [(0, 0), (0, 0), (0, W - ks.shape[2]), (0, 0), (0, 0)]
                ks, vs = jnp.pad(ks, pad), jnp.pad(vs, pad)
            cache["k"] = ks
            cache["v"] = vs
        cache["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
        return lg, cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        x = params["embed"][tokens][:, None, :]
        pos = cache["pos"]
        rec = self._split(params, "rec")
        attn = self._split(params, "attn") if self.n_attn else None
        new_cache = {"pos": pos + 1}

        # layer order: for triple t: rec(2t), rec(2t+1), attn(t); then remainder
        new_h, new_conv = [], []
        new_k, new_v = [], []
        ai = 0
        for li in range(self.n_rec + self.n_attn):
            tri, off = divmod(li, 3)
            if tri < self.n_tri and off == 2:
                lp = {k: v[ai] for k, v in attn.items()}
                kc, vc = cache["k"][ai], cache["v"][ai]
                W = kc.shape[1]
                h = cm.rms_norm(x, lp["norm"])
                q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"])
                k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"])
                v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
                cos, sin = cm.rope_angles(pos[None], cfg.resolved_head_dim,
                                          cfg.rope_theta)
                q = cm.apply_rope(q, cos[None], sin[None])
                k = cm.apply_rope(k, cos[None], sin[None])
                idx = pos % W
                kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), idx, 1)
                vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), idx, 1)
                kc = _sh.constrain_batch(kc)
                vc = _sh.constrain_batch(vc)
                o = cm.decode_attention(q[:, 0], kc, vc, jnp.minimum(pos + 1, W))
                x = x + jnp.einsum("bhk,hkd->bd", o, lp["wo"])[:, None]
                h = cm.rms_norm(x, lp["mlp_norm"])
                x = x + cm.swiglu(h, lp["mlp_gate"], lp["mlp_up"], lp["mlp_down"])
                new_k.append(kc)
                new_v.append(vc)
                ai += 1
            else:
                ri = 2 * tri + off if tri < self.n_tri else li - self.n_attn
                lp = {k: v[ri] for k, v in rec.items()}
                st = {"h": cache["h"][ri], "conv": cache["conv"][ri]}
                x, ns = self._recurrent_block(lp, x, st)
                new_h.append(ns["h"])
                new_conv.append(ns["conv"])
        new_cache["h"] = jnp.stack(new_h)
        new_cache["conv"] = jnp.stack(new_conv)
        if self.n_attn:
            new_cache["k"] = jnp.stack(new_k)
            new_cache["v"] = jnp.stack(new_v)
        xl = cm.rms_norm(x, params["final_norm"])
        lg = jnp.einsum("bsd,dv->bsv", xl, self._unembed(params))[:, 0]
        return lg, new_cache
