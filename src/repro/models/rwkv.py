"""RWKV-6 "Finch" (arXiv:2404.05892) — attention-free RNN with
data-dependent decay and token shift.

Time-mix:  r,k,v,g,w projections with data-dependent token-shift (low-rank
"ddlerp"), per-channel data-dependent decay w_t = exp(-exp(w0 + lora_w(x))),
bonus u, per-head WKV state S in R^{hd x hd}:
    o_t = r_t · (S_{t-1} + diag(u) k_t v_t^T);   S_t = diag(w_t) S_{t-1} + k_t v_t^T
Channel-mix: squared-ReLU MLP with token shift.

Decode state is O(1) per layer — the framework's native long_500k citizen.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import sharding as _sh
from repro.configs.base import ModelConfig
from repro.models import common as cm

_DDLERP_RANK = 32
_DECAY_RANK = 64


class RWKV6:
    def __init__(self, cfg: ModelConfig, *, remat: bool = False, **_):
        self.cfg = cfg
        self.remat = remat
        assert cfg.d_model % cfg.resolved_head_dim == 0
        self.n_heads = cfg.d_model // cfg.resolved_head_dim

    # ---------------------------------------------------------------- init
    def init(self, rng, dtype=jnp.float32) -> Tuple[cm.Params, cm.Axes]:
        cfg, H, hd = self.cfg, self.n_heads, self.cfg.resolved_head_dim
        d, L, f = cfg.d_model, cfg.num_layers, cfg.d_ff
        b = cm.ParamBuilder(rng, dtype)
        b.param("embed", (cfg.vocab_size, d), ("vocab", "embed"),
                scale=1.0 / math.sqrt(d))
        b.param("unembed", (d, cfg.vocab_size), ("embed", "vocab"))
        b.param("final_norm", (d,), ("embed",), init="ones")
        la, le = ("layers",), ("layers", "embed")
        b.param("blocks/tm_norm", (L, d), le, init="ones")
        b.param("blocks/cm_norm", (L, d), le, init="ones")
        # ddlerp token-shift mixers: base mu for x and per-target (r,k,v,w,g)
        b.param("blocks/mu_x", (L, d), le, init="zeros")
        b.param("blocks/mu_rkvwg", (L, 5, d), ("layers", None, "embed"), init="zeros")
        b.param("blocks/ddlerp_a", (L, d, 5 * _DDLERP_RANK), ("layers", "embed", None))
        b.param("blocks/ddlerp_b", (L, 5, _DDLERP_RANK, d), ("layers", None, None, "embed"))
        # time-mix projections
        for nm in ("wr", "wk", "wv", "wg"):
            b.param(f"blocks/{nm}", (L, d, H, hd),
                    ("layers", "embed", "heads", "head_dim"))
        b.param("blocks/wo", (L, H, hd, d), ("layers", "heads", "head_dim", "embed"),
                scale=1.0 / math.sqrt(d))
        # data-dependent decay (low-rank) + bonus
        b.param("blocks/w0", (L, H, hd), ("layers", "heads", "head_dim"), init="zeros")
        b.param("blocks/decay_a", (L, d, _DECAY_RANK), ("layers", "embed", None))
        b.param("blocks/decay_b", (L, _DECAY_RANK, H, hd),
                ("layers", None, "heads", "head_dim"))
        b.param("blocks/u", (L, H, hd), ("layers", "heads", "head_dim"), init="zeros")
        b.param("blocks/ln_out", (L, H, hd), ("layers", "heads", "head_dim"), init="ones")
        # channel-mix
        b.param("blocks/cm_mu_k", (L, d), le, init="zeros")
        b.param("blocks/cm_mu_r", (L, d), le, init="zeros")
        b.param("blocks/cm_wk", (L, d, f), ("layers", "embed", "ffn"))
        b.param("blocks/cm_wv", (L, f, d), ("layers", "ffn", "embed"))
        b.param("blocks/cm_wr", (L, d, d), ("layers", "embed", "embed_out"))
        return b.build()

    # ------------------------------------------------------------- pieces
    def _ddlerp(self, lp, x, x_prev):
        """Data-dependent token-shift. x, x_prev: (B, S, d) ->
        five mixed streams (B, S, 5, d) for (r, k, v, w, g)."""
        dx = x_prev - x
        xx = x + dx * lp["mu_x"]
        low = jnp.tanh(jnp.einsum("bsd,dr->bsr", xx, lp["ddlerp_a"]))
        low = low.reshape(*low.shape[:-1], 5, _DDLERP_RANK)
        off = jnp.einsum("bsfr,frd->bsfd", low, lp["ddlerp_b"])
        mix = lp["mu_rkvwg"] + off                       # (B,S,5,d)
        return x[..., None, :] + dx[..., None, :] * mix

    def _decay(self, lp, xw):
        """xw: (B,S,d) -> per-token decay w in (0,1): (B,S,H,hd)."""
        low = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw, lp["decay_a"]))
        wlog = lp["w0"] + jnp.einsum("bsr,rhk->bshk", low, lp["decay_b"])
        return jnp.exp(-jnp.exp(wlog.astype(jnp.float32)))

    def _wkv(self, r, k, v, w, u, state, chunk: int = 64):
        """r,k,v,w: (B,S,H,hd); u: (H,hd); state: (B,H,hd,hd) carries.
        Returns (out (B,S,H,hd), new_state).

        Two-level scan: an outer scan over checkpointed chunks bounds BPTT
        memory to O(S/chunk + chunk) state copies instead of O(S) — the
        chunked-recurrence scheme RWKV/linear-attention trainings use.
        """
        def step(S, rkvw):
            rt, kt, vt, wt = rkvw                       # (B,H,hd)
            kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)    # outer product
            out = jnp.einsum("bhk,bhkv->bhv", rt, S + u[None, :, :, None] * kv)
            S = wt[..., None] * S + kv
            return S, out

        rs, ks, vs, ws = (jnp.moveaxis(t.astype(jnp.float32), 1, 0)
                          for t in (r, k, v, w))
        state = state.astype(jnp.float32)
        S_len = rs.shape[0]
        if S_len % chunk != 0 or S_len <= chunk:
            state, outs = lax.scan(step, state, (rs, ks, vs, ws))
            return jnp.moveaxis(outs, 0, 1), state

        n_chunks = S_len // chunk
        xs = jax.tree.map(
            lambda t: t.reshape((n_chunks, chunk) + t.shape[1:]),
            (rs, ks, vs, ws))

        def chunk_body(S, xc):
            return lax.scan(step, S, xc)

        state, outs = lax.scan(jax.checkpoint(chunk_body), state, xs)
        outs = outs.reshape((S_len,) + outs.shape[2:])
        return jnp.moveaxis(outs, 0, 1), state

    def _time_mix(self, lp, x, x_prev_tok, state):
        """x: (B,S,d). x_prev_tok: (B,d) last token of previous chunk.
        Returns (out, last_token, new_state)."""
        B, S, d = x.shape
        H, hd = self.n_heads, self.cfg.resolved_head_dim
        xs = jnp.concatenate([x_prev_tok[:, None, :], x[:, :-1, :]], axis=1)
        mixed = self._ddlerp(lp, x, xs)                 # (B,S,5,d)
        xr, xk, xv, xw, xg = (mixed[:, :, i, :] for i in range(5))
        r = jnp.einsum("bsd,dhk->bshk", xr, lp["wr"])
        k = jnp.einsum("bsd,dhk->bshk", xk, lp["wk"])
        v = jnp.einsum("bsd,dhk->bshk", xv, lp["wv"])
        g = cm.swish(jnp.einsum("bsd,dhk->bshk", xg, lp["wg"]))
        w = self._decay(lp, xw)
        out, state = self._wkv(r, k, v, w, lp["u"].astype(jnp.float32), state)
        # per-head groupnorm
        mu = jnp.mean(out, axis=-1, keepdims=True)
        var = jnp.var(out, axis=-1, keepdims=True)
        out = (out - mu) * lax.rsqrt(var + 1e-5) * lp["ln_out"]
        out = (out.astype(x.dtype) * g)
        y = jnp.einsum("bshk,hkd->bsd", out, lp["wo"])
        return y, x[:, -1, :], state

    def _channel_mix(self, lp, x, x_prev_tok):
        xs = jnp.concatenate([x_prev_tok[:, None, :], x[:, :-1, :]], axis=1)
        dx = xs - x
        xk = x + dx * lp["cm_mu_k"]
        xr = x + dx * lp["cm_mu_r"]
        k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, lp["cm_wk"])))
        kv = jnp.einsum("bsf,fd->bsd", k, lp["cm_wv"])
        r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, lp["cm_wr"]))
        return r * kv, x[:, -1, :]

    # ------------------------------------------------------------- forward
    def _stack(self, params, x, states, collect_states: bool = True):
        """states: dict of stacked (L, ...) carries."""
        blocks = {k.split("/", 1)[1]: v for k, v in params.items()
                  if k.startswith("blocks/")}

        def body(x, lp_state):
            lp, st = lp_state
            h, tm_tok, s_new = self._time_mix(
                lp, cm.rms_norm(x, lp["tm_norm"]), st["tm_tok"], st["wkv"])
            x = x + h
            h, cm_tok = self._channel_mix(
                lp, cm.rms_norm(x, lp["cm_norm"]), st["cm_tok"])
            x = _sh.constrain_batch(x + h)
            if not collect_states:
                return x, None
            return x, {"wkv": s_new, "tm_tok": tm_tok, "cm_tok": cm_tok}

        if self.remat:
            body = jax.checkpoint(body)
        x, new_states = lax.scan(body, x, (blocks, states))
        return x, new_states

    def _zero_states(self, B, dtype):
        cfg, H, hd = self.cfg, self.n_heads, self.cfg.resolved_head_dim
        L, d = cfg.num_layers, cfg.d_model
        states = {
            "wkv": jnp.zeros((L, B, H, hd, hd), jnp.float32),
            "tm_tok": jnp.zeros((L, B, d), dtype),
            "cm_tok": jnp.zeros((L, B, d), dtype),
        }
        axes = {
            "wkv": ("layers", "batch", "heads", "head_dim", "head_dim2"),
            "tm_tok": ("layers", "batch", "embed"),
            "cm_tok": ("layers", "batch", "embed"),
        }
        return states, axes

    def loss(self, params, batch):
        tokens = batch["tokens"]
        x = params["embed"][tokens]
        states, _ = self._zero_states(tokens.shape[0], x.dtype)
        x, _ = self._stack(params, x, states, collect_states=False)
        x = cm.rms_norm(x, params["final_norm"])
        loss = cm.lm_loss(x, params["unembed"], batch["labels"],
                          batch.get("mask", None))
        return loss, {"xent": loss, "aux": jnp.zeros((), jnp.float32)}

    # ----------------------------------------------------------- serve api
    def init_cache(self, B, cache_len, dtype=jnp.bfloat16):
        states, axes = self._zero_states(B, dtype)
        states["pos"] = jnp.zeros((), jnp.int32)
        axes["pos"] = ()
        return states, axes

    def prefill(self, params, tokens, frontend=None, pad_to: int = 0):
        x = params["embed"][tokens]
        states, _ = self._zero_states(tokens.shape[0], x.dtype)
        x, states = self._stack(params, x, states)
        x = cm.rms_norm(x[:, -1:, :], params["final_norm"])
        lg = jnp.einsum("bsd,dv->bsv", x, params["unembed"])[:, 0]
        states["pos"] = jnp.asarray(tokens.shape[1], jnp.int32)
        return lg, states

    def decode_step(self, params, cache, tokens):
        x = params["embed"][tokens][:, None, :]
        pos = cache["pos"]
        states = {k: v for k, v in cache.items() if k != "pos"}
        x, states = self._stack(params, x, states)
        x = cm.rms_norm(x, params["final_norm"])
        lg = jnp.einsum("bsd,dv->bsv", x, params["unembed"])[:, 0]
        states["pos"] = pos + 1
        return lg, states
