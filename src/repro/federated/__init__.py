from repro.federated.runtime import TaskResult, run_async, run_sync, run_task
from repro.federated.real import RealLearner
from repro.federated.surrogate import SurrogateLearner
