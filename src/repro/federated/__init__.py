from repro.federated.runtime import (
    STRATEGIES, AsyncStrategy, RoundEvent, Strategy, SyncStrategy, TaskResult,
    get_strategy, register_strategy, run_async, run_sync, run_task,
)
from repro.federated.real import RealLearner
from repro.federated.surrogate import SurrogateLearner
