"""Real JAX learner: actual federated training of any model-zoo config.

Holds server params + FedAdam state, compiles the client local-SGD step
once (ragged client datasets are padded into a fixed scan length), and —
for FedBuff — keeps a ring of recent param versions so stale clients
really do train against the model they were sent (true staleness, not an
approximation). Deltas optionally round-trip the int8 wire codec.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig, RunConfig
from repro.data.synthetic import FederatedDataset
from repro.federated import aggregation
from repro.federated.client import make_client_update, stack_batches
from repro.models import get_model
from repro.optim import server_optimizer


class RealLearner:
    real = True

    def __init__(self, model_cfg: ModelConfig, fed: FederatedConfig,
                 run: RunConfig, dataset: FederatedDataset,
                 max_client_steps: int = 8, seed: int = 0):
        self.cfg = model_cfg
        self.fed = fed
        self.run = run
        self.dataset = dataset
        self.max_steps = max_client_steps
        self.model = get_model(model_cfg)
        rng = jax.random.PRNGKey(seed)
        self.params, self.axes = self.model.init(rng, dtype=jnp.float32)
        self.opt = server_optimizer(fed.server_optimizer, fed.server_lr,
                                    b1=fed.adam_beta1, b2=fed.adam_beta2,
                                    eps=fed.adam_eps)
        self.opt_state = self.opt.init(self.params)
        self._client_update = make_client_update(self.model.loss, fed.client_lr)
        self.version = 0
        self._history: List[Tuple[int, Dict[str, np.ndarray]]] = []
        self._push_history()
        self._eval_batch = None

        def server_step(params, opt_state, mean_delta):
            # FedAdam: server "gradient" is the negative aggregated delta
            grads = {k: -v for k, v in mean_delta.items()}
            return self.opt.update(grads, opt_state, params)

        self._server_step = jax.jit(server_step)

    # -------------------------------------------------------------- history
    def _push_history(self):
        self._history.append((self.version, jax.device_get(self.params)))
        cap = max(2, self.fed.staleness_cap)
        if len(self._history) > cap:
            self._history.pop(0)

    def params_at(self, version: int):
        for v, p in reversed(self._history):
            if v <= version:
                return p
        return self._history[0][1]

    # -------------------------------------------------------------- learner
    def client_deltas(self, client_ids, version: Optional[int] = None):
        """Vmapped cohort update (true cross-device simulation): all clients
        train in parallel from the same server params — one compiled call
        per round instead of len(cohort) sequential ones."""
        base = self.params if version is None or version == self.version \
            else self.params_at(version)
        stacked_all, masks, n_ex = [], [], []
        for cid in client_ids:
            batches = self.dataset.client_batches(
                cid, self.fed.client_batch_size, self.fed.local_epochs)
            st, m = stack_batches(batches, self.max_steps)
            stacked_all.append(st)
            masks.append(m)
            n_ex.append(min(len(batches), self.max_steps)
                        * self.fed.client_batch_size)
        cohort = {k: np.stack([s[k] for s in stacked_all])
                  for k in stacked_all[0]}
        cmask = np.stack(masks)
        if not hasattr(self, "_vmapped_update"):
            self._vmapped_update = jax.jit(jax.vmap(
                self._client_update._fun
                if hasattr(self._client_update, "_fun") else
                self._client_update, in_axes=(None, 0, 0)))
        deltas, _ = self._vmapped_update(base, cohort, cmask)
        if self.fed.compression == "int8":
            deltas = aggregation.compress_roundtrip(
                deltas, block=self.fed.quant_block)
        out = jax.device_get(deltas)
        return [{k: v[i] for k, v in out.items()}
                for i in range(len(client_ids))], [float(n) for n in n_ex]

    def client_delta(self, client_id: int, version: Optional[int] = None):
        """Run real local training; returns (delta dict, example weight)."""
        base = self.params if version is None or version == self.version \
            else self.params_at(version)
        batches = self.dataset.client_batches(
            client_id, self.fed.client_batch_size, self.fed.local_epochs)
        stacked, mask = stack_batches(batches, self.max_steps)
        delta, _ = self._client_update(base, stacked, mask)
        if self.fed.compression == "int8":
            delta = aggregation.compress_roundtrip(delta,
                                                   block=self.fed.quant_block)
        n_ex = min(len(batches), self.max_steps) * self.fed.client_batch_size
        return jax.device_get(delta), float(n_ex)

    def apply(self, deltas: List[Dict[str, np.ndarray]], weights: List[float],
              *, n_contributors: int = 0, mean_staleness: float = 0.0,
              staleness: Optional[List[int]] = None) -> None:
        assert deltas, "apply() with empty buffer"
        w = np.asarray(weights, np.float32)
        if staleness is not None:  # FedBuff staleness scaling
            w = w * aggregation.fedbuff_weights(staleness,
                                                self.fed.staleness_exponent)
        stacked = {k: jnp.stack([d[k] for d in deltas]) for k in deltas[0]}
        mean_delta = aggregation.weighted_mean_deltas(stacked, jnp.asarray(w))
        self.params, self.opt_state = self._server_step(
            self.params, self.opt_state, mean_delta)
        self.version += 1
        self._push_history()

    def eval_perplexity(self) -> float:
        if self._eval_batch is None:
            self._eval_batch = self.dataset.eval_batch(
                self.run.eval_clients, batch_size=32)
            self._eval_fn = jax.jit(lambda p, b: self.model.loss(p, b)[0])
        loss = self._eval_fn(self.params, self._eval_batch)
        return float(np.exp(np.clip(np.asarray(loss), 0, 20)))
