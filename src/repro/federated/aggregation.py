"""Server-side aggregation (the PAPAYA Aggregator) + FedAdam update.

Sync (FedAvg): example-weighted mean of client deltas.
Async (FedBuff): staleness-scaled mean over the buffer, weight
(1+staleness)^-alpha (Nguyen et al. 2022).

Wire compression (paper §6 lever): deltas optionally round-trip through the
blockwise-int8 codec (kernels/int8_quant) before entering the buffer,
exactly like a production uplink would — so its quality effect (if any) is
part of the training loop, not just an accounting trick.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.int8_quant import ops as q8


def compress_roundtrip(delta: Dict[str, jnp.ndarray], block: int = 256,
                       use_pallas: bool = False) -> Dict[str, jnp.ndarray]:
    """Simulate the int8 uplink: quantize + dequantize each leaf."""
    return {k: q8.quant_dequant(v, block=block, use_pallas=use_pallas)
            for k, v in delta.items()}


@jax.jit
def weighted_mean_deltas(deltas: Dict[str, jnp.ndarray],
                         weights: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """deltas: dict of (N, ...) stacked client deltas; weights: (N,)."""
    w = weights / jnp.maximum(jnp.sum(weights), 1e-12)

    def avg(v):
        wb = w.reshape((-1,) + (1,) * (v.ndim - 1))
        return jnp.sum(v * wb, axis=0)

    return {k: avg(v) for k, v in deltas.items()}


def fedbuff_weights(staleness: Sequence[int], alpha: float) -> np.ndarray:
    s = np.asarray(staleness, np.float64)
    return (1.0 + s) ** (-alpha)
