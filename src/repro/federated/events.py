"""Session event sampling: device heterogeneity -> durations -> outcomes.

This is the simulator twin of the paper's production logger: for every
selected client we draw a device (fleet popularity weights) and a country
(participation mix), derive download/compute/upload durations from model
bytes, client data volume and device throughput, then resolve the outcome
(completed / dropped mid-session / 4-minute timeout). All durations carry a
lognormal jitter (thermal throttling, background load, link variance).

The engine is columnar: ``plan_batch``/``resolve_batch`` plan and resolve a
whole cohort in a handful of NumPy ops (array-vectorized splitmix64 counter
randomness, Box–Muller lognormal jitter, inverse-CDF Lomax sampling) and
return a ``PlanBatch``/``SessionBatch`` of columns. The scalar ``plan``/
``resolve`` are thin wrappers over batch size 1; ``plan_scalar``/
``resolve_scalar`` keep the original pure-Python path as the reference
implementation for equivalence tests and the runtime benchmark baseline.

``LaneSampler`` lifts the same columnar pass across the *spec* axis: L
compatible samplers (one per sweep lane, each with its own seed and
environment constants) plan/resolve as one ``(lane, batch)``-shaped batch,
bit-identical per row to each lane's own sampler — the substrate of the
lane-batched sweep engine in ``repro.federated.runtime``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Dict, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig
from repro.core.availability import AvailabilityModel, exit_times
from repro.core.faults import FaultModel
from repro.core.profiles import (COUNTRY_MIX, DOWNLOAD_BPS, FLEET, UPLOAD_BPS,
                                 DeviceProfile)
from repro.core.telemetry import OUTCOME_CODE, SessionBatch
from repro.data.synthetic import client_num_samples
from repro.kernels.int8_quant.ops import wire_bytes

_JITTER_SIGMA = 0.35
_M64 = (1 << 64) - 1
_U64 = np.uint64
_GOLDEN = 0x9E3779B97F4A7C15


def _splitmix64(x: int) -> int:
    """splitmix64 on python ints — cheap deterministic per-session
    randomness (np.random.default_rng construction is ~50us; this is <1us)."""
    x = (x + _GOLDEN) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


_INV53 = 1.0 / float(1 << 53)


def _uniforms(seed: int, client_id: int, round_idx: int, n: int):
    base = (((seed * 1_000_003 + round_idx) & 0xFFFFFFFF) * 2_654_435_761
            + (client_id & _M64) * 97) & _M64
    return [(_splitmix64((base + i * _GOLDEN) & _M64) >> 11)
            * _INV53 for i in range(n)]


def _splitmix64_arr(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 on uint64 arrays (wrapping semantics match the
    masked python-int version bit for bit)."""
    x = x + _U64(_GOLDEN)
    x = (x ^ (x >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> _U64(27))) * _U64(0x94D049BB133111EB)
    return x ^ (x >> _U64(31))


_LANES: dict = {}


def _lane_offsets(n: int) -> np.ndarray:
    try:
        return _LANES[n]
    except KeyError:
        _LANES[n] = np.arange(n, dtype=np.uint64) * _U64(_GOLDEN)
        return _LANES[n]


def _uniforms_batch(seed: int, client_ids: np.ndarray, round_idx: int,
                    n: int) -> np.ndarray:
    """(B, n) uniforms in [0,1); column i equals the scalar ``_uniforms``
    draw i for that (seed, client_id, round_idx) exactly."""
    cid = np.asarray(client_ids).astype(np.uint64)
    base0 = _U64((seed * 1_000_003 + round_idx) & 0xFFFFFFFF)
    with np.errstate(over="ignore"):
        base = base0 * _U64(2_654_435_761) + cid * _U64(97)
        lanes = _lane_offsets(n)
        vals = _splitmix64_arr(base[:, None] + lanes[None, :])
    return (vals >> _U64(11)).astype(np.float64) * _INV53


def _plan_uniforms(seed: int, cid: np.ndarray, round_idx: int) -> np.ndarray:
    """The planner's 9 uniforms in one splitmix pass: columns 0..7 are the
    (seed, cid, round_idx) draws 0..7, column 8 is the (seed, cid, 0)
    draw 0 (the round-independent data-volume draw). Bit-identical to the
    two separate ``_uniforms_batch`` calls it replaces — one array pass
    instead of two matters because the async window merge issues many
    small dispatch batches."""
    with np.errstate(over="ignore"):
        base_r = _U64((seed * 1_000_003 + round_idx) & 0xFFFFFFFF) \
            * _U64(2_654_435_761) + cid * _U64(97)
        base_0 = _U64((seed * 1_000_003) & 0xFFFFFFFF) \
            * _U64(2_654_435_761) + cid * _U64(97)
        keys = np.empty((len(cid), 9), np.uint64)
        keys[:, :8] = base_r[:, None] + _lane_offsets(8)[None, :]
        keys[:, 8] = base_0
        vals = _splitmix64_arr(keys)
    return (vals >> _U64(11)).astype(np.float64) * _INV53


def _plan_uniforms_rows(seeds: np.ndarray, cid: np.ndarray,
                        round_idx: int) -> np.ndarray:
    """``_plan_uniforms`` with a per-row seed (uint64): the lane-batched
    engine keys every row's randomness on its lane's seed, so one splitmix
    pass plans a whole lane pack. Bit-identical per row to the scalar-seed
    version — uint64 wraparound is mod 2**64 and ``& 0xFFFFFFFF`` of a
    Python int picks the same low 32 bits."""
    with np.errstate(over="ignore"):
        base_r = ((seeds * _U64(1_000_003) + _U64(round_idx))
                  & _U64(0xFFFFFFFF)) * _U64(2_654_435_761) + cid * _U64(97)
        base_0 = ((seeds * _U64(1_000_003))
                  & _U64(0xFFFFFFFF)) * _U64(2_654_435_761) + cid * _U64(97)
        keys = np.empty((len(cid), 9), np.uint64)
        keys[:, :8] = base_r[:, None] + _lane_offsets(8)[None, :]
        keys[:, 8] = base_0
        vals = _splitmix64_arr(keys)
    return (vals >> _U64(11)).astype(np.float64) * _INV53


def _uniforms_batch_rows(seeds: np.ndarray, client_ids: np.ndarray,
                         round_idx: int, n: int) -> np.ndarray:
    """``_uniforms_batch`` with a per-row seed (see _plan_uniforms_rows)."""
    cid = np.asarray(client_ids).astype(np.uint64)
    with np.errstate(over="ignore"):
        base = ((seeds * _U64(1_000_003) + _U64(round_idx))
                & _U64(0xFFFFFFFF)) * _U64(2_654_435_761) + cid * _U64(97)
        vals = _splitmix64_arr(base[:, None] + _lane_offsets(n)[None, :])
    return (vals >> _U64(11)).astype(np.float64) * _INV53


def _fused_uniforms_rows(seeds: np.ndarray, cid: np.ndarray,
                         round_idx: int) -> np.ndarray:
    """Plan + resolve uniforms in ONE splitmix pass: columns 0..8 are the
    planner draws (see ``_plan_uniforms``), columns 9..10 the outcome
    draws (key base ``round_idx + 1_000_000``). Bit-identical per column
    to the two separate passes — every lane-loop dispatch plans and
    resolves back-to-back, so fusing halves the per-call fixed cost."""
    with np.errstate(over="ignore"):
        base_r = ((seeds * _U64(1_000_003) + _U64(round_idx))
                  & _U64(0xFFFFFFFF)) * _U64(2_654_435_761) + cid * _U64(97)
        base_0 = ((seeds * _U64(1_000_003))
                  & _U64(0xFFFFFFFF)) * _U64(2_654_435_761) + cid * _U64(97)
        base_v = ((seeds * _U64(1_000_003) + _U64(round_idx + 1_000_000))
                  & _U64(0xFFFFFFFF)) * _U64(2_654_435_761) + cid * _U64(97)
        keys = np.empty((len(cid), 11), np.uint64)
        keys[:, :8] = base_r[:, None] + _lane_offsets(8)[None, :]
        keys[:, 8] = base_0
        keys[:, 9:11] = base_v[:, None] + _lane_offsets(2)[None, :]
        vals = _splitmix64_arr(keys)
    return (vals >> _U64(11)).astype(np.float64) * _INV53


_SLOT_MIX = 0xD1342543DE82EF95   # per-slot lane spacing (distinct from _GOLDEN)


def slot_stream_ids(seed: int, slots: Union[np.ndarray, Sequence[int]],
                    generations: Union[np.ndarray, Sequence[int]],
                    population: int) -> np.ndarray:
    """Counter-based replacement-id streams for the async engine: the g-th
    replacement dispatched into in-flight slot s draws client id
    ``splitmix64((seed, s, g))`` — a deterministic function of the slot and
    its replacement count alone. Identity never depends on global arrival
    order, which is what lets ``AsyncStrategy`` resolve whole windows of
    chained replacements columnar-ly instead of popping a heap."""
    s = np.asarray(slots, dtype=np.uint64)
    g = np.asarray(generations, dtype=np.uint64)
    base0 = _U64(((seed & 0xFFFFFFFF) * 0x9E3779B9 + 0x7F4A7C15) & _M64)
    with np.errstate(over="ignore"):
        h = _splitmix64_arr(base0 + s * _U64(_SLOT_MIX) + g * _U64(_GOLDEN))
    u = (h >> _U64(11)).astype(np.float64) * _INV53
    return (u * population).astype(np.int64)


def slot_stream_id(seed: int, slot: int, generation: int,
                   population: int) -> int:
    """Scalar twin of ``slot_stream_ids`` (used by the reference oracle);
    pure python-int splitmix so the scalar engine stays numpy-free on its
    per-pop path — bit-identical to the batch version."""
    base = ((seed & 0xFFFFFFFF) * 0x9E3779B9 + 0x7F4A7C15) & _M64
    h = _splitmix64((base + slot * _SLOT_MIX + generation * _GOLDEN) & _M64)
    return int((h >> 11) * _INV53 * population)


_RESERVOIR_MIX = 0x2545F4914F6CDD1D   # reservoir-key lane for streaming logs


def reservoir_keys(seed: int,
                   indices: Union[np.ndarray, Sequence[int]]) -> np.ndarray:
    """Raw uint64 splitmix keys for the streaming-telemetry reservoir:
    session ``i`` (its global, engine-order index within the task) hashes
    to ``splitmix64((seed, i))`` along a dedicated ``_RESERVOIR_MIX`` lane
    so reservoir keys never alias the planner / outcome / slot / probe
    streams. The retained sample is the bottom-k of these keys — a pure
    function of ``(seed, global session index)``, so it is identical
    regardless of chunk size, lane packing, or worker count."""
    idx = np.asarray(indices, dtype=np.uint64)
    base0 = _U64(((seed & 0xFFFFFFFF) * 0x9E3779B9 + 0x7F4A7C15) & _M64)
    with np.errstate(over="ignore"):
        return _splitmix64_arr(base0 + idx * _U64(_RESERVOIR_MIX))


_RETRY_MIX = 0xE7037ED1A0B428DB   # retry-id lane spacing (recovery policy)


def retry_stream_ids(seed: int, units: Union[np.ndarray, Sequence[int]],
                     attempts: Union[np.ndarray, Sequence[int]],
                     population: int) -> np.ndarray:
    """Counter-based retry-id streams for the recovery policy: the a-th
    retry re-dispatched for recovery unit u draws client id
    ``splitmix64((seed, u, a))`` along a dedicated ``_RETRY_MIX`` lane, so
    retry identities never alias the plain replacement streams (async:
    u = in-flight slot, a = generation; sync: u = cohort position,
    a = round * (retry_limit + 1) + attempt). Pure counter functions keep
    serial, lane-batched and oracle retry chains seed-for-seed identical."""
    s = np.asarray(units, dtype=np.uint64)
    g = np.asarray(attempts, dtype=np.uint64)
    base0 = _U64(((seed & 0xFFFFFFFF) * 0x9E3779B9 + 0x7F4A7C15) & _M64)
    with np.errstate(over="ignore"):
        h = _splitmix64_arr(base0 + s * _U64(_RETRY_MIX) + g * _U64(_GOLDEN))
    u = (h >> _U64(11)).astype(np.float64) * _INV53
    return (u * population).astype(np.int64)


def retry_stream_id(seed: int, unit: int, attempt: int,
                    population: int) -> int:
    """Scalar twin of ``retry_stream_ids`` (the reference oracle's path) —
    bit-identical to the batch version."""
    base = ((seed & 0xFFFFFFFF) * 0x9E3779B9 + 0x7F4A7C15) & _M64
    h = _splitmix64((base + unit * _RETRY_MIX + attempt * _GOLDEN) & _M64)
    return int((h >> 11) * _INV53 * population)


_PROBE_MIX = 0xA0761D6478BD642F   # probe-lane spacing for carbon-aware picks


def probe_uniforms(seed: int, slots: Union[np.ndarray, Sequence[int]],
                   generations: Union[np.ndarray, Sequence[int]],
                   n: int) -> np.ndarray:
    """(B, n) per-(slot, generation) selection-probe uniform streams for
    the carbon-aware coordinator: column 0 is the exploration draw,
    columns 1.. are candidate-id draws. Keyed like ``slot_stream_ids`` but
    spread along a distinct probe lane (``_PROBE_MIX``), so carbon-aware
    probing never aliases the plain async replacement streams. Identity
    stays a pure counter function of (seed, slot, generation, probe) —
    independent of global arrival order, which is what lets the async
    window merge, the lane engine and the scalar oracle all replay the
    same picks."""
    s = np.asarray(slots, dtype=np.uint64)
    g = np.asarray(generations, dtype=np.uint64)
    base0 = _U64(((seed & 0xFFFFFFFF) * 0x9E3779B9 + 0x7F4A7C15) & _M64)
    lanes = (np.arange(1, n + 1, dtype=np.uint64)) * _U64(_PROBE_MIX)
    with np.errstate(over="ignore"):
        base = base0 + s * _U64(_SLOT_MIX) + g * _U64(_GOLDEN)
        h = _splitmix64_arr(base[:, None] + lanes[None, :])
    return (h >> _U64(11)).astype(np.float64) * _INV53


def _lognormal(u1: float, u2: float, sigma: float) -> float:
    # Box-Muller
    r = math.sqrt(-2.0 * math.log(max(u1, 1e-12)))
    return math.exp(sigma * r * math.cos(2.0 * math.pi * u2))


def _lognormal_arr(u1: np.ndarray, u2: np.ndarray,
                   sigma: float) -> np.ndarray:
    r = np.sqrt(-2.0 * np.log(np.maximum(u1, 1e-12)))
    return np.exp(sigma * r * np.cos(2.0 * np.pi * u2))


def _pareto_samples(u: float, mean: float = 34.0, shape: float = 1.8) -> int:
    # inverse-CDF Lomax with E = scale/(shape-1)
    scale = mean * (shape - 1.0)
    n = int(scale * ((max(1.0 - u, 1e-12)) ** (-1.0 / shape) - 1.0)) + 1
    return max(2, min(n, 4096))


def _pareto_samples_arr(u: np.ndarray, mean: float = 34.0,
                        shape: float = 1.8) -> np.ndarray:
    scale = mean * (shape - 1.0)
    x = scale * (np.maximum(1.0 - u, 1e-12) ** (-1.0 / shape) - 1.0)
    return np.clip(x.astype(np.int64) + 1, 2, 4096)


@dataclass(frozen=True)
class SessionPlan:
    """Durations + bytes for one client session, before outcome resolution."""
    client_id: int
    device: DeviceProfile
    country: str
    download_s: float
    compute_s: float
    upload_s: float
    bytes_down: float
    bytes_up: float
    n_examples: int


@dataclass(frozen=True)
class PlanBatch:
    """A planned cohort as columns (`device_idx` indexes the sampler's
    fleet, `country_idx` its country list)."""
    client_ids: np.ndarray       # int64
    device_idx: np.ndarray       # int32
    country_idx: np.ndarray      # int32
    download_s: np.ndarray       # float64
    compute_s: np.ndarray
    upload_s: np.ndarray
    bytes_down: np.ndarray
    bytes_up: np.ndarray
    n_examples: np.ndarray       # int64

    def __len__(self) -> int:
        return int(self.client_ids.shape[0])


class SessionSampler:
    def __init__(self, model_cfg: ModelConfig, fed: FederatedConfig,
                 seq_len: int, param_bytes: Optional[float] = None,
                 fleet: Optional[Sequence[DeviceProfile]] = None,
                 country_mix: Optional[Mapping[str, float]] = None,
                 download_bps: Optional[float] = None,
                 upload_bps: Optional[float] = None,
                 fault: Optional[FaultModel] = None,
                 availability: Optional[AvailabilityModel] = None):
        self.cfg = model_cfg
        self.fed = fed
        self.seq_len = seq_len
        fleet = tuple(fleet) if fleet is not None else FLEET
        country_mix = dict(country_mix) if country_mix is not None \
            else COUNTRY_MIX
        self.fleet = fleet
        self.download_bps = download_bps or DOWNLOAD_BPS
        self.upload_bps = upload_bps or UPLOAD_BPS
        n_params = model_cfg.param_count()
        self.n_params = n_params
        full = 4.0 * n_params  # f32 on the wire
        if fed.compression == "int8":
            self.bytes_down = float(wire_bytes(n_params, fed.quant_block))
            self.bytes_up = float(wire_bytes(n_params, fed.quant_block))
            self.compute_overhead = 1.05   # on-device (de)quant cost
        else:
            self.bytes_down = param_bytes or full
            self.bytes_up = param_bytes or full
            self.compute_overhead = 1.0
        self.flops_per_token = model_cfg.train_flops_per_token()
        self._countries = list(country_mix)
        cw = np.asarray(list(country_mix.values()), np.float64)
        self._ccum = np.cumsum(cw / cw.sum())
        dw = np.asarray([p.weight for p in fleet], np.float64)
        self._dcum = np.cumsum(dw / dw.sum())
        self._gflops = np.asarray([p.train_gflops for p in fleet], np.float64)
        self.device_names: Tuple[str, ...] = tuple(p.name for p in fleet)
        self.country_names: Tuple[str, ...] = tuple(self._countries)
        if fed.mode == "carbon-aware" and fed.carbon_topk > len(
                self._countries):
            raise ValueError(
                f"carbon_topk ({fed.carbon_topk}) exceeds the country "
                f"vocabulary ({len(self._countries)} countries in the "
                "participation mix)")
        # fault injection: a disabled (all-zero) model keeps has_faults
        # False and every resolve path runs the fault-free code verbatim
        self.fault = fault
        self.has_faults = fault is not None and fault.enabled
        if self.has_faults:
            self._hazard_tab = fault.hazard_table(self.country_names)
            self._burst_start, self._burst_end = fault.burst_windows()
            self._burst_p = fault.burst_fail_prob
        # availability: a disabled (all-available) model keeps has_avail
        # False and every resolve path runs the availability-free code
        # verbatim — the admission/churn uniform is never even drawn
        self.availability = availability
        self.has_avail = availability is not None and availability.enabled
        if self.has_avail:
            self._avail_tab = availability.eligibility_table(
                self.country_names)

    def country_draw(self, client_ids: Union[np.ndarray, Sequence[int]],
                     round_idx: int) -> np.ndarray:
        """Just the country column of ``plan_batch`` (uniform draw 1 of
        the planner's splitmix pass) — what the carbon-aware coordinator
        uses to screen candidate ids without planning full sessions.
        Bit-identical to the ``country_idx`` a subsequent ``plan_batch``
        of the same ids would produce."""
        cid = np.asarray(client_ids, np.int64).astype(np.uint64)
        with np.errstate(over="ignore"):
            base_r = _U64((self.fed.seed * 1_000_003 + round_idx)
                          & 0xFFFFFFFF) * _U64(2_654_435_761) \
                + cid * _U64(97)
            vals = _splitmix64_arr(base_r + _U64(_GOLDEN))
        u1 = (vals >> _U64(11)).astype(np.float64) * _INV53
        return np.searchsorted(self._ccum, u1).astype(np.int32)

    # ------------------------------------------------------- availability
    def admission_uniforms(self, client_ids: Union[np.ndarray,
                                                   Sequence[int]],
                           round_idx: int) -> np.ndarray:
        """The availability-model admission/churn uniform for each
        ``(seed, client_id, round_idx)`` — a dedicated counter stream
        (key base ``round_idx + 3_000_000``) so it never aliases the
        planner, outcome or fault draws. The carbon-aware coordinator
        re-derives these to screen candidates; bit-identical to the draw
        a subsequent ``resolve_batch`` of the same ids consumes."""
        return _uniforms_batch(self.fed.seed, client_ids,
                               round_idx + 3_000_000, 1)[:, 0]

    def _avail_masks(self, country_idx: np.ndarray, start: np.ndarray,
                     ua: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Availability overlay for one cohort: ``(not_admitted,
        exit_t)``. A device is eligible exactly while ``ua <
        eligibility(t)`` — one uniform couples admission (at ``start``)
        and mid-flight churn (the first segment boundary where the curve
        falls to or below the draw). Element-wise, so any per-lane subset
        of a pack reproduces this bit for bit."""
        e0 = self._avail_tab.at(country_idx, start)
        return ua >= e0, exit_times(self._avail_tab, country_idx, ua, start)

    # ----------------------------------------------------------- faults
    def _fault_masks(self, country_idx: np.ndarray, start: np.ndarray,
                     end_full: np.ndarray, full: np.ndarray,
                     uf: np.ndarray, pre: np.ndarray
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Fault overlay for one cohort: ``(failed, fault_burn)``.

        ``uf`` is the 3-column fault-uniform block (hazard draw, burst
        draw, hazard burn point); ``pre`` masks rows already resolved by a
        higher-precedence outcome (dropout, timeout). A hazard failure
        dies at a random point of its span; a burst failure dies the
        moment the first overlapping outage window opens (sessions born
        inside a window die instantly). Everything is element-wise, so
        any per-lane subset of a pack reproduces this bit for bit."""
        hz = self._hazard_tab.at(country_idx, start)
        fh = ~pre & (uf[:, 0] < hz)
        nb = len(self._burst_start)
        if nb:
            # first window whose end is past our start; overlaps iff it
            # also opens before our end (starts are strictly increasing)
            i = np.searchsorted(self._burst_end, start, side="right")
            valid = i < nb
            bs = self._burst_start[np.minimum(i, nb - 1)]
            fb = ~pre & ~fh & valid & (bs < end_full) \
                & (uf[:, 1] < self._burst_p)
            t_hit = np.maximum(start, bs)
        else:
            fb = np.zeros(len(start), bool)
            t_hit = start
        fburn = np.where(fh, uf[:, 2] * full,
                         np.clip(t_hit - start, 0.0, full))
        return fh | fb, fburn

    # ------------------------------------------------------------ columnar
    def plan_batch(self, client_ids: Union[np.ndarray, Sequence[int]],
                   round_idx: int) -> PlanBatch:
        """Plan a whole cohort in a handful of NumPy ops. Column i of the
        uniform block matches scalar draw i, so this reproduces
        ``plan_scalar`` per client bit-for-bit (modulo libm ulps)."""
        ids = np.asarray(client_ids, np.int64)
        u = _plan_uniforms(self.fed.seed, ids.astype(np.uint64), round_idx)
        dev = np.searchsorted(self._dcum, u[:, 0]).astype(np.int32)
        ctry = np.searchsorted(self._ccum, u[:, 1]).astype(np.int32)
        n_ex = _pareto_samples_arr(u[:, 8])
        tokens = n_ex * (self.seq_len * self.fed.local_epochs)
        # one Box-Muller pass over the three (u1, u2) jitter pairs —
        # columns (2,3) compute, (4,5) download, (6,7) upload
        jit = _lognormal_arr(u[:, 2:8:2], u[:, 3:8:2], _JITTER_SIGMA)
        compute_s = (tokens * self.flops_per_token * self.compute_overhead
                     / (self._gflops[dev] * 1e9)) * jit[:, 0]
        download_s = 8.0 * self.bytes_down / self.download_bps * jit[:, 1]
        upload_s = 8.0 * self.bytes_up / self.upload_bps * jit[:, 2]
        n = len(ids)
        return PlanBatch(ids, dev, ctry, download_s, compute_s, upload_s,
                         np.full(n, self.bytes_down),
                         np.full(n, self.bytes_up), n_ex)

    def resolve_batch(self, pb: PlanBatch, round_idx: int,
                      start_t: Union[float, np.ndarray],
                      deadline: Optional[float] = None,
                      late_code: Optional[int] = None
                      ) -> Tuple[SessionBatch, np.ndarray]:
        """Resolve a planned cohort's outcomes; returns ``(batch, ok)``
        where ``ok[i]`` is True iff session i completed (contributed).

        start_t may be a scalar or a per-client array of task-clock starts;
        deadline is the absolute task-clock time after which the round no
        longer accepts results (sync round close / over-selection cancel);
        late_code relabels deadline-cut rows (default "dropped" — the sync
        over-selection path passes "cancelled" so the surplus it invited is
        visibly its own doing). Downlink bytes are prorated by the
        completed download fraction so a client dropped mid-download isn't
        charged the full payload."""
        fed = self.fed
        n = len(pb)
        uu = _uniforms_batch(fed.seed, pb.client_ids, round_idx + 1_000_000, 2)
        full_d, full_c, full_u = pb.download_s, pb.compute_s, pb.upload_s
        start = np.broadcast_to(np.asarray(start_t, np.float64), (n,))
        full = full_d + full_c + full_u
        # same association order as the scalar reference, so the session
        # whose end DEFINES the round deadline compares equal (not late)
        end_full = start + full_d + full_c + full_u

        dropped = uu[:, 0] < fed.dropout_rate
        timeout = ~dropped & (full_c > fed.client_timeout_s)
        if self.has_avail:
            # inadmissible devices interrupt at zero cost; admitted ones
            # interrupt mid-flight when their curve dips to their draw
            ua = self.admission_uniforms(pb.client_ids, round_idx)
            not_adm, exit_t = self._avail_masks(pb.country_idx, start, ua)
            churned = ~not_adm & ~dropped & ~timeout & (exit_t < end_full)
            inter = not_adm | churned
            iburn = np.where(not_adm, 0.0,
                             np.minimum(np.maximum(exit_t - start, 0.0),
                                        full))
            dropped &= ~not_adm
            timeout &= ~not_adm
        else:
            inter = None
        if self.has_faults:
            pre = dropped | timeout
            if inter is not None:
                pre = pre | inter
            uf = _uniforms_batch(fed.seed, pb.client_ids,
                                 round_idx + 2_000_000, 3)
            failed, fburn = self._fault_masks(pb.country_idx, start,
                                              end_full, full, uf, pre)
        else:
            failed = None
        if deadline is not None:
            late = ~dropped & ~timeout & (end_full > deadline)
            if failed is not None:
                late &= ~failed
            if inter is not None:
                late &= ~inter
        else:
            late = np.zeros(n, bool)
        # burn budget for the cut-short sessions: dropout picks a random
        # stop point, a deadline cut burns until the round closes
        burn = uu[:, 1] * full
        if deadline is not None:
            burn = np.where(late, np.maximum(0.0, deadline - start), burn)
        cut = dropped | late
        if failed is not None:
            burn = np.where(failed, fburn, burn)
            cut = cut | failed
        if inter is not None:
            burn = np.where(inter, iburn, burn)
            cut = cut | inter
        d = np.where(cut, np.minimum(full_d, burn), full_d)
        c = np.where(cut, np.minimum(full_c,
                                     np.maximum(0.0, burn - full_d)),
                     full_c)
        u = np.where(cut, np.minimum(full_u,
                                     np.maximum(0.0, burn - full_d - full_c)),
                     full_u)
        # the 4-minute training timeout truncates compute and skips upload
        c = np.where(timeout, fed.client_timeout_s, c)
        u = np.where(timeout, 0.0, u)
        end = np.where(dropped, start + burn, end_full)
        end = np.where(timeout, start + full_d + fed.client_timeout_s, end)
        if failed is not None:
            end = np.where(failed, start + fburn, end)
        if inter is not None:
            end = np.where(inter, start + iburn, end)
        if deadline is not None:
            # retries may start after the round closed: never end < start
            end = np.where(late, np.maximum(start, deadline), end)

        outcome = np.zeros(n, np.int8)  # completed
        outcome[cut] = OUTCOME_CODE["dropped"]
        outcome[timeout] = OUTCOME_CODE["timeout"]
        if failed is not None:
            outcome[failed] = OUTCOME_CODE["failed"]
        if inter is not None:
            outcome[inter] = OUTCOME_CODE["interrupted"]
        if late_code is not None and late_code != OUTCOME_CODE["dropped"]:
            outcome[late] = late_code
        ok = outcome == OUTCOME_CODE["completed"]
        frac_down = np.divide(d, full_d, out=np.zeros(n), where=full_d > 0)
        batch = SessionBatch(
            device_names=self.device_names,
            country_names=self.country_names,
            client_id=pb.client_ids,
            round_idx=np.full(n, round_idx, np.int64),
            device_idx=pb.device_idx, country_idx=pb.country_idx,
            download_s=d, compute_s=c, upload_s=u,
            bytes_down=pb.bytes_down * np.minimum(1.0, frac_down),
            bytes_up=np.where(ok, pb.bytes_up, 0.0),
            start_t=np.asarray(start, np.float64).copy(),
            end_t=end, outcome=outcome,
            staleness=np.zeros(n, np.int32))
        return batch, ok

    def apply_deadline(self, pb: PlanBatch, batch: SessionBatch,
                       ok: np.ndarray, deadline: float,
                       late_code: Optional[int] = None) -> None:
        """Patch a no-deadline ``resolve_batch`` into its with-deadline
        twin, in place (the serial twin of ``LaneSampler.apply_deadline``):
        only completed rows that finish past the deadline change — they
        burn budget until the round closes and drop (or relabel to
        ``late_code`` — the over-selection surplus outcome). Bit-identical
        to resolving with the deadline up front, because dropped / timeout
        / failed / interrupted rows never depend on it. Lets the sync
        fault path resolve retry chains before the round deadline is
        known."""
        idx = np.flatnonzero(ok & (batch.end_t > deadline))
        if not len(idx):
            return
        burn = np.maximum(0.0, deadline - batch.start_t[idx])
        fd, fc, fu = pb.download_s[idx], pb.compute_s[idx], pb.upload_s[idx]
        d = np.minimum(fd, burn)
        c = np.minimum(fc, np.maximum(0.0, burn - fd))
        u = np.minimum(fu, np.maximum(0.0, burn - fd - fc))
        frac = np.divide(d, fd, out=np.zeros(len(idx)), where=fd > 0)
        batch.download_s[idx] = d
        batch.compute_s[idx] = c
        batch.upload_s[idx] = u
        batch.bytes_down[idx] = pb.bytes_down[idx] * np.minimum(1.0, frac)
        batch.bytes_up[idx] = 0.0
        batch.end_t[idx] = np.maximum(deadline, batch.start_t[idx])
        batch.outcome[idx] = OUTCOME_CODE["dropped"] if late_code is None \
            else late_code
        ok[idx] = False

    # ------------------------------------------------- scalar (batch of 1)
    def plan(self, client_id: int, round_idx: int) -> SessionPlan:
        pb = self.plan_batch(np.asarray([client_id], np.int64), round_idx)
        return SessionPlan(client_id, self.fleet[int(pb.device_idx[0])],
                           self._countries[int(pb.country_idx[0])],
                           float(pb.download_s[0]), float(pb.compute_s[0]),
                           float(pb.upload_s[0]), self.bytes_down,
                           self.bytes_up, int(pb.n_examples[0]))

    def resolve(self, plan: SessionPlan, round_idx: int, start_t: float,
                deadline: Optional[float] = None,
                late_code: Optional[int] = None) -> Tuple[dict, bool]:
        """Resolve the outcome; returns (session_kwargs, contributed)."""
        pb = PlanBatch(np.asarray([plan.client_id], np.int64),
                       np.asarray([self.fleet.index(plan.device)], np.int32),
                       np.asarray([self._countries.index(plan.country)],
                                  np.int32),
                       np.asarray([plan.download_s]),
                       np.asarray([plan.compute_s]),
                       np.asarray([plan.upload_s]),
                       np.asarray([plan.bytes_down]),
                       np.asarray([plan.bytes_up]),
                       np.asarray([plan.n_examples], np.int64))
        b, ok = self.resolve_batch(pb, round_idx, start_t, deadline,
                                   late_code=late_code)
        s = b.to_sessions()[0]
        kw = {f: getattr(s, f) for f in
              ("client_id", "round_idx", "device", "country", "download_s",
               "compute_s", "upload_s", "bytes_down", "bytes_up", "start_t",
               "end_t", "outcome")}
        return kw, bool(ok[0])

    # ------------------------------------------------- reference (scalar)
    def plan_scalar(self, client_id: int, round_idx: int) -> SessionPlan:
        """Original pure-Python planner — reference implementation for
        equivalence tests and the scalar-engine benchmark baseline."""
        u = _uniforms(self.fed.seed, client_id, round_idx, 10)
        device = self.fleet[int(np.searchsorted(self._dcum, u[0]))]
        country = self._countries[int(np.searchsorted(self._ccum, u[1]))]
        n_ex = _pareto_samples(
            _uniforms(self.fed.seed, client_id, 0, 1)[0])
        tokens = n_ex * self.seq_len * self.fed.local_epochs
        compute_s = (tokens * self.flops_per_token * self.compute_overhead
                     / (device.train_gflops * 1e9)) \
            * _lognormal(u[2], u[3], _JITTER_SIGMA)
        download_s = 8.0 * self.bytes_down / self.download_bps \
            * _lognormal(u[4], u[5], _JITTER_SIGMA)
        upload_s = 8.0 * self.bytes_up / self.upload_bps \
            * _lognormal(u[6], u[7], _JITTER_SIGMA)
        return SessionPlan(client_id, device, country, download_s, compute_s,
                           upload_s, self.bytes_down, self.bytes_up, n_ex)

    def resolve_scalar(self, plan: SessionPlan, round_idx: int,
                       start_t: float, deadline: Optional[float] = None,
                       late_outcome: Optional[str] = None
                       ) -> Tuple[dict, bool]:
        """Original pure-Python outcome resolution (see plan_scalar)."""
        fed = self.fed
        uu = _uniforms(fed.seed, plan.client_id, round_idx + 1_000_000, 2)
        full_d, full_c, full_u = plan.download_s, plan.compute_s, plan.upload_s
        end = start_t + full_d + full_c + full_u
        outcome = "completed"
        d, c, u = full_d, full_c, full_u

        not_adm = False
        churn_burn = None
        if self.has_avail:
            ua = _uniforms(fed.seed, plan.client_id,
                           round_idx + 3_000_000, 1)[0]
            ci = np.asarray([self._countries.index(plan.country)], np.int32)
            e0 = float(self._avail_tab.at(ci, np.asarray([start_t]))[0])
            not_adm = ua >= e0
            if not not_adm and not (uu[0] < fed.dropout_rate
                                    or full_c > fed.client_timeout_s):
                et = float(exit_times(self._avail_tab, ci,
                                      np.asarray([ua]),
                                      np.asarray([start_t]))[0])
                if et < end:
                    full = full_d + full_c + full_u
                    churn_burn = min(max(et - start_t, 0.0), full)

        fail_burn = None
        if self.has_faults and not (uu[0] < fed.dropout_rate
                                    or full_c > fed.client_timeout_s
                                    or not_adm or churn_burn is not None):
            uf = _uniforms(fed.seed, plan.client_id, round_idx + 2_000_000, 3)
            ci = np.asarray([self._countries.index(plan.country)], np.int32)
            hz = float(self._hazard_tab.at(ci, np.asarray([start_t]))[0])
            full = full_d + full_c + full_u
            if uf[0] < hz:
                fail_burn = uf[2] * full
            elif len(self._burst_start):
                i = int(np.searchsorted(self._burst_end, start_t,
                                        side="right"))
                if i < len(self._burst_start) \
                        and self._burst_start[i] < end \
                        and uf[1] < self._burst_p:
                    fail_burn = min(max(0.0, float(self._burst_start[i])
                                        - start_t), full)

        if not_adm:
            # refused at admission: the device isn't eligible right now
            d = c = u = 0.0
            end = start_t
            outcome = "interrupted"
        elif uu[0] < fed.dropout_rate:
            # device stopped being idle/charging at a random point
            frac = uu[1]
            burn = frac * (full_d + full_c + full_u)
            d = min(full_d, burn)
            c = min(full_c, max(0.0, burn - full_d))
            u = min(full_u, max(0.0, burn - full_d - full_c))
            end = start_t + burn
            outcome = "dropped"
        elif full_c > fed.client_timeout_s:
            # the paper's 4-minute training timeout
            c = fed.client_timeout_s
            u = 0.0
            end = start_t + d + c
            outcome = "timeout"
        elif churn_burn is not None:
            # exited eligibility mid-flight (unplugged, off wifi)
            d = min(full_d, churn_burn)
            c = min(full_c, max(0.0, churn_burn - full_d))
            u = min(full_u, max(0.0, churn_burn - full_d - full_c))
            end = start_t + churn_burn
            outcome = "interrupted"
        elif fail_burn is not None:
            # killed by the fault model (hazard or burst)
            d = min(full_d, fail_burn)
            c = min(full_c, max(0.0, fail_burn - full_d))
            u = min(full_u, max(0.0, fail_burn - full_d - full_c))
            end = start_t + fail_burn
            outcome = "failed"
        elif deadline is not None and end > deadline:
            burn = max(0.0, deadline - start_t)
            d = min(full_d, burn)
            c = min(full_c, max(0.0, burn - full_d))
            u = min(full_u, max(0.0, burn - full_d - full_c))
            end = max(start_t, deadline)   # retries may start post-close
            outcome = late_outcome or "dropped"

        frac_down = d / full_d if full_d > 0 else 0.0
        kw = dict(client_id=plan.client_id, round_idx=round_idx,
                  device=plan.device.name, country=plan.country,
                  download_s=d, compute_s=c, upload_s=u,
                  bytes_down=plan.bytes_down * min(1.0, frac_down),
                  bytes_up=plan.bytes_up if outcome == "completed" else 0.0,
                  start_t=start_t, end_t=end, outcome=outcome)
        return kw, outcome == "completed"


# ---------------------------------------------------------------------------
# Lane-batched sampling: many compatible samplers, one columnar pass
# ---------------------------------------------------------------------------

def _pad2(rows: Sequence[np.ndarray], pad: float) -> np.ndarray:
    """Stack ragged per-lane 1-D tables into one (L, max_len) array."""
    width = max((len(r) for r in rows), default=0) or 1
    out = np.full((len(rows), width), pad, np.float64)
    for i, r in enumerate(rows):
        out[i, :len(r)] = r
    return out


class LaneSampler:
    """L independent ``SessionSampler``s advanced as ONE columnar batch.

    Every row of a plan/resolve call carries a ``lane`` id that selects
    that lane's seed and environment constants (fleet tables, country mix,
    bandwidths, payload bytes, model FLOPs). Because all per-session
    randomness is counter-based splitmix64 keyed on ``(seed, client_id,
    round_idx)`` — never on shared mutable RNG state — batching rows from
    many lanes into one array pass reproduces each lane's own
    ``SessionSampler.plan_batch``/``resolve_batch`` bit for bit; only the
    array shapes change. This is what turns S small sweep runs into one
    (S*B)-row simulation (the lane-batched sweep engine).

    Device/country indices stay *lane-local* (each lane keeps its own
    vocabularies, mirrored in ``device_names``/``country_names``), so a
    per-lane slice of the output columns is directly comparable to that
    lane's serial ``SessionBatch``.
    """

    def __init__(self, samplers: Sequence[SessionSampler]):
        self.samplers = list(samplers)
        self.n_lanes = len(self.samplers)
        assert self.n_lanes > 0
        ss = self.samplers
        self.seeds = np.asarray([s.fed.seed for s in ss], np.uint64)
        self.dropout_rate = np.asarray([s.fed.dropout_rate for s in ss])
        self.timeout_s = np.asarray([s.fed.client_timeout_s for s in ss])
        self.bytes_down = np.asarray([s.bytes_down for s in ss])
        self.bytes_up = np.asarray([s.bytes_up for s in ss])
        self.overhead = np.asarray([s.compute_overhead for s in ss])
        self.fpt = np.asarray([s.flops_per_token for s in ss])
        self.tokens_per_ex = np.asarray(
            [s.seq_len * s.fed.local_epochs for s in ss], np.int64)
        self.down_bps = np.asarray([s.download_bps for s in ss])
        self.up_bps = np.asarray([s.upload_bps for s in ss])
        self.device_names = [s.device_names for s in ss]
        self.country_names = [s.country_names for s in ss]
        # per-lane cumulative-weight / throughput tables, padded so one
        # fancy-indexed comparison replaces L searchsorted calls (pad 2.0
        # can never sit below a uniform in [0,1), so pads never count)
        self._dcum2 = _pad2([s._dcum for s in ss], 2.0)
        self._ccum2 = _pad2([s._ccum for s in ss], 2.0)
        self._gfl2 = _pad2([s._gflops for s in ss], 1.0)
        # fault lanes delegate the overlay to their own sampler's
        # element-wise _fault_masks (per-lane hazard tables/burst windows);
        # an all-fault-free pack skips the overlay entirely
        self._fault_lanes = np.asarray([s.has_faults for s in ss], bool)
        self.any_faults = bool(self._fault_lanes.any())
        # availability lanes delegate the admission/churn overlay to their
        # own sampler's element-wise _avail_masks (per-lane eligibility
        # tables); an all-available pack never draws the admission uniform
        self._avail_lanes = np.asarray([s.has_avail for s in ss], bool)
        self.any_avail = bool(self._avail_lanes.any())

    # ------------------------------------------------------------- planning
    def _plan_from_u(self, lane: np.ndarray, ids: np.ndarray,
                     u: np.ndarray) -> PlanBatch:
        """Plan math over a uniforms block (columns 0..8, see
        ``_plan_uniforms``)."""
        # count-of-strictly-less == np.searchsorted(cum, u, side="left")
        dev = (self._dcum2[lane] < u[:, 0:1]).sum(axis=1).astype(np.int32)
        ctry = (self._ccum2[lane] < u[:, 1:2]).sum(axis=1).astype(np.int32)
        n_ex = _pareto_samples_arr(u[:, 8])
        tokens = n_ex * self.tokens_per_ex[lane]
        jit = _lognormal_arr(u[:, 2:8:2], u[:, 3:8:2], _JITTER_SIGMA)
        compute_s = (tokens * self.fpt[lane] * self.overhead[lane]
                     / (self._gfl2[lane, dev] * 1e9)) * jit[:, 0]
        download_s = 8.0 * self.bytes_down[lane] / self.down_bps[lane] \
            * jit[:, 1]
        upload_s = 8.0 * self.bytes_up[lane] / self.up_bps[lane] * jit[:, 2]
        return PlanBatch(ids, dev, ctry, download_s, compute_s, upload_s,
                         self.bytes_down[lane], self.bytes_up[lane], n_ex)

    def plan_batch(self, lane: np.ndarray,
                   client_ids: Union[np.ndarray, Sequence[int]],
                   round_idx: int) -> PlanBatch:
        """Plan one row per (lane, client): the lane column selects each
        row's seed and environment constants. Matches each lane's own
        ``SessionSampler.plan_batch`` bit for bit."""
        ids = np.asarray(client_ids, np.int64)
        lane = np.asarray(lane, np.intp)
        u = _plan_uniforms_rows(self.seeds[lane], ids.astype(np.uint64),
                                round_idx)
        return self._plan_from_u(lane, ids, u)

    # ------------------------------------------------------------ resolving
    def plan_resolve(self, lane: np.ndarray,
                     client_ids: Union[np.ndarray, Sequence[int]],
                     round_idx: int, start_t: Union[float, np.ndarray],
                     rem: Optional[np.ndarray] = None
                     ) -> Tuple[PlanBatch, Dict[str, np.ndarray],
                                np.ndarray]:
        """Plan AND resolve one row per (lane, client) off a single fused
        splitmix pass — the lane loops' dispatch fast path (they always
        resolve what they just planned). ``rem`` scales each row's planned
        compute before resolution (checkpoint/resume retries redo only the
        remainder; ``x * 1.0`` is IEEE-exact, so all-ones rows are
        untouched). Returns ``(pb, cols, ok)``, bit-identical to
        ``plan_batch`` + compute scaling + ``resolve_batch``."""
        ids = np.asarray(client_ids, np.int64)
        lane = np.asarray(lane, np.intp)
        u = _fused_uniforms_rows(self.seeds[lane], ids.astype(np.uint64),
                                 round_idx)
        pb = self._plan_from_u(lane, ids, u)
        if rem is not None:
            np.multiply(pb.compute_s, rem, out=pb.compute_s)
        cols, ok = self._resolve_from_u(pb, lane, round_idx, start_t,
                                        u[:, 9:11], copy_start=False)
        return pb, cols, ok

    def resolve_batch(self, pb: PlanBatch, lane: np.ndarray, round_idx: int,
                      start_t: Union[float, np.ndarray],
                      deadline: Optional[np.ndarray] = None,
                      late_code: Optional[np.ndarray] = None
                      ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Resolve a lane-planned cohort; returns ``(cols, ok)`` where
        ``cols`` holds every SessionBatch column (device/country indices
        lane-local, ``staleness`` zeroed) keyed for a ``LaneAccumulator``
        append. ``deadline`` may be a per-row array (each lane closes its
        own round); ``late_code`` relabels each row's deadline cut
        (scalar or per-row — over-selecting lanes pass "cancelled")."""
        lane = np.asarray(lane, np.intp)
        uu = _uniforms_batch_rows(self.seeds[lane], pb.client_ids,
                                  round_idx + 1_000_000, 2)
        return self._resolve_from_u(pb, lane, round_idx, start_t, uu,
                                    deadline=deadline, late_code=late_code)

    def _resolve_from_u(self, pb: PlanBatch, lane: np.ndarray,
                        round_idx: int, start_t: Union[float, np.ndarray],
                        uu: np.ndarray,
                        deadline: Optional[np.ndarray] = None,
                        copy_start: bool = True,
                        late_code: Optional[np.ndarray] = None
                        ) -> Tuple[Dict[str, np.ndarray], np.ndarray]:
        """Outcome math over a resolve-uniforms block (2 columns).
        ``copy_start=False`` lets a caller that hands over a fresh start
        array skip the defensive copy."""
        n = len(pb)
        full_d, full_c, full_u = pb.download_s, pb.compute_s, pb.upload_s
        start_arr = np.asarray(start_t, np.float64)
        start = np.broadcast_to(start_arr, (n,))
        full = full_d + full_c + full_u
        end_full = start + full_d + full_c + full_u

        timeout_s = self.timeout_s[lane]
        dropped = uu[:, 0] < self.dropout_rate[lane]
        timeout = ~dropped & (full_c > timeout_s)
        if self.any_avail:
            ua = _uniforms_batch_rows(self.seeds[lane], pb.client_ids,
                                      round_idx + 3_000_000, 1)[:, 0]
            not_adm = np.zeros(n, bool)
            exit_t = np.full(n, np.inf)
            for li in np.unique(lane[self._avail_lanes[lane]]):
                m = lane == li
                na_, et_ = self.samplers[li]._avail_masks(
                    pb.country_idx[m], start[m], ua[m])
                not_adm[m] = na_
                exit_t[m] = et_
            churned = ~not_adm & ~dropped & ~timeout & (exit_t < end_full)
            inter = not_adm | churned
            iburn = np.where(not_adm, 0.0,
                             np.minimum(np.maximum(exit_t - start, 0.0),
                                        full))
            dropped &= ~not_adm
            timeout &= ~not_adm
        else:
            inter = None
        if self.any_faults:
            uf = _uniforms_batch_rows(self.seeds[lane], pb.client_ids,
                                      round_idx + 2_000_000, 3)
            pre = dropped | timeout
            if inter is not None:
                pre = pre | inter
            failed = np.zeros(n, bool)
            fburn = np.zeros(n, np.float64)
            for li in np.unique(lane[self._fault_lanes[lane]]):
                m = lane == li
                f_, b_ = self.samplers[li]._fault_masks(
                    pb.country_idx[m], start[m], end_full[m], full[m],
                    uf[m], pre[m])
                failed[m] = f_
                fburn[m] = b_
        else:
            failed = None
        if deadline is not None:
            late = ~dropped & ~timeout & (end_full > deadline)
            if failed is not None:
                late &= ~failed
            if inter is not None:
                late &= ~inter
        else:
            late = np.zeros(n, bool)
        burn = uu[:, 1] * full
        if deadline is not None:
            burn = np.where(late, np.maximum(0.0, deadline - start), burn)
        cut = dropped | late
        if failed is not None:
            burn = np.where(failed, fburn, burn)
            cut = cut | failed
        if inter is not None:
            burn = np.where(inter, iburn, burn)
            cut = cut | inter
        d = np.where(cut, np.minimum(full_d, burn), full_d)
        c = np.where(cut, np.minimum(full_c,
                                     np.maximum(0.0, burn - full_d)),
                     full_c)
        u = np.where(cut, np.minimum(full_u,
                                     np.maximum(0.0, burn - full_d - full_c)),
                     full_u)
        c = np.where(timeout, timeout_s, c)
        u = np.where(timeout, 0.0, u)
        end = np.where(dropped, start + burn, end_full)
        end = np.where(timeout, start + full_d + timeout_s, end)
        if failed is not None:
            end = np.where(failed, start + fburn, end)
        if inter is not None:
            end = np.where(inter, start + iburn, end)
        if deadline is not None:
            # retries may start after the round closed: never end < start
            end = np.where(late, np.maximum(start, deadline), end)

        outcome = np.zeros(n, np.int8)  # completed
        outcome[cut] = OUTCOME_CODE["dropped"]
        outcome[timeout] = OUTCOME_CODE["timeout"]
        if failed is not None:
            outcome[failed] = OUTCOME_CODE["failed"]
        if inter is not None:
            outcome[inter] = OUTCOME_CODE["interrupted"]
        if late_code is not None:
            lc = np.broadcast_to(np.asarray(late_code, np.int8), (n,))
            relabel = late & (lc != OUTCOME_CODE["dropped"])
            outcome[relabel] = lc[relabel]
        ok = outcome == OUTCOME_CODE["completed"]
        frac_down = np.divide(d, full_d, out=np.zeros(n), where=full_d > 0)
        cols = dict(
            client_id=pb.client_ids,
            round_idx=np.full(n, round_idx, np.int64),
            device_idx=pb.device_idx, country_idx=pb.country_idx,
            download_s=d, compute_s=c, upload_s=u,
            bytes_down=pb.bytes_down * np.minimum(1.0, frac_down),
            bytes_up=np.where(ok, pb.bytes_up, 0.0),
            start_t=start_arr if (not copy_start
                                 and start_arr.shape == (n,)
                                 and start_arr.flags.writeable)
            else np.asarray(start, np.float64).copy(),
            end_t=end, outcome=outcome,
            staleness=np.zeros(n, np.int32))
        return cols, ok

    def apply_deadline(self, pb: PlanBatch, cols: Dict[str, np.ndarray],
                       ok: np.ndarray, deadline: np.ndarray,
                       late_code: Optional[np.ndarray] = None) -> None:
        """Patch a no-deadline resolve into its with-deadline twin, in
        place: only rows that completed past the deadline change (they
        burn budget until the round closes and drop, or relabel to their
        lane's ``late_code`` — the over-selection surplus outcome), every
        other row is untouched — so the sync lane round needs ONE resolve
        pass instead of two. Bit-identical to ``resolve_batch(...,
        deadline=...)``: dropped/timeout/failed/interrupted rows never
        depend on the deadline, and a completed row's ``end_t`` equals its
        full-duration end."""
        idx = np.flatnonzero(ok & (cols["end_t"] > deadline))
        if not len(idx):
            return
        dl = deadline[idx]
        burn = np.maximum(0.0, dl - cols["start_t"][idx])
        fd, fc, fu = pb.download_s[idx], pb.compute_s[idx], pb.upload_s[idx]
        d = np.minimum(fd, burn)
        c = np.minimum(fc, np.maximum(0.0, burn - fd))
        u = np.minimum(fu, np.maximum(0.0, burn - fd - fc))
        frac = np.divide(d, fd, out=np.zeros(len(idx)), where=fd > 0)
        cols["download_s"][idx] = d
        cols["compute_s"][idx] = c
        cols["upload_s"][idx] = u
        cols["bytes_down"][idx] = pb.bytes_down[idx] * np.minimum(1.0, frac)
        cols["bytes_up"][idx] = 0.0
        cols["end_t"][idx] = np.maximum(dl, cols["start_t"][idx])
        if late_code is None:
            cols["outcome"][idx] = OUTCOME_CODE["dropped"]
        else:
            lc = np.broadcast_to(np.asarray(late_code, np.int8),
                                 ok.shape)
            cols["outcome"][idx] = lc[idx]
        ok[idx] = False

    # --------------------------------------------------- replacement streams
    def slot_stream_ids(self, lane: np.ndarray, slots: np.ndarray,
                        generations: np.ndarray, population: int
                        ) -> np.ndarray:
        """Per-row-seed twin of the module-level ``slot_stream_ids``."""
        lane = np.asarray(lane, np.intp)
        s = np.asarray(slots, dtype=np.uint64)
        g = np.asarray(generations, dtype=np.uint64)
        with np.errstate(over="ignore"):
            base0 = (self.seeds[lane] & _U64(0xFFFFFFFF)) \
                * _U64(0x9E3779B9) + _U64(0x7F4A7C15)
            h = _splitmix64_arr(base0 + s * _U64(_SLOT_MIX)
                                + g * _U64(_GOLDEN))
        u_ = (h >> _U64(11)).astype(np.float64) * _INV53
        return (u_ * population).astype(np.int64)

    def retry_stream_ids(self, lane: np.ndarray, units: np.ndarray,
                         attempts: np.ndarray, population: int
                         ) -> np.ndarray:
        """Per-row-seed twin of the module-level ``retry_stream_ids``."""
        lane = np.asarray(lane, np.intp)
        s = np.asarray(units, dtype=np.uint64)
        g = np.asarray(attempts, dtype=np.uint64)
        with np.errstate(over="ignore"):
            base0 = (self.seeds[lane] & _U64(0xFFFFFFFF)) \
                * _U64(0x9E3779B9) + _U64(0x7F4A7C15)
            h = _splitmix64_arr(base0 + s * _U64(_RETRY_MIX)
                                + g * _U64(_GOLDEN))
        u_ = (h >> _U64(11)).astype(np.float64) * _INV53
        return (u_ * population).astype(np.int64)

    def probe_uniforms(self, lane: np.ndarray, slots: np.ndarray,
                       generations: np.ndarray, n: int) -> np.ndarray:
        """Per-row-seed twin of the module-level ``probe_uniforms``."""
        lane = np.asarray(lane, np.intp)
        s = np.asarray(slots, dtype=np.uint64)
        g = np.asarray(generations, dtype=np.uint64)
        probe = np.arange(1, n + 1, dtype=np.uint64) * _U64(_PROBE_MIX)
        with np.errstate(over="ignore"):
            base0 = (self.seeds[lane] & _U64(0xFFFFFFFF)) \
                * _U64(0x9E3779B9) + _U64(0x7F4A7C15)
            base = base0 + s * _U64(_SLOT_MIX) + g * _U64(_GOLDEN)
            h = _splitmix64_arr(base[:, None] + probe[None, :])
        return (h >> _U64(11)).astype(np.float64) * _INV53

    def country_draw(self, lane: np.ndarray,
                     client_ids: Union[np.ndarray, Sequence[int]],
                     round_idx: int) -> np.ndarray:
        """Per-row-seed twin of ``SessionSampler.country_draw`` over the
        pack's padded country-cumulative table (count-of-strictly-less ==
        left searchsorted, pad 2.0 never counts)."""
        lane = np.asarray(lane, np.intp)
        cid = np.asarray(client_ids, np.int64).astype(np.uint64)
        with np.errstate(over="ignore"):
            base_r = ((self.seeds[lane] * _U64(1_000_003)
                       + _U64(round_idx))
                      & _U64(0xFFFFFFFF)) * _U64(2_654_435_761) \
                + cid * _U64(97)
            vals = _splitmix64_arr(base_r + _U64(_GOLDEN))
        u1 = (vals >> _U64(11)).astype(np.float64) * _INV53
        return (self._ccum2[lane] < u1[:, None]).sum(axis=1) \
            .astype(np.int32)

    def admission_uniforms(self, lane: np.ndarray,
                           client_ids: Union[np.ndarray, Sequence[int]],
                           round_idx: int) -> np.ndarray:
        """Per-row-seed twin of ``SessionSampler.admission_uniforms``."""
        lane = np.asarray(lane, np.intp)
        cid = np.asarray(client_ids, np.int64)
        return _uniforms_batch_rows(self.seeds[lane], cid,
                                    round_idx + 3_000_000, 1)[:, 0]
