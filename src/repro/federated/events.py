"""Session event sampling: device heterogeneity -> durations -> outcomes.

This is the simulator twin of the paper's production logger: for every
selected client we draw a device (fleet popularity weights) and a country
(participation mix), derive download/compute/upload durations from model
bytes, client data volume and device throughput, then resolve the outcome
(completed / dropped mid-session / 4-minute timeout). All durations carry a
lognormal jitter (thermal throttling, background load, link variance).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig
from repro.core.profiles import (COUNTRY_MIX, DOWNLOAD_BPS, FLEET, UPLOAD_BPS,
                                 DeviceProfile)
from repro.data.synthetic import client_num_samples
from repro.kernels.int8_quant.ops import wire_bytes

_JITTER_SIGMA = 0.35
_M64 = (1 << 64) - 1


def _splitmix64(x: int) -> int:
    """splitmix64 on python ints — cheap deterministic per-session
    randomness (np.random.default_rng construction is ~50us; this is <1us)."""
    x = (x + 0x9E3779B97F4A7C15) & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    return x ^ (x >> 31)


_INV53 = 1.0 / float(1 << 53)


def _uniforms(seed: int, client_id: int, round_idx: int, n: int):
    base = (((seed * 1_000_003 + round_idx) & 0xFFFFFFFF) * 2_654_435_761
            + (client_id & _M64) * 97) & _M64
    return [(_splitmix64((base + i * 0x9E3779B97F4A7C15) & _M64) >> 11)
            * _INV53 for i in range(n)]


def _lognormal(u1: float, u2: float, sigma: float) -> float:
    # Box-Muller
    r = math.sqrt(-2.0 * math.log(max(u1, 1e-12)))
    return math.exp(sigma * r * math.cos(2.0 * math.pi * u2))


def _pareto_samples(u: float, mean: float = 34.0, shape: float = 1.8) -> int:
    # inverse-CDF Lomax with E = scale/(shape-1)
    scale = mean * (shape - 1.0)
    n = int(scale * ((max(1.0 - u, 1e-12)) ** (-1.0 / shape) - 1.0)) + 1
    return max(2, min(n, 4096))


@dataclass(frozen=True)
class SessionPlan:
    """Durations + bytes for one client session, before outcome resolution."""
    client_id: int
    device: DeviceProfile
    country: str
    download_s: float
    compute_s: float
    upload_s: float
    bytes_down: float
    bytes_up: float
    n_examples: int


class SessionSampler:
    def __init__(self, model_cfg: ModelConfig, fed: FederatedConfig,
                 seq_len: int, param_bytes: Optional[float] = None,
                 fleet: Optional[Sequence[DeviceProfile]] = None,
                 country_mix: Optional[Mapping[str, float]] = None,
                 download_bps: Optional[float] = None,
                 upload_bps: Optional[float] = None):
        self.cfg = model_cfg
        self.fed = fed
        self.seq_len = seq_len
        fleet = tuple(fleet) if fleet is not None else FLEET
        country_mix = dict(country_mix) if country_mix is not None \
            else COUNTRY_MIX
        self.fleet = fleet
        self.download_bps = download_bps or DOWNLOAD_BPS
        self.upload_bps = upload_bps or UPLOAD_BPS
        n_params = model_cfg.param_count()
        self.n_params = n_params
        full = 4.0 * n_params  # f32 on the wire
        if fed.compression == "int8":
            self.bytes_down = float(wire_bytes(n_params, fed.quant_block))
            self.bytes_up = float(wire_bytes(n_params, fed.quant_block))
            self.compute_overhead = 1.05   # on-device (de)quant cost
        else:
            self.bytes_down = param_bytes or full
            self.bytes_up = param_bytes or full
            self.compute_overhead = 1.0
        self.flops_per_token = model_cfg.train_flops_per_token()
        self._countries = list(country_mix)
        cw = np.asarray(list(country_mix.values()), np.float64)
        self._ccum = np.cumsum(cw / cw.sum())
        dw = np.asarray([p.weight for p in fleet], np.float64)
        self._dcum = np.cumsum(dw / dw.sum())

    def plan(self, client_id: int, round_idx: int) -> SessionPlan:
        u = _uniforms(self.fed.seed, client_id, round_idx, 10)
        device = self.fleet[int(np.searchsorted(self._dcum, u[0]))]
        country = self._countries[int(np.searchsorted(self._ccum, u[1]))]
        n_ex = _pareto_samples(
            _uniforms(self.fed.seed, client_id, 0, 1)[0])
        tokens = n_ex * self.seq_len * self.fed.local_epochs
        compute_s = (tokens * self.flops_per_token * self.compute_overhead
                     / (device.train_gflops * 1e9)) \
            * _lognormal(u[2], u[3], _JITTER_SIGMA)
        download_s = 8.0 * self.bytes_down / self.download_bps \
            * _lognormal(u[4], u[5], _JITTER_SIGMA)
        upload_s = 8.0 * self.bytes_up / self.upload_bps \
            * _lognormal(u[6], u[7], _JITTER_SIGMA)
        return SessionPlan(client_id, device, country, download_s, compute_s,
                           upload_s, self.bytes_down, self.bytes_up, n_ex)

    def resolve(self, plan: SessionPlan, round_idx: int, start_t: float,
                deadline: Optional[float] = None
                ) -> Tuple[dict, bool]:
        """Resolve the outcome; returns (session_kwargs, contributed).

        deadline: absolute task-clock time after which the round no longer
        accepts results (sync FL round close / over-selection cancel)."""
        fed = self.fed
        uu = _uniforms(fed.seed, plan.client_id, round_idx + 1_000_000, 2)
        full_d, full_c, full_u = plan.download_s, plan.compute_s, plan.upload_s
        end = start_t + full_d + full_c + full_u
        outcome = "completed"
        d, c, u = full_d, full_c, full_u

        if uu[0] < fed.dropout_rate:
            # device stopped being idle/charging at a random point
            frac = uu[1]
            burn = frac * (full_d + full_c + full_u)
            d = min(full_d, burn)
            c = min(full_c, max(0.0, burn - full_d))
            u = min(full_u, max(0.0, burn - full_d - full_c))
            end = start_t + burn
            outcome = "dropped"
        elif full_c > fed.client_timeout_s:
            # the paper's 4-minute training timeout
            c = fed.client_timeout_s
            u = 0.0
            end = start_t + d + c
            outcome = "timeout"
        elif deadline is not None and end > deadline:
            burn = max(0.0, deadline - start_t)
            d = min(full_d, burn)
            c = min(full_c, max(0.0, burn - full_d))
            u = min(full_u, max(0.0, burn - full_d - full_c))
            end = deadline
            outcome = "dropped"

        kw = dict(client_id=plan.client_id, round_idx=round_idx,
                  device=plan.device.name, country=plan.country,
                  download_s=d, compute_s=c, upload_s=u,
                  bytes_down=plan.bytes_down if d > 0 else 0.0,
                  bytes_up=plan.bytes_up if outcome == "completed" else 0.0,
                  start_t=start_t, end_t=end, outcome=outcome)
        return kw, outcome == "completed"
