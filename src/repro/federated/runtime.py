"""The FL task runtime: synchronous (FedAvg) and asynchronous (FedBuff)
event loops with full carbon telemetry (paper §3.1).

Both loops are ``Strategy`` classes registered in the string-keyed
``STRATEGIES`` registry ("sync", "async", "carbon-aware";
``register_strategy`` stays open for new policies). They drive a pluggable
learner (RealLearner or SurrogateLearner) through the same PAPAYA-shaped
protocol:

sync  — each round selects `concurrency` clients ("users per round"); the
        round closes when the `aggregation_goal`-th result arrives; clients
        still running are cancelled (over-selection waste is charged);
        server updates once per round.
async — `concurrency` clients are always in flight; a finished client's
        (staleness-weighted) delta joins the buffer; every
        `aggregation_goal` arrivals the server updates and later clients
        train on the newer model (FedBuff). Stragglers never block.
carbon-aware — the async loop with grid-aware cohort selection (CAFE-style
        time/geo shifting): each replacement dispatch screens a counter-
        keyed stream of candidate ids and takes the first whose country
        draw lands in the ``carbon_topk`` lowest-intensity countries at
        the dispatch clock (``Environment.intensity_schedule`` supplies
        the diurnal curves), with a ``carbon_explore`` floor of
        unscreened dispatches. See ``CarbonAwareStrategy``.

Both loops are columnar end-to-end: cohorts are planned/resolved through
the vectorized ``SessionSampler.plan_batch``/``resolve_batch`` and logged
as ``SessionBatch`` columns, so the per-session cost is a few array ops
rather than Python-object allocation. Sync closes each round with a
partition on end_t; async is a window-batched exact merge — per-slot
splitmix64 replacement-id streams (``slot_stream_ids``) decouple
replacement identity from arrival order, so the span between two server
updates resolves as arrays instead of a per-session heap pop (see
``AsyncStrategy``). The returned TaskLog contains every session's vitals;
CarbonEstimator turns it into the paper's component breakdown. Strategies
emit a ``RoundEvent`` after every server eval so callers
(``repro.api.Experiment``) can stream progress. ``run_task`` survives
only as a deprecated shim over the registry — new code goes through
``repro.api``.

The engine also vectorizes across the *spec* axis: ``LaneRunner`` packs
compatible experiments (same mode; any mix of concurrency, goals, seeds,
models, budgets, Environments) and both strategies implement
``lane_loop`` — a lockstep twin of ``_loop`` where every sampler call is
``(lane, batch)``-shaped (``events.LaneSampler``), sessions land in one
``telemetry.LaneAccumulator`` store with a lane column, and the
estimator reduces per-lane segments (``estimator.lane_carbon``).
Lane-batched results are seed-for-seed identical to per-spec runs;
``repro.api.sweep(specs, vectorize=True)`` is the front end.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Type

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig, RunConfig
from repro.core.carbon import SECONDS_PER_DAY
from repro.core.estimator import (CarbonBreakdown, CarbonEstimator,
                                  lane_carbon)
from repro.core.streaming import StreamedLog
from repro.core.telemetry import (OUTCOME_CODE, BatchAccumulator,
                                  LaneAccumulator, SessionBatch, TaskLog)
from repro.federated.events import (LaneSampler, SessionSampler,
                                    probe_uniforms, retry_stream_ids,
                                    slot_stream_ids)

_SERVER_AGG_S = 2.0     # server-side aggregation latency per update
_POPULATION = 5_000_000  # eligible-device pool the coordinator selects from
# dispatch cohorts are planned/resolved in blocks of at most this many rows
# so population-scale concurrency never materializes a full-cohort plan;
# plan/resolve are row-pure, so any chunking is bit-identical (tests
# monkeypatch this down to exercise the chunked paths at small scale)
_DISPATCH_CHUNK = 1 << 17


@dataclass
class TaskResult:
    log: TaskLog
    carbon: CarbonBreakdown
    reached_target: bool
    rounds: int
    duration_h: float
    final_perplexity: float
    smoothed_perplexity: float
    # True iff the sync loop gave up after `starvation_patience`
    # consecutive under-quorum (starved) rounds
    aborted: bool = False

    def summary(self) -> Dict[str, float]:
        return {
            "rounds": self.rounds,
            "duration_h": self.duration_h,
            "reached_target": float(self.reached_target),
            "perplexity": self.final_perplexity,
            "carbon_total_kg": self.carbon.total_kg,
            **{k: v for k, v in self.carbon.as_dict().items()},
            "sessions": float(self.log.n_sessions),
            "aborted": float(self.aborted),
        }


@dataclass(frozen=True)
class RoundEvent:
    """Streamed to `on_round` after every server eval (both strategies)."""
    round_idx: int               # server model updates so far
    t_s: float                   # task clock, seconds
    perplexity: float
    smoothed_perplexity: float
    n_sessions: int              # client sessions logged so far
    mode: str                    # strategy key ("sync"/"async"/"carbon-aware")


RoundCallback = Callable[[RoundEvent], None]


class _Stopper:
    """Paper §3.2: stop when smoothed test perplexity has been at/below the
    target for `patience` consecutive evals, or at the time limit."""

    def __init__(self, run: RunConfig):
        self.run = run
        self.smoothed: Optional[float] = None
        self.hits = 0
        self.reached = False
        self.aborted = False   # set by the sync starvation-patience abort

    def update(self, ppl: float) -> None:
        a = self.run.ema_alpha
        self.smoothed = ppl if self.smoothed is None else \
            a * ppl + (1 - a) * self.smoothed
        if self.smoothed <= self.run.target_perplexity:
            self.hits += 1
        else:
            self.hits = 0
        if self.hits >= self.run.patience_rounds:
            self.reached = True

    def out_of_budget(self, t_s: float, rounds: int) -> bool:
        return (t_s >= self.run.max_hours * 3600.0
                or rounds >= self.run.max_rounds)


def _select_cohort(rng: np.random.Generator, k: int,
                   population: int) -> np.ndarray:
    """Coordinator client selection: eligible devices, unique per round.
    Sampled without replacement from the population directly (the old
    sample-from-a-larger-range-then-modulo trick silently reintroduced
    duplicates and a mild modulo bias)."""
    return rng.choice(population, size=k, replace=False).astype(np.int64)


def _sync_dispatch_n(fed: FederatedConfig, goal: int) -> int:
    """Sync round cohort size. With ``over_select_fraction`` f > 0 the
    coordinator explicitly dispatches ceil((1+f)*goal) clients (always
    >= goal) and cancels the surplus at the round close; f == 0 keeps the
    legacy concurrency-sized cohort."""
    if fed.over_select_fraction > 0:
        return int(math.ceil((1.0 + fed.over_select_fraction) * goal))
    return fed.concurrency


def _retry_rem(outcome: np.ndarray, planned_c: np.ndarray,
               burned_c: np.ndarray, rem, period_s) -> np.ndarray:
    """Per-row remainder fraction (of an ORIGINAL full session's compute)
    a retry child must redo, given its parent attempt's outcome. Failed
    attempts redo their parent's whole remainder ``rem``; interrupted
    attempts salvage local progress to the last checkpoint —
    ``floor(burned / P) * P`` of the parent's (already rem-scaled)
    planned compute survives the interruption, so the child's remainder
    shrinks by that completed fraction. With ``period_s`` == 0 (salvage
    off) every entry stays at its parent's ``rem`` (1.0 for fresh
    dispatches), and all downstream ``compute_s * rem`` multiplies are
    IEEE-exact no-ops — fault-only runs are untouched bit for bit.
    Row-pure, shared verbatim by the scalar oracle."""
    F, I = OUTCOME_CODE["failed"], OUTCOME_CODE["interrupted"]
    out = np.where((outcome == F) | (outcome == I),
                   np.asarray(rem, np.float64), 1.0)
    P = np.broadcast_to(np.asarray(period_s, np.float64), outcome.shape)
    im = np.flatnonzero((outcome == I) & (P > 0))
    if len(im):
        salv = np.floor(burned_c[im] / P[im]) * P[im]
        fc = planned_c[im]
        frac = np.divide(salv, fc, out=np.zeros(len(im)), where=fc > 0)
        out[im] = out[im] * (1.0 - frac)
    return out


def _sync_server_update(learner, contributors: List[int]) -> float:
    """One FedAvg server update from a round's contributor list; returns
    the fresh eval perplexity (shared by the serial and lane loops)."""
    deltas, weights = [], []
    if getattr(learner, "real", True):
        if hasattr(learner, "client_deltas"):
            deltas, weights = learner.client_deltas(contributors)
        else:
            for c in contributors:
                d, w = learner.client_delta(c, None)
                deltas.append(d)
                weights.append(w)
    else:
        deltas, weights = [None], [1.0]
    learner.apply(deltas, weights, n_contributors=len(contributors))
    return learner.eval_perplexity()


def _async_server_update(learner, cids: np.ndarray, vers_ok: np.ndarray,
                         version: int) -> float:
    """One FedBuff server update from the buffer's contributing arrivals;
    returns the fresh eval perplexity (shared by the serial and lane
    loops)."""
    if getattr(learner, "real", True):
        staleness = (version - vers_ok).tolist()
        deltas, weights = [], []
        for bc, bv in zip(cids.tolist(), vers_ok.tolist()):
            dd, w = learner.client_delta(bc, bv)
            deltas.append(dd)
            weights.append(w)
        kw_extra = {"staleness": staleness}
        mean_st = float(np.mean(staleness))
    else:
        deltas, weights, kw_extra = [None], [1.0], {}
        mean_st = version - (vers_ok.sum() / len(vers_ok))
    learner.apply(deltas, weights, n_contributors=len(vers_ok),
                  mean_staleness=mean_st, **kw_extra)
    return learner.eval_perplexity()


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------

STRATEGIES: Dict[str, Type["Strategy"]] = {}


def register_strategy(name: str):
    """Class decorator: expose a Strategy under a string key (open for
    carbon-aware selection policies next)."""
    def deco(cls: Type["Strategy"]) -> Type["Strategy"]:
        cls.mode = name
        STRATEGIES[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> "Strategy":
    try:
        return STRATEGIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: {sorted(STRATEGIES)}"
        ) from None


class Strategy:
    """One FL orchestration policy. Subclasses implement `_loop`; the base
    handles sampler/estimator wiring so every strategy sees the same
    environment knobs (fleet, country mix, bandwidths, carbon models)."""

    mode: str = ""

    def run(self, model_cfg: ModelConfig, fed: FederatedConfig,
            run: RunConfig, learner, *, seq_len: int = 64,
            estimator: Optional[CarbonEstimator] = None,
            sampler: Optional[SessionSampler] = None,
            on_round: Optional[RoundCallback] = None,
            snap=None) -> TaskResult:
        sampler = sampler or SessionSampler(model_cfg, fed, seq_len)
        est = estimator or CarbonEstimator()
        # selection policies may read the environment's grid model (the
        # carbon-aware strategy screens candidates by intensity-at-clock)
        self._estimator = est
        # checkpoint/resume salvage only counts when a resume can actually
        # use the checkpoint: availability churn live AND retries enabled.
        # The estimator reads this off the log to split interrupted rows'
        # wasted compute into salvaged (pre-checkpoint) vs lost.
        ckpt = fed.checkpoint_period_s \
            if (sampler.has_avail and fed.retry_limit > 0) else 0.0
        if run.telemetry == "streaming":
            log: TaskLog = StreamedLog(est, sampler.device_names,
                                       sampler.country_names, seed=fed.seed,
                                       sample=run.telemetry_sample,
                                       mode=self.mode,
                                       checkpoint_period_s=ckpt)
        else:
            log = TaskLog()
            log.checkpoint_period_s = ckpt
        stop = _Stopper(run)
        # engine snapshots (repro.core.snapshot): the hook rides on the
        # instance so subclassed `_loop` signatures stay untouched; loops
        # read it back with getattr. Resume restores the log / stopper /
        # learner here (they are built above); the loop-local state is
        # restored by `_loop` itself.
        self._snap = snap
        if snap is not None and snap.resume is not None:
            snap.resume.restore_log(log)
            snap.resume.restore_stopper(stop)
            snap.resume.restore_learner(learner)
        t, rounds, ppl = self._loop(model_cfg, fed, learner, sampler, log,
                                    stop, on_round)
        return TaskResult(log, est.estimate(log), stop.reached, rounds,
                          t / 3600.0, ppl, stop.smoothed or ppl,
                          aborted=stop.aborted)

    # subclasses: run the event loop, return (t_s, rounds, perplexity)
    def _loop(self, model_cfg: ModelConfig, fed: FederatedConfig, learner,
              sampler: SessionSampler, log: TaskLog, stop: _Stopper,
              on_round: Optional[RoundCallback]) -> Tuple[float, int, float]:
        raise NotImplementedError

    def _emit(self, on_round: Optional[RoundCallback], n_sessions: int,
              round_idx: int, t: float, ppl: float, smoothed: float) -> None:
        if on_round is not None:
            on_round(RoundEvent(round_idx, t, ppl, smoothed,
                                n_sessions, self.mode))

    @staticmethod
    def _make_sink(log: TaskLog, device_names: Tuple[str, ...],
                   country_names: Tuple[str, ...]):
        """Window sink for loops that log in column blocks: a streaming
        log folds appended blocks directly (constant memory); a full log
        stages them in a BatchAccumulator flushed at task end."""
        if hasattr(log, "append"):
            return log
        return BatchAccumulator(device_names, country_names)

    @staticmethod
    def _flush_sink(log: TaskLog, acc) -> None:
        if acc is not log and len(acc):
            log.log_batch(acc.to_batch())


@register_strategy("sync")
class SyncStrategy(Strategy):
    """FedAvg rounds with over-selection cancel (paper §3.1 sync)."""

    def _loop(self, model_cfg, fed, learner, sampler, log, stop, on_round):
        assert fed.mode == "sync"
        snap = getattr(self, "_snap", None)
        rng = np.random.default_rng(fed.seed + 1)
        t = 0.0
        rounds = 0
        ppl = float(model_cfg.vocab_size)
        goal = min(fed.aggregation_goal, fed.concurrency)
        # explicit over-selection: surplus sessions past the round close
        # relabel "cancelled" (dropped is the implicit-deadline legacy)
        ndisp = _sync_dispatch_n(fed, goal)
        lc = OUTCOME_CODE["cancelled"] if fed.over_select_fraction > 0 \
            else None
        # graceful degradation: a round that closes with fewer than
        # `quorum` completions is *starved* — it still charges its cohort,
        # but the server skips the update; `starvation_patience`
        # consecutive starved rounds abort the task outright
        quorum = max(1, int(np.ceil(fed.min_report_fraction * goal)))
        streak = 0
        if snap is not None and snap.engine_state is not None:
            # resume at a round boundary: the saved RNG state was captured
            # before the round's cohort draw, so selection replays exactly
            es = snap.engine_state
            t = float(es["t"])
            rounds = int(es["rounds"])
            ppl = float(es["ppl"])
            streak = int(es["streak"])
            rng.bit_generator.state = es["rng_state"]

        while True:
            if snap is not None:
                snap.tick(rounds, lambda: (
                    dict(t=t, rounds=rounds, ppl=ppl, streak=streak,
                         rng_state=rng.bit_generator.state), None),
                    log, learner, stop)
            cohort = _select_cohort(rng, ndisp, population=_POPULATION)
            if sampler.has_faults or (sampler.has_avail
                                      and fed.retry_limit > 0):
                n_ok, contributors, round_end = self._faulty_round(
                    fed, sampler, log, cohort, rounds, t, goal,
                    late_code=lc)
            elif len(cohort) <= _DISPATCH_CHUNK:
                pb = sampler.plan_batch(cohort, rounds)
                # pass 1: tentative outcomes, find when the goal-th result
                # arrives (a partition on end_t, not a full sort)
                tb, ok = sampler.resolve_batch(pb, rounds, t)
                ends = tb.end_t[ok]
                if len(ends) >= goal:
                    round_end = float(np.partition(ends, goal - 1)[goal - 1])
                elif len(ends):
                    # dropouts ate the over-selection slack: the round
                    # closes at the last survivor (production would hit the
                    # round deadline) and the server updates with what it
                    # received
                    round_end = float(ends.max())
                else:
                    round_end = float(tb.end_t.max()) if len(tb) else t
                # pass 2: sessions against the round deadline (cancel
                # stragglers)
                fb, ok2 = sampler.resolve_batch(pb, rounds, t,
                                                deadline=round_end,
                                                late_code=lc)
                log.log_batch(fb)
                n_ok = int(np.count_nonzero(ok2))
                contributors: List[int] = \
                    cohort[np.nonzero(ok2)[0][:goal]].tolist()
            else:
                # population-scale cohort: bounded-size chunks. Pass 1
                # keeps only the surviving end times (plans are re-derived
                # in pass 2 — plan/resolve are row-pure, so re-planning is
                # bit-identical to caching); the round close is a
                # partition, which is order-independent across chunks.
                ends_parts: List[np.ndarray] = []
                n_rows, max_end = 0, t
                for lo in range(0, len(cohort), _DISPATCH_CHUNK):
                    ch = cohort[lo:lo + _DISPATCH_CHUNK]
                    tb, ok = sampler.resolve_batch(
                        sampler.plan_batch(ch, rounds), rounds, t)
                    ends_parts.append(tb.end_t[ok])
                    if len(tb):
                        max_end = max(max_end, float(tb.end_t.max()))
                    n_rows += len(tb)
                ends = np.concatenate(ends_parts)
                if len(ends) >= goal:
                    round_end = float(np.partition(ends, goal - 1)[goal - 1])
                elif len(ends):
                    round_end = float(ends.max())
                else:
                    round_end = max_end if n_rows else t
                ok2_parts: List[np.ndarray] = []
                for lo in range(0, len(cohort), _DISPATCH_CHUNK):
                    ch = cohort[lo:lo + _DISPATCH_CHUNK]
                    fb, ok2c = sampler.resolve_batch(
                        sampler.plan_batch(ch, rounds), rounds, t,
                        deadline=round_end, late_code=lc)
                    log.log_batch(fb)
                    ok2_parts.append(ok2c)
                ok2 = np.concatenate(ok2_parts)
                n_ok = int(np.count_nonzero(ok2))
                contributors = cohort[np.nonzero(ok2)[0][:goal]].tolist()
            starved = n_ok < quorum
            t = round_end + _SERVER_AGG_S
            rounds += 1
            if not starved and contributors:
                ppl = _sync_server_update(learner, contributors)
                stop.update(ppl)
            log.log_round(t, starved=starved)
            log.log_eval(t, rounds, ppl, stop.smoothed or ppl)
            self._emit(on_round, log.n_sessions, rounds, t, ppl,
                       stop.smoothed or ppl)
            if starved:
                streak += 1
                if fed.starvation_patience \
                        and streak >= fed.starvation_patience:
                    stop.aborted = True
                    break
            else:
                streak = 0
            if stop.reached or stop.out_of_budget(t, rounds):
                break
        return t, rounds, ppl

    @staticmethod
    def _faulty_round(fed, sampler, log, cohort, rounds, t, goal,
                      late_code=None):
        """One sync round under a live fault and/or churn model: resolve
        the cohort with no deadline, chase failed AND interrupted slots
        through up to ``retry_limit`` re-dispatches (exponential backoff,
        distinct counter-keyed retry ids — every attempt is charged),
        close the round over ALL attempts' survivors, then patch the
        deadline in and log the blocks attempt-major. Checkpoint/resume:
        when ``checkpoint_period_s`` > 0 an interrupted attempt's retry
        redoes only the un-checkpointed remainder (its planned compute is
        scaled by the running ``rem`` fraction — see ``_retry_rem``).
        Cohorts resolve one-shot (no ``_DISPATCH_CHUNK`` pass — retry
        waves shrink geometrically, the cohort block dominates). Returns
        (n_ok, contributors, round_end)."""
        F, I = OUTCOME_CODE["failed"], OUTCOME_CODE["interrupted"]
        salv_on = sampler.has_avail and fed.retry_limit > 0 \
            and fed.checkpoint_period_s > 0
        pos = np.arange(len(cohort), dtype=np.int64)
        ids = cohort
        starts = t
        rem = np.ones(len(cohort))
        blocks = []
        for att in range(fed.retry_limit + 1):
            pb = sampler.plan_batch(ids, rounds)
            if salv_on and att:
                np.multiply(pb.compute_s, rem, out=pb.compute_s)
            fb, ok = sampler.resolve_batch(pb, rounds, starts)
            blocks.append((pb, fb, ok))
            fm = np.flatnonzero((fb.outcome == F) | (fb.outcome == I))
            if att == fed.retry_limit or not len(fm):
                break
            # failed/interrupted slots re-dispatch: a fresh client id off
            # the retry stream (keyed by cohort position + a round-scoped
            # attempt counter) after an exponential-backoff delay
            if salv_on:
                rem = _retry_rem(fb.outcome, pb.compute_s, fb.compute_s,
                                 rem, fed.checkpoint_period_s)[fm]
            pos = pos[fm]
            ids = retry_stream_ids(
                fed.seed, pos,
                np.full(len(pos), rounds * (fed.retry_limit + 1) + att + 1,
                        np.int64),
                _POPULATION)
            starts = fb.end_t[fm] + fed.retry_backoff_s * 2.0 ** att
        ok_ends = np.concatenate([fb.end_t[ok] for _, fb, ok in blocks])
        if len(ok_ends) >= goal:
            round_end = float(np.partition(ok_ends, goal - 1)[goal - 1])
        elif len(ok_ends):
            round_end = float(ok_ends.max())
        else:
            round_end = float(max(fb.end_t.max() for _, fb, _ in blocks))
        n_ok = 0
        contributors: List[int] = []
        for att, (pb, fb, ok) in enumerate(blocks):
            sampler.apply_deadline(pb, fb, ok, round_end,
                                   late_code=late_code)
            if att < fed.retry_limit:
                # a retry went out for every one of these failures
                # (interrupted rows keep their label — the outcome
                # taxonomy separates churn from the crash-retry path)
                fb.outcome[fb.outcome == F] = OUTCOME_CODE["retried"]
            log.log_batch(fb)
            n_ok += int(np.count_nonzero(ok))
            if len(contributors) < goal:
                sel = np.flatnonzero(ok)[:goal - len(contributors)]
                contributors.extend(fb.client_id[sel].tolist())
        return n_ok, contributors, round_end

    def lane_loop(self, pack: "_LanePack") -> None:
        """Lockstep lane-batched twin of ``_loop``: one plan/resolve pass
        covers every active lane's cohort (rows keyed per lane through
        ``LaneSampler``), the per-lane round close stays a partition on
        that lane's ``end_t`` slice, and learner/stopper bookkeeping runs
        per lane on scalars. Active lanes always share the lockstep round
        index ``k`` (every window closes exactly one round per lane), so
        ``round_idx`` stays a scalar in the sampler keys. Seed-for-seed
        identical to running each lane alone — cohort selection consumes
        each lane's own rng exactly as the serial loop does, and lanes
        share no other RNG state.

        Fault lanes ride the same lockstep: each retry wave is one batched
        plan/resolve over every lane's surviving failures (attempt-major,
        exactly the serial ``_faulty_round`` per lane), and quorum /
        starvation bookkeeping runs per lane on scalars."""
        lanes = pack.lanes
        rngs = [np.random.default_rng(f.seed + 1) for f in pack.feds]
        goals = [min(f.aggregation_goal, f.concurrency) for f in pack.feds]
        ndisp = [_sync_dispatch_n(f, goals[i])
                 for i, f in enumerate(pack.feds)]
        L = pack.n_lanes
        quorum = [max(1, int(np.ceil(f.min_report_fraction * goals[i])))
                  for i, f in enumerate(pack.feds)]
        retry_lim = np.asarray([f.retry_limit
                                if (s.has_faults or s.has_avail) else 0
                                for f, s in zip(pack.feds, lanes.samplers)],
                               np.int64)
        retry_bo = np.asarray([f.retry_backoff_s for f in pack.feds])
        any_faults = any(s.has_faults for s in lanes.samplers)
        # retry waves run when any lane chases failures (fault lanes
        # resolve one-shot even at retry 0, like the serial route) or
        # retries churn interruptions
        any_retry = any_faults or bool((retry_lim > 0).any())
        # per-lane checkpoint salvage (see serial _faulty_round)
        salv_P = np.asarray([f.checkpoint_period_s
                             if (s.has_avail and f.retry_limit > 0) else 0.0
                             for f, s in zip(pack.feds, lanes.samplers)])
        any_salv = bool((salv_P > 0).any())
        # per-lane late-straggler label (cancelled under over-selection)
        late_arr = np.asarray(
            [OUTCOME_CODE["cancelled"] if f.over_select_fraction > 0
             else OUTCOME_CODE["dropped"] for f in pack.feds], np.int8)
        any_osel = any(f.over_select_fraction > 0 for f in pack.feds)
        streak = np.zeros(L, np.int64)
        F, R = OUTCOME_CODE["failed"], OUTCOME_CODE["retried"]
        I = OUTCOME_CODE["interrupted"]
        k = 0                        # == every active lane's `rounds`
        while pack.active.any():
            act = np.flatnonzero(pack.active)
            cohorts = [_select_cohort(rngs[i], ndisp[i], _POPULATION)
                       for i in act]
            sizes = np.asarray([ndisp[i] for i in act], np.int64)
            offs = np.concatenate([[0], np.cumsum(sizes)])
            lane_row = np.repeat(act, sizes)
            start = pack.t[lane_row]
            ids = np.concatenate(cohorts)
            total = len(lane_row)
            # retry lanes resolve one-shot, like the serial fault path
            chunked = total > _DISPATCH_CHUNK and not any_retry
            if not chunked:
                pb, fb, ok = lanes.plan_resolve(lane_row, ids, k, start)
                blocks = [(lane_row, pb, fb, ok)]
                if any_retry:
                    # lockstep retry waves: wave a re-dispatches every
                    # lane's attempt-(a-1) failures AND interruptions in
                    # ONE batched resolve
                    prev_lane, prev_pb, prev_fb = lane_row, pb, fb
                    prev_pos = np.concatenate(
                        [np.arange(ndisp[i], dtype=np.int64) for i in act])
                    prev_rem = np.ones(total) if any_salv else None
                    att = 0
                    while True:
                        sel = np.flatnonzero(
                            ((prev_fb["outcome"] == F)
                             | (prev_fb["outcome"] == I))
                            & (retry_lim[prev_lane] > att))
                        att += 1
                        if not len(sel):
                            break
                        lane_r = prev_lane[sel]
                        pos_r = prev_pos[sel]
                        rem_r = None
                        if any_salv:
                            rem_r = _retry_rem(
                                prev_fb["outcome"], prev_pb.compute_s,
                                prev_fb["compute_s"], prev_rem,
                                salv_P[prev_lane])[sel]
                        ids_r = lanes.retry_stream_ids(
                            lane_r, pos_r,
                            k * (retry_lim[lane_r] + 1) + att, _POPULATION)
                        starts_r = prev_fb["end_t"][sel] \
                            + retry_bo[lane_r] * 2.0 ** (att - 1)
                        pb_r, fb_r, ok_r = lanes.plan_resolve(
                            lane_r, ids_r, k, starts_r, rem=rem_r)
                        blocks.append((lane_r, pb_r, fb_r, ok_r))
                        prev_lane, prev_pb, prev_fb = lane_r, pb_r, fb_r
                        prev_pos, prev_rem = pos_r, rem_r
                # per-block per-lane segment bounds (every block stays
                # lane-sorted: attempt 0 by construction, retry waves
                # because flatnonzero preserves the sorted row order)
                cuts = [np.append(np.searchsorted(lane_b, act), len(lane_b))
                        for lane_b, _, _, _ in blocks]
                round_end = np.empty(len(act))
                for j, i in enumerate(act):
                    oe = [fb_b["end_t"][cb[j]:cb[j + 1]]
                          [ok_b[cb[j]:cb[j + 1]]]
                          for (_, _, fb_b, ok_b), cb in zip(blocks, cuts)]
                    oe = oe[0] if len(oe) == 1 else np.concatenate(oe)
                    g = goals[i]
                    if len(oe) >= g:
                        round_end[j] = np.partition(oe, g - 1)[g - 1]
                    elif len(oe):
                        round_end[j] = oe.max()
                    else:
                        seg = np.concatenate(
                            [fb_b["end_t"][cb[j]:cb[j + 1]]
                             for (_, _, fb_b, _), cb in zip(blocks, cuts)])
                        round_end[j] = seg.max() if len(seg) else pack.t[i]
                # pass 2 of the serial loop collapses to a masked patch of
                # the stragglers (cancel-at-deadline); failures whose
                # retry went out relabel as "retried"; log attempt-major
                deadline_lane = np.empty(L)
                deadline_lane[act] = round_end
                for att_i, (lane_b, pb_b, fb_b, ok_b) in enumerate(blocks):
                    lanes.apply_deadline(
                        pb_b, fb_b, ok_b, deadline_lane[lane_b],
                        late_code=late_arr[lane_b] if any_osel else None)
                    if any_retry:
                        m = (fb_b["outcome"] == F) \
                            & (retry_lim[lane_b] > att_i)
                        fb_b["outcome"][m] = R
                    pack.acc.append(lane=lane_b, **fb_b)
                n_ok_lane = np.zeros(L, np.int64)
                rows_lane = np.zeros(L, np.int64)
                contrib: Dict[int, List[int]] = {int(i): [] for i in act}
                for (lane_b, _, fb_b, ok_b), cb in zip(blocks, cuts):
                    for j, i in enumerate(act):
                        sl = slice(int(cb[j]), int(cb[j + 1]))
                        okb = ok_b[sl]
                        n_ok_lane[i] += int(np.count_nonzero(okb))
                        rows_lane[i] += sl.stop - sl.start
                        got = contrib[int(i)]
                        if len(got) < goals[i]:
                            got.extend(fb_b["client_id"][sl][okb]
                                       [:goals[i] - len(got)].tolist())
            else:
                # population-scale pack: resolve in bounded chunks keeping
                # only end_t/ok for the round close; pass 2 re-plans
                # (row-pure, bit-identical — see the serial loop)
                et_parts, ok_parts = [], []
                for lo in range(0, total, _DISPATCH_CHUNK):
                    sc = slice(lo, lo + _DISPATCH_CHUNK)
                    _, fb_c, ok_c = lanes.plan_resolve(
                        lane_row[sc], ids[sc], k, start[sc])
                    et_parts.append(fb_c["end_t"])
                    ok_parts.append(ok_c)
                end_t = np.concatenate(et_parts)
                ok = np.concatenate(ok_parts)
                round_end = np.empty(len(act))
                for j, i in enumerate(act):
                    sl = slice(offs[j], offs[j + 1])
                    ends = end_t[sl][ok[sl]]
                    g = goals[i]
                    if len(ends) >= g:
                        round_end[j] = np.partition(ends, g - 1)[g - 1]
                    elif len(ends):
                        round_end[j] = ends.max()
                    else:
                        seg = end_t[sl]
                        round_end[j] = seg.max() if len(seg) else pack.t[i]
                deadline_rows = np.repeat(round_end, sizes)
                ok2_parts: List[np.ndarray] = []
                for lo in range(0, total, _DISPATCH_CHUNK):
                    sc = slice(lo, lo + _DISPATCH_CHUNK)
                    pb_c, fb_c, ok2_c = lanes.plan_resolve(
                        lane_row[sc], ids[sc], k, start[sc])
                    lanes.apply_deadline(
                        pb_c, fb_c, ok2_c, deadline_rows[sc],
                        late_code=(late_arr[lane_row[sc]]
                                   if any_osel else None))
                    pack.acc.append(lane=lane_row[sc], **fb_c)
                    ok2_parts.append(ok2_c)
                ok2 = np.concatenate(ok2_parts)
                n_ok_lane = np.zeros(L, np.int64)
                rows_lane = np.zeros(L, np.int64)
                contrib = {int(i): [] for i in act}
                for j, i in enumerate(act):
                    sl = slice(offs[j], offs[j + 1])
                    n_ok_lane[i] = int(np.count_nonzero(ok2[sl]))
                    rows_lane[i] = int(sizes[j])
                    contrib[int(i)] = cohorts[j][
                        np.flatnonzero(ok2[sl])[:goals[i]]].tolist()
            k += 1
            for j, i in enumerate(act):
                contributors = contrib[int(i)]
                starved = bool(n_ok_lane[i] < quorum[i])
                pack.t[i] = round_end[j] + _SERVER_AGG_S
                pack.rounds[i] = k
                stop = pack.stoppers[i]
                if not starved and contributors:
                    pack.ppl[i] = _sync_server_update(pack.learners[i],
                                                      contributors)
                    stop.update(pack.ppl[i])
                pack.n_logged[i] += int(rows_lane[i])
                pack.close_round(i, k, self.mode, starved=starved)
                if starved:
                    streak[i] += 1
                    if pack.feds[i].starvation_patience \
                            and streak[i] >= pack.feds[i].starvation_patience:
                        stop.aborted = True
                        pack.active[i] = False
                        continue
                else:
                    streak[i] = 0
                if stop.reached or stop.out_of_budget(pack.t[i], k):
                    pack.active[i] = False


# async pool fields that only the window close needs (the expansion phase
# works on slot/gen/end/ok alone, so these stay as per-generation blocks
# and are concatenated once per window)
_DEFERRED = ("cid", "ver", "start", "d", "c", "u", "bd", "bu",
             "dev", "ctry", "out")

# canonical in-flight column order (what `_async_rows` returns) — the
# engine-snapshot payload stores/restores the flight dict by these keys
_FLIGHT_FIELDS = ("slot", "gen", "cid", "ver", "start", "end", "d", "c",
                  "u", "bd", "bu", "dev", "ctry", "out", "ok", "att", "nrem")


def _async_rows(slots: np.ndarray, gens: np.ndarray, version: int,
                batch: SessionBatch, ok: np.ndarray,
                att: Optional[np.ndarray] = None,
                nrem: Optional[np.ndarray] = None) -> Dict[str, np.ndarray]:
    """One column block of dispatched async sessions (slot + generation
    identify the session; everything else comes from ``resolve_batch``).
    ``att`` is the row's consecutive-failure retry counter (0 = a fresh
    dispatch, not a retry); ``nrem`` the remainder fraction this row's
    retry successor would redo (1.0 outside checkpoint/resume salvage —
    see ``_retry_rem``)."""
    n = len(ok)
    return dict(slot=np.asarray(slots, np.int64),
                gen=np.asarray(gens, np.int64),
                cid=batch.client_id,
                ver=np.full(n, version, np.int64),
                start=batch.start_t, end=batch.end_t,
                d=batch.download_s, c=batch.compute_s, u=batch.upload_s,
                bd=batch.bytes_down, bu=batch.bytes_up,
                dev=batch.device_idx, ctry=batch.country_idx,
                out=batch.outcome, ok=ok,
                att=(np.zeros(n, np.int64) if att is None
                     else np.asarray(att, np.int64)),
                nrem=(np.ones(n) if nrem is None
                      else np.asarray(nrem, np.float64)))


def _async_rows_cols(slots: np.ndarray, gens: np.ndarray, version: int,
                     cols: Dict[str, np.ndarray], ok: np.ndarray,
                     att: Optional[np.ndarray] = None,
                     nrem: Optional[np.ndarray] = None
                     ) -> Dict[str, np.ndarray]:
    """``_async_rows`` over a LaneSampler column dict instead of a
    SessionBatch (the lane-batched async loop's dispatch format)."""
    n = len(ok)
    return dict(slot=np.asarray(slots, np.int64),
                gen=np.asarray(gens, np.int64),
                cid=cols["client_id"],
                ver=np.full(n, version, np.int64),
                start=cols["start_t"], end=cols["end_t"],
                d=cols["download_s"], c=cols["compute_s"],
                u=cols["upload_s"],
                bd=cols["bytes_down"], bu=cols["bytes_up"],
                dev=cols["device_idx"], ctry=cols["country_idx"],
                out=cols["outcome"], ok=ok,
                att=(np.zeros(n, np.int64) if att is None
                     else np.asarray(att, np.int64)),
                nrem=(np.ones(n) if nrem is None
                      else np.asarray(nrem, np.float64)))


def _truncate_cancelled(flight: Dict[str, np.ndarray], idx: np.ndarray,
                        t_final: float) -> Dict[str, np.ndarray]:
    """In-flight sessions at task end: truncate the burned phases at the
    final task clock (a device stops the moment the task is torn down),
    prorate downlink bytes to the downloaded fraction, and zero uplink
    bytes (the result never reached the server). Mirrored scalar-ly by the
    reference oracle's flush — keep the two numerically identical."""
    d, c, u = flight["d"][idx], flight["c"][idx], flight["u"][idx]
    cap = np.maximum(0.0, t_final - flight["start"][idx])
    nd = np.minimum(d, cap)
    nc = np.minimum(c, np.maximum(0.0, cap - d))
    nu = np.minimum(u, np.maximum(0.0, cap - d - c))
    frac = np.divide(nd, d, out=np.zeros(len(idx)), where=d > 0)
    # a pending retry may be scheduled past the task end (backoff delay):
    # it burned nothing, but never let end_t precede start_t
    return dict(download_s=nd, compute_s=nc, upload_s=nu,
                bytes_down=flight["bd"][idx] * frac,
                bytes_up=np.zeros(len(idx)),
                end_t=np.minimum(flight["end"][idx],
                                 np.maximum(t_final, flight["start"][idx])))


@register_strategy("async")
class AsyncStrategy(Strategy):
    """FedBuff: always-`concurrency` in-flight clients, buffer size =
    aggregation_goal, staleness-weighted aggregation — vectorized as a
    window-batched exact merge (no event heap).

    Two facts make the merge exact:

    * arrivals are globally sorted by ``(end_t, slot, generation)``: every
      dispatch happens at the then-current clock, so a replacement's end
      never precedes its predecessor's — the old heap's pop order IS this
      sort order;
    * replacement *identity* is decoupled from pop *rank*: slot s draws
      its g-th replacement id from a counter-based splitmix64 stream
      (``slot_stream_ids``), so chained replacements inside a window can
      be planned/resolved as arrays without knowing global arrival order
      first (the circularity that previously forced per-pop dispatch).

    Each window (the span between two server updates) resolves all
    candidate arrivals columnar-ly, finds the update boundary with a
    cumsum over ok flags (the ``aggregation_goal``-th ok arrival), and
    expands chained replacements generation-by-generation until no
    undiscovered arrival precedes the boundary. A speculative chain row
    can never move the boundary wrongly: any row with key <= boundary has
    its whole ancestor chain at strictly smaller keys, so the ancestors
    all pop and the row is validly dispatched. Sessions still in flight
    when the task ends are logged as ``cancelled``, truncated at the
    final clock.
    """

    # ------------------------------------------------------ dispatch hooks
    # Replacement *identity* is the one policy axis subclasses may bend
    # without touching the window merge: ids must stay a pure function of
    # (seed, slot, generation, dispatch clock, model version) — never of
    # global arrival order — so the merge, the lane engine and the scalar
    # oracle keep replaying the same draws. The carbon-aware strategy
    # overrides these to screen candidates by grid intensity.
    # ``skip`` marks rows whose ids the caller overwrites right after the
    # call (the retry stream) — strategies with expensive screening use it
    # to avoid paying for picks that are discarded. The cheap counter
    # streams ignore it (masking would cost more than the draw).
    def _replacement_ids(self, sampler: SessionSampler, fed: FederatedConfig,
                         slots: np.ndarray, gens: np.ndarray,
                         starts: np.ndarray, version: int,
                         skip: Optional[np.ndarray] = None) -> np.ndarray:
        return slot_stream_ids(fed.seed, slots, gens, _POPULATION)

    def _lane_replacement_ids(self, pack: "_LanePack", lane: np.ndarray,
                              slots: np.ndarray, gens: np.ndarray,
                              starts: np.ndarray, version: int,
                              skip: Optional[np.ndarray] = None
                              ) -> np.ndarray:
        return pack.lanes.slot_stream_ids(lane, slots, gens, _POPULATION)

    def _loop(self, model_cfg, fed, learner, sampler, log, stop, on_round):
        assert fed.mode == self.mode
        rng = np.random.default_rng(fed.seed + 2)
        conc = fed.concurrency
        goal = fed.aggregation_goal
        t = 0.0
        version = 0
        ppl = float(model_cfg.vocab_size)
        max_t = stop.run.max_hours * 3600.0
        acc = self._make_sink(log, sampler.device_names,
                              sampler.country_names)
        # recovery policy: failed AND churn-interrupted rows chain a RETRY
        # successor (distinct id stream, exponential backoff, attempt
        # counter up) instead of a fresh replacement; `att` rides the
        # flight/expansion columns. Checkpoint/resume: an interrupted
        # row's retry redoes only the un-checkpointed remainder (`nrem`
        # rides along — see ``_retry_rem``).
        retry_on = (sampler.has_faults or sampler.has_avail) \
            and fed.retry_limit > 0
        salv_on = retry_on and sampler.has_avail \
            and fed.checkpoint_period_s > 0
        F, R = OUTCOME_CODE["failed"], OUTCOME_CODE["retried"]
        I = OUTCOME_CODE["interrupted"]

        snap = getattr(self, "_snap", None)
        if snap is not None and snap.engine_state is not None:
            # resume at a window boundary: the flight columns + scalars
            # are the whole loop state (the init RNG below this point is
            # never consumed again, and every later draw is counter-keyed)
            es = snap.engine_state
            t = float(es["t"])
            version = int(es["version"])
            ppl = float(es["ppl"])
            alive = np.asarray(es["alive"], bool).copy()
            flight = {f: np.asarray(es["flight_" + f]).copy()
                      for f in _FLIGHT_FIELDS}
            sb = snap.sink_batch()
            if sb is not None and acc is not log:
                # pre-checkpoint pops re-enter the staging sink (streaming
                # folds were restored into the log itself by Strategy.run)
                acc.append(client_id=sb.client_id, round_idx=sb.round_idx,
                           device_idx=sb.device_idx,
                           country_idx=sb.country_idx,
                           download_s=sb.download_s, compute_s=sb.compute_s,
                           upload_s=sb.upload_s, bytes_down=sb.bytes_down,
                           bytes_up=sb.bytes_up, start_t=sb.start_t,
                           end_t=sb.end_t, outcome=sb.outcome,
                           staleness=sb.staleness)
        else:
            # initial cohort: batched plan/resolve with jittered starts, in
            # bounded chunks at population scale (row-pure, so chunking is
            # bit-identical); slot s starts out running cohort[s] at
            # generation 0
            cohort = _select_cohort(rng, conc, population=_POPULATION)
            starts0 = rng.uniform(0, 5.0, size=conc)
            flight: Optional[Dict[str, np.ndarray]] = None
            for lo in range(0, conc, _DISPATCH_CHUNK):
                sc = slice(lo, min(lo + _DISPATCH_CHUNK, conc))
                pb0 = sampler.plan_batch(cohort[sc], version)
                b0, ok0 = sampler.resolve_batch(pb0, version, starts0[sc])
                nr0 = _retry_rem(b0.outcome, pb0.compute_s, b0.compute_s,
                                 np.ones(len(ok0)), fed.checkpoint_period_s) \
                    if salv_on else None
                rows = _async_rows(
                    np.arange(sc.start, sc.stop, dtype=np.int64),
                    np.zeros(sc.stop - sc.start, np.int64),
                    version, b0, ok0, nrem=nr0)
                if flight is None and conc <= _DISPATCH_CHUNK:
                    flight = rows
                    break
                if flight is None:
                    flight = {f: np.empty(conc, a.dtype)
                              for f, a in rows.items()}
                for f, a in rows.items():
                    flight[f][sc] = a
            alive = np.ones(conc, bool)

        while True:
            if t >= max_t or version >= stop.run.max_rounds:
                break
            if snap is not None:
                snap.tick(version, lambda: (
                    dict(t=t, version=version, ppl=ppl, alive=alive,
                         **{"flight_" + f: flight[f]
                            for f in _FLIGHT_FIELDS}),
                    None if acc is log else acc),
                    log, learner, stop)
            t0 = t
            # ---- expansion phase: discover this window's arrivals -------
            # Chains are expanded against a cheap upper bound on the window
            # end — the goal-th smallest ok end (a partition, not a sort)
            # and/or the first end at/past the time budget. The bound only
            # tightens as rows join, so "every unexpanded row sits past the
            # bound" is a sound fixed point; the single exact lexsort below
            # then settles the boundary.
            slot_all, gen_all = flight["slot"], flight["gen"]
            end_all, ok_all = flight["end"], flight["ok"]
            att_all = flight["att"]
            nrem_all = flight["nrem"]
            out_run = flight["out"] if retry_on else None
            parts: Dict[str, List[np.ndarray]] = \
                {f: [flight[f]] for f in _DEFERRED}
            succ = np.full(conc, -1, np.int64)   # row -> successor row
            n_rows = conc
            while True:
                bound = np.inf
                if int(np.count_nonzero(ok_all)) >= goal:
                    bound = float(np.partition(end_all[ok_all],
                                               goal - 1)[goal - 1])
                over = end_all[end_all >= max_t]
                if len(over):
                    # the budget check runs before each pop against the
                    # PREVIOUS arrival's clock, so the first arrival at/past
                    # max_t still pops before the loop stops
                    bound = min(bound, float(over.min()))
                frontier = succ < 0
                if not np.isinf(bound):
                    frontier &= end_all <= bound
                    if not frontier.any():
                        break
                need = np.nonzero(frontier)[0]
                slots_n = slot_all[need]
                gens_n = gen_all[need] + 1
                starts_n = np.maximum(t0, end_all[need])
                if retry_on:
                    prev_att = att_all[need]
                    rf = ((out_run[need] == F) | (out_run[need] == I)) \
                        & (prev_att < fed.retry_limit)
                    att_n = np.where(rf, prev_att + 1, 0)
                    starts_n = starts_n + np.where(
                        rf, fed.retry_backoff_s * 2.0 ** prev_att, 0.0)
                else:
                    att_n = np.zeros(len(need), np.int64)
                ids_n = self._replacement_ids(
                    sampler, fed, slots_n, gens_n, starts_n, version,
                    skip=rf if retry_on else None)
                if retry_on and rf.any():
                    ids_n[rf] = retry_stream_ids(fed.seed, slots_n[rf],
                                                 gens_n[rf], _POPULATION)
                pb_n = sampler.plan_batch(ids_n, version)
                rem_n = None
                if salv_on:
                    # retry children redo their parent's remainder only
                    rem_n = np.where(rf, nrem_all[need], 1.0)
                    np.multiply(pb_n.compute_s, rem_n, out=pb_n.compute_s)
                bn, okn = sampler.resolve_batch(pb_n, version, starts_n)
                nrem_n = _retry_rem(bn.outcome, pb_n.compute_s,
                                    bn.compute_s, rem_n,
                                    fed.checkpoint_period_s) \
                    if salv_on else None
                succ[need] = n_rows + np.arange(len(need))
                n_rows += len(need)
                succ = np.concatenate(
                    [succ, np.full(len(need), -1, np.int64)])
                slot_all = np.concatenate([slot_all, slots_n])
                gen_all = np.concatenate([gen_all, gens_n])
                end_all = np.concatenate([end_all, bn.end_t])
                ok_all = np.concatenate([ok_all, okn])
                att_all = np.concatenate([att_all, att_n])
                new = _async_rows(slots_n, gens_n, version, bn, okn, att_n,
                                  nrem=nrem_n)
                nrem_all = np.concatenate([nrem_all, new["nrem"]])
                for f in _DEFERRED:
                    parts[f].append(new[f])
                if retry_on:
                    out_run = np.concatenate([out_run, new["out"]])
            # ---- exact close: one lexsort settles the boundary ----------
            order = np.lexsort((gen_all, slot_all, end_all))
            ends_sorted = end_all[order]
            cum = np.cumsum(ok_all[order])
            b_pos = int(np.searchsorted(cum, goal)) \
                if cum[-1] >= goal else -1
            cut = int(np.searchsorted(ends_sorted, max_t, side="left"))
            if 0 <= b_pos <= cut:
                pops_to, closes = b_pos, "update"
            else:
                pops_to, closes = cut, "budget"   # cut < n_rows: bound was
            pop_idx = order[:pops_to + 1]         # finite via max_t
            # every pop precedes the bound, so its chain was expanded
            assert succ[pop_idx].min() >= 0
            A = {"slot": slot_all, "gen": gen_all,
                 "end": end_all, "ok": ok_all, "att": att_all,
                 "nrem": nrem_all,
                 **{f: np.concatenate(p) if len(p) > 1 else p[0]
                    for f, p in parts.items()}}
            # ---- log pops, advance per-slot chains ----------------------
            okm = A["ok"][pop_idx]
            out_p = A["out"][pop_idx]
            if retry_on:
                # label at LOG time only (parts blocks alias the flight
                # arrays): a failed pop with attempt budget left had a
                # retry successor scheduled -> "retried"
                out_p = np.where((out_p == F)
                                 & (A["att"][pop_idx] < fed.retry_limit),
                                 R, out_p)
            acc.append(client_id=A["cid"][pop_idx],
                       round_idx=A["ver"][pop_idx],
                       device_idx=A["dev"][pop_idx],
                       country_idx=A["ctry"][pop_idx],
                       download_s=A["d"][pop_idx],
                       compute_s=A["c"][pop_idx],
                       upload_s=A["u"][pop_idx],
                       bytes_down=A["bd"][pop_idx],
                       bytes_up=A["bu"][pop_idx],
                       start_t=A["start"][pop_idx],
                       end_t=A["end"][pop_idx],
                       outcome=out_p,
                       staleness=version - A["ver"][pop_idx])
            # per-slot chain tip among the pops -> its successor goes
            # in-flight (fancy-index write is made unique by the tip mask)
            sl, gn = A["slot"][pop_idx], A["gen"][pop_idx]
            best = np.full(conc, -1, np.int64)
            np.maximum.at(best, sl, gn)
            is_tip = gn == best[sl]
            tip_slots = sl[is_tip]
            repl_rows = succ[pop_idx[is_tip]]
            for f in flight:
                flight[f][tip_slots] = A[f][repl_rows]
            if closes == "budget":
                t = max(t0, float(ends_sorted[pops_to]))
                break
            # ---- server update at the boundary arrival ------------------
            b_row = int(pop_idx[-1])
            vers_ok = A["ver"][pop_idx][okm]
            ppl = _async_server_update(learner, A["cid"][pop_idx][okm],
                                       vers_ok, version)
            version += 1
            t = max(t0, float(A["end"][b_row])) + _SERVER_AGG_S
            stop.update(ppl)
            log.log_round(t)
            log.log_eval(t, version, ppl, stop.smoothed or ppl)
            self._emit(on_round, len(acc), version, t, ppl,
                       stop.smoothed or ppl)
            b_slot = int(A["slot"][b_row])
            if stop.reached or stop.out_of_budget(t, version):
                alive[b_slot] = False   # its replacement never went out
                break
            # the boundary slot's replacement goes out AFTER the update,
            # against the new model version (the plain async stream id is
            # version-independent; a carbon-aware pick may differ from the
            # speculative expansion row, which is overwritten here anyway)
            b_gen = int(A["gen"][b_row]) + 1
            nid = self._replacement_ids(sampler, fed,
                                        np.asarray([b_slot], np.int64),
                                        np.asarray([b_gen], np.int64),
                                        np.asarray([t]), version)
            pb_b1 = sampler.plan_batch(nid, version)
            b1, okb = sampler.resolve_batch(pb_b1, version, t)
            nrem_b = _retry_rem(b1.outcome, pb_b1.compute_s, b1.compute_s,
                                np.ones(1), fed.checkpoint_period_s) \
                if salv_on else None
            row = _async_rows(np.asarray([b_slot], np.int64),
                              np.asarray([b_gen], np.int64), version, b1,
                              okb, nrem=nrem_b)
            for f in flight:
                flight[f][b_slot] = row[f][0]

        # ---- task end: in-flight sessions are logged as cancelled -------
        idx = np.nonzero(alive)[0]
        if len(idx):
            acc.append(client_id=flight["cid"][idx],
                       round_idx=flight["ver"][idx],
                       device_idx=flight["dev"][idx],
                       country_idx=flight["ctry"][idx],
                       start_t=flight["start"][idx],
                       outcome=np.full(len(idx), OUTCOME_CODE["cancelled"],
                                       np.int8),
                       staleness=version - flight["ver"][idx],
                       **_truncate_cancelled(flight, idx, t))
        self._flush_sink(log, acc)
        return t, version, ppl

    def lane_loop(self, pack: "_LanePack") -> None:
        """Lockstep lane-batched twin of ``_loop``: every iteration closes
        one window (one server update) per active lane. The per-lane flight
        state lives in one concatenated array store (``offsets`` maps lane
        -> slot block); the expansion fixed point interleaves all lanes'
        chain discovery so each inner iteration issues ONE batched
        plan/resolve for every lane's frontier, and the post-update
        boundary redispatches batch into a single L-row call — the two
        per-window fixed costs that dominate small-concurrency sweeps.
        Per-lane bounds/lexsort/boundary bookkeeping are unchanged from the
        serial loop, just applied to lane slices, so the merge stays exact
        per lane. Active lanes always share the lockstep version ``k``."""
        lanes = pack.lanes
        feds = pack.feds
        L = pack.n_lanes
        concs = np.asarray([f.concurrency for f in feds], np.int64)
        goals = [f.aggregation_goal for f in feds]
        offsets = np.concatenate([[0], np.cumsum(concs)])
        max_ts = [r.max_hours * 3600.0 for r in pack.runs]
        max_rounds = [r.max_rounds for r in pack.runs]
        # per-lane recovery policy (0 disables; see serial `_loop`)
        retry_lim = np.asarray(
            [f.retry_limit if (s.has_faults or s.has_avail) else 0
             for f, s in zip(feds, lanes.samplers)], np.int64)
        retry_bo = np.asarray([f.retry_backoff_s for f in feds])
        retry_on = bool((retry_lim > 0).any())
        # per-lane checkpoint salvage (see serial `_loop`)
        lane_P = np.asarray(
            [f.checkpoint_period_s
             if (s.has_avail and f.retry_limit > 0) else 0.0
             for f, s in zip(feds, lanes.samplers)])
        any_salv = bool((lane_P > 0).any())
        F, R = OUTCOME_CODE["failed"], OUTCOME_CODE["retried"]
        I = OUTCOME_CODE["interrupted"]
        # ---- initial cohorts: one batched resolve across all lanes ------
        rngs = [np.random.default_rng(f.seed + 2) for f in feds]
        cohorts, starts0 = [], []
        for i, f in enumerate(feds):
            cohorts.append(_select_cohort(rngs[i], f.concurrency,
                                          _POPULATION))
            starts0.append(rngs[i].uniform(0, 5.0, size=f.concurrency))
        lane_of = np.repeat(np.arange(L, dtype=np.intp), concs)
        slot_of = np.concatenate(
            [np.arange(c, dtype=np.int64) for c in concs])
        ids0 = np.concatenate(cohorts)
        st0 = np.concatenate(starts0)
        n_slots = len(slot_of)
        if n_slots <= _DISPATCH_CHUNK:
            pb0, b0, ok0 = lanes.plan_resolve(lane_of, ids0, 0, st0)
            nr0 = _retry_rem(b0["outcome"], pb0.compute_s, b0["compute_s"],
                             np.ones(n_slots), lane_P[lane_of]) \
                if any_salv else None
            flight = _async_rows_cols(slot_of,
                                      np.zeros(n_slots, np.int64),
                                      0, b0, ok0, nrem=nr0)
        else:
            # population-scale pack: bounded-chunk dispatch (row-pure,
            # bit-identical to the one-shot resolve)
            flight = None
            for lo in range(0, n_slots, _DISPATCH_CHUNK):
                sc = slice(lo, min(lo + _DISPATCH_CHUNK, n_slots))
                pb0, b0, ok0 = lanes.plan_resolve(lane_of[sc], ids0[sc], 0,
                                                  st0[sc])
                nr0 = _retry_rem(b0["outcome"], pb0.compute_s,
                                 b0["compute_s"],
                                 np.ones(sc.stop - sc.start),
                                 lane_P[lane_of[sc]]) \
                    if any_salv else None
                rows = _async_rows_cols(slot_of[sc],
                                        np.zeros(sc.stop - sc.start,
                                                 np.int64), 0, b0, ok0,
                                        nrem=nr0)
                if flight is None:
                    flight = {f: np.empty(n_slots, a.dtype)
                              for f, a in rows.items()}
                for f, a in rows.items():
                    flight[f][sc] = a
        alive = np.ones(int(offsets[-1]), bool)
        k = 0                        # == every active lane's `version`

        def _flush_cancelled(i: int, t_final: float, version_i: int) -> None:
            """Lane i is done: log its in-flight slots as cancelled
            (truncated at its final clock) and deactivate it."""
            lo, hi = int(offsets[i]), int(offsets[i + 1])
            idx = lo + np.flatnonzero(alive[lo:hi])
            if len(idx):
                pack.acc.append(lane=np.full(len(idx), i, np.int32),
                                client_id=flight["cid"][idx],
                                round_idx=flight["ver"][idx],
                                device_idx=flight["dev"][idx],
                                country_idx=flight["ctry"][idx],
                                start_t=flight["start"][idx],
                                outcome=np.full(len(idx),
                                                OUTCOME_CODE["cancelled"],
                                                np.int8),
                                staleness=version_i - flight["ver"][idx],
                                **_truncate_cancelled(flight, idx, t_final))
                pack.n_logged[i] += len(idx)
            pack.active[i] = False

        while True:
            for i in np.flatnonzero(pack.active):
                if pack.t[i] >= max_ts[i] or k >= max_rounds[i]:
                    _flush_cancelled(i, float(pack.t[i]), k)
            act = np.flatnonzero(pack.active)
            if not len(act):
                break
            t0 = pack.t.copy()
            # ---- expansion: all active lanes' windows discover at once --
            rows_idx = np.concatenate(
                [np.arange(offsets[i], offsets[i + 1]) for i in act])
            win_lane = lane_of[rows_idx]
            slot_all = flight["slot"][rows_idx]
            gen_all = flight["gen"][rows_idx]
            end_all = flight["end"][rows_idx]
            ok_all = flight["ok"][rows_idx]
            att_all = flight["att"][rows_idx]
            nrem_all = flight["nrem"][rows_idx]
            out_run = flight["out"][rows_idx] if retry_on else None
            parts: Dict[str, List[np.ndarray]] = \
                {f: [flight[f][rows_idx]] for f in _DEFERRED}
            succ = np.full(len(rows_idx), -1, np.int64)
            n_rows = len(rows_idx)
            # Expansion bounds are SOUND, not tight: a lane's bound only
            # has to sit at/above its final boundary, because rows beyond
            # the boundary are speculative — never popped, never logged,
            # never in flight (only popped tips' successors survive). So
            # unlike the serial loop, the goal-bound partition runs ONCE
            # per lane per window (not per inner iteration) over the rows
            # present at computation time — it can only be looser than the
            # serial re-tightened bound, trading a few extra speculative
            # resolves (batched, cheap) for per-lane Python (expensive at
            # lane-pack scale). Lanes below goal recheck as arrivals join;
            # the over-budget fallback min updates vectorized.
            goal_bound = np.full(L, np.inf)
            over_min = np.full(L, np.inf)
            max_t_arr = np.asarray(max_ts)
            goals_arr = np.asarray(goals, np.int64)
            n_ok_lane = np.bincount(win_lane[ok_all], minlength=L)
            ov0 = end_all >= max_t_arr[win_lane]
            if ov0.any():
                np.minimum.at(over_min, win_lane[ov0], end_all[ov0])
            below: List[int] = []
            pos = 0
            for i in act:
                sl = slice(pos, pos + int(concs[i]))
                pos += int(concs[i])
                if n_ok_lane[i] >= goals[i]:
                    e_i, o_i = end_all[sl], ok_all[sl]
                    goal_bound[i] = np.partition(e_i[o_i],
                                                 goals[i] - 1)[goals[i] - 1]
                else:
                    below.append(i)
            unexp = np.arange(n_rows, dtype=np.int64)
            while True:
                if below:
                    for i in list(below):
                        if n_ok_lane[i] >= goals[i]:
                            m_i = win_lane == i
                            e_i = end_all[m_i]
                            goal_bound[i] = np.partition(
                                e_i[ok_all[m_i]],
                                goals[i] - 1)[goals[i] - 1]
                            below.remove(i)
                bound_row = np.minimum(goal_bound,
                                       over_min)[win_lane[unexp]]
                m = end_all[unexp] <= bound_row   # inf bound passes all
                need = unexp[m]
                if not len(need):
                    break
                unexp = unexp[~m]
                lanes_n = win_lane[need]
                slots_n = slot_all[need]
                gens_n = gen_all[need] + 1
                starts_n = np.maximum(t0[lanes_n], end_all[need])
                if retry_on:
                    prev_att = att_all[need]
                    rf = ((out_run[need] == F) | (out_run[need] == I)) \
                        & (prev_att < retry_lim[lanes_n])
                    att_n = np.where(rf, prev_att + 1, 0)
                    starts_n = starts_n + np.where(
                        rf, retry_bo[lanes_n] * 2.0 ** prev_att, 0.0)
                else:
                    att_n = np.zeros(len(need), np.int64)
                ids_n = self._lane_replacement_ids(
                    pack, lanes_n, slots_n, gens_n, starts_n, k,
                    skip=rf if retry_on else None)
                if retry_on and rf.any():
                    ids_n[rf] = lanes.retry_stream_ids(
                        lanes_n[rf], slots_n[rf], gens_n[rf], _POPULATION)
                rem_n = None
                if any_salv:
                    # retry children redo their parent's remainder only
                    rem_n = np.where(rf, nrem_all[need], 1.0)
                pb_n, bn, okn = lanes.plan_resolve(lanes_n, ids_n, k,
                                                   starts_n, rem=rem_n)
                nrem_n = _retry_rem(bn["outcome"], pb_n.compute_s,
                                    bn["compute_s"], rem_n,
                                    lane_P[lanes_n]) \
                    if any_salv else None
                end_n = bn["end_t"]
                succ[need] = n_rows + np.arange(len(need))
                unexp = np.concatenate(
                    [unexp, np.arange(n_rows, n_rows + len(need),
                                      dtype=np.int64)])
                n_rows += len(need)
                succ = np.concatenate(
                    [succ, np.full(len(need), -1, np.int64)])
                win_lane = np.concatenate([win_lane, lanes_n])
                slot_all = np.concatenate([slot_all, slots_n])
                gen_all = np.concatenate([gen_all, gens_n])
                end_all = np.concatenate([end_all, end_n])
                ok_all = np.concatenate([ok_all, okn])
                att_all = np.concatenate([att_all, att_n])
                new = _async_rows_cols(slots_n, gens_n, k, bn, okn, att_n,
                                       nrem=nrem_n)
                nrem_all = np.concatenate([nrem_all, new["nrem"]])
                for f in _DEFERRED:
                    parts[f].append(new[f])
                if retry_on:
                    out_run = np.concatenate([out_run, new["out"]])
                if below:
                    n_ok_lane = n_ok_lane + np.bincount(lanes_n[okn],
                                                        minlength=L)
                ov = end_n >= max_t_arr[lanes_n]
                if ov.any():
                    np.minimum.at(over_min, lanes_n[ov], end_n[ov])
            # ---- per-lane exact close (unchanged serial logic on slices)
            A = {"slot": slot_all, "gen": gen_all,
                 "end": end_all, "ok": ok_all, "att": att_all,
                 "nrem": nrem_all,
                 **{f: np.concatenate(p) if len(p) > 1 else p[0]
                    for f, p in parts.items()}}
            # ONE lexsort settles every lane's boundary: keying by (lane,
            # end, slot, gen) makes each lane's segment contiguous AND
            # internally sorted exactly like the serial per-lane lexsort
            # ((slot, gen) is unique within a lane); a global cumsum then
            # gives each lane's ok-count prefix via one base subtraction
            order = np.lexsort((gen_all, slot_all, end_all, win_lane))
            lane_sorted = win_lane[order]
            ends_s = end_all[order]
            cum_pad = np.concatenate(([0], np.cumsum(ok_all[order])))
            seg = np.searchsorted(lane_sorted, act, side="left")
            seg = np.append(seg, len(lane_sorted))
            # vectorized boundary search: the global ok-cumsum is monotone,
            # so one searchsorted per window finds every lane's goal-th ok
            # arrival (b_pos), with per-lane bases read off the segment
            # starts
            bases = cum_pad[seg[:-1]]
            tot_ok = cum_pad[seg[1:]] - bases
            b_glob = np.searchsorted(cum_pad[1:], bases + goals_arr[act])
            pops_to_arr = np.empty(len(act), np.int64)
            closes_upd = np.zeros(len(act), bool)
            for j, i in enumerate(act):
                lo = int(seg[j])
                b_pos = int(b_glob[j]) - lo if tot_ok[j] >= goals[i] else -1
                cut = int(np.searchsorted(ends_s[lo:int(seg[j + 1])],
                                          max_ts[i], side="left"))
                if 0 <= b_pos <= cut:
                    pops_to_arr[j], closes_upd[j] = b_pos, True
                else:
                    pops_to_arr[j] = cut
            # one batched gather serves the tip updates, the log append
            # and the per-lane server updates (views into the pop block)
            pop_parts = [order[int(seg[j]):int(seg[j]) + int(p) + 1]
                         for j, p in enumerate(pops_to_arr)]
            pops = np.concatenate(pop_parts) \
                if len(pop_parts) > 1 else pop_parts[0]
            sizes_p = np.asarray([len(p) for p in pop_parts])
            offs_p = np.concatenate([[0], np.cumsum(sizes_p)])
            pop_lane_rep = np.repeat(act, sizes_p)
            # every pop precedes its lane's bound, so its chain expanded
            assert succ[pops].min() >= 0
            ok_p = A["ok"][pops]
            ver_p = A["ver"][pops]
            cid_p = A["cid"][pops]
            end_p = A["end"][pops]
            slot_p = A["slot"][pops]
            gen_p = A["gen"][pops]
            # per-slot chain tips (slots disjoint across lanes, so one
            # global maximum.at replaces L per-lane passes) -> successors
            # go in flight before the cancelled flushes read it
            slots_glob = offsets[pop_lane_rep] + slot_p
            best = np.full(int(offsets[-1]), -1, np.int64)
            np.maximum.at(best, slots_glob, gen_p)
            is_tip = gen_p == best[slots_glob]
            tip_rows = slots_glob[is_tip]
            repl_rows = succ[pops[is_tip]]
            for f in flight:
                flight[f][tip_rows] = A[f][repl_rows]
            # one batched append logs every lane's pops for this window
            # (within-lane order is pop order, which is all that matters);
            # cancelled flushes follow so a closing lane's store order
            # stays pops-then-cancelled like the serial loop's
            out_p = A["out"][pops]
            if retry_on:
                # relabel on the fancy-index copy only (see serial `_loop`)
                out_p = np.where((out_p == F)
                                 & (A["att"][pops]
                                    < retry_lim[pop_lane_rep]),
                                 R, out_p)
            pack.acc.append(lane=pop_lane_rep,
                            client_id=cid_p,
                            round_idx=ver_p,
                            device_idx=A["dev"][pops],
                            country_idx=A["ctry"][pops],
                            download_s=A["d"][pops],
                            compute_s=A["c"][pops],
                            upload_s=A["u"][pops],
                            bytes_down=A["bd"][pops],
                            bytes_up=A["bu"][pops],
                            start_t=A["start"][pops],
                            end_t=end_p,
                            outcome=out_p,
                            staleness=k - ver_p)
            redis: List[Tuple[int, int, int]] = []   # (lane, slot, gen)
            flush_q: List[Tuple[int, float, int]] = []
            for j, i in enumerate(act):
                sl = slice(int(offs_p[j]), int(offs_p[j + 1]))
                pack.n_logged[i] += sl.stop - sl.start
                if not closes_upd[j]:
                    pack.t[i] = max(float(t0[i]), float(end_p[sl.stop - 1]))
                    flush_q.append((i, float(pack.t[i]), k))
                    continue
                # ---- server update at the boundary arrival --------------
                okm = ok_p[sl]
                vers_ok = ver_p[sl][okm]
                pack.ppl[i] = _async_server_update(
                    pack.learners[i], cid_p[sl][okm], vers_ok, k)
                pack.t[i] = max(float(t0[i]),
                                float(end_p[sl.stop - 1])) + _SERVER_AGG_S
                stop = pack.stoppers[i]
                stop.update(pack.ppl[i])
                pack.rounds[i] = k + 1
                pack.close_round(i, k + 1, self.mode)
                b_slot = int(slot_p[sl.stop - 1])
                if stop.reached or stop.out_of_budget(pack.t[i], k + 1):
                    alive[int(offsets[i]) + b_slot] = False
                    flush_q.append((i, float(pack.t[i]), k + 1))
                    continue
                redis.append((i, b_slot, int(gen_p[sl.stop - 1]) + 1))
            for i, t_fin, ver_fin in flush_q:
                _flush_cancelled(i, t_fin, ver_fin)
            # ---- boundary slots redispatch after the update, batched ----
            if redis:
                rl = np.asarray([r[0] for r in redis], np.intp)
                rs = np.asarray([r[1] for r in redis], np.int64)
                rg = np.asarray([r[2] for r in redis], np.int64)
                nid = self._lane_replacement_ids(pack, rl, rs, rg,
                                                 pack.t[rl], k + 1)
                pb_b, bb, okb = lanes.plan_resolve(rl, nid, k + 1,
                                                   pack.t[rl])
                nrem_b = _retry_rem(bb["outcome"], pb_b.compute_s,
                                    bb["compute_s"], np.ones(len(rl)),
                                    lane_P[rl]) if any_salv else None
                row = _async_rows_cols(rs, rg, k + 1, bb, okb, nrem=nrem_b)
                fl_rows = offsets[rl] + rs
                for f in flight:
                    flight[f][fl_rows] = row[f]
            k += 1


# ---------------------------------------------------------------------------
# Carbon-aware selection (CAFE-style time/geo shifting)
# ---------------------------------------------------------------------------

_CARBON_PROBES = 8   # candidate ids screened per dispatch


def carbon_pick_ids(sampler: SessionSampler, intensity, fed: FederatedConfig,
                    slots: np.ndarray, gens: np.ndarray,
                    starts, version: int,
                    skip: Optional[np.ndarray] = None) -> np.ndarray:
    """Carbon-aware replacement ids, columnar: for each (slot, generation)
    dispatch, walk that slot's probe stream (``events.probe_uniforms``) and
    pick the first candidate whose deterministic country draw lands in the
    ``fed.carbon_topk`` lowest-intensity countries at the row's dispatch
    clock; rows under the ``fed.carbon_explore`` floor (and rows where all
    ``_CARBON_PROBES`` candidates miss) take the unscreened first probe.

    Under a live ``AvailabilityModel`` the screen also intersects each
    candidate's admission test (its own counter-keyed admission uniform vs
    eligibility at the dispatch clock — the exact draw ``resolve`` will
    re-derive), preferring low-carbon AND admissible; rows with no
    admissible low-carbon candidate fall back to the first admissible
    probe, then to the unscreened first probe.

    Diurnal screening runs off the schedule's COMPILED segment grid
    (``_VocabSchedule.segment_table``/``allowed_masks``): one searchsorted
    per row plus a precomputed (segment, country) mask gather, instead of
    evaluating all V countries' intensities per row and re-partitioning.
    The precompute cannot change picks: the grid's segments are exactly
    the maximal clock spans on which no country's schedule changes value,
    the per-segment mask stores the same "value <= k-th smallest" screen
    (a value threshold, never an argpartition rank, so tied intensities
    resolve identically), and both admission uniforms and candidate
    country draws stay untouched counter streams — so the compiled gather
    answers with the very mask the direct per-row recompute would build.

    Every output is a pure per-row function of (seed, slot, generation,
    start clock, version) and the environment — never of batch grouping or
    global arrival order — so the serial loop, the lane-batched engine and
    the scalar oracle replay identical picks, row for row. ``skip`` marks
    rows whose result the caller will overwrite (the retry stream): they
    take the unscreened first probe without paying for screening, which
    is pick-identical because screening is row-local and their pick is
    discarded anyway."""
    slots = np.asarray(slots, np.int64)
    gens = np.asarray(gens, np.int64)
    n = len(slots)
    u = probe_uniforms(fed.seed, slots, gens, _CARBON_PROBES + 1)
    cand = (u[:, 1:] * _POPULATION).astype(np.int64)
    names = sampler.country_names
    k = min(int(fed.carbon_topk), len(names))
    if k >= len(names) and not sampler.has_avail:
        return cand[:, 0]
    # exploration rows take the unscreened first probe regardless — skip
    # their country/admission probe work up front (pick-identical)
    live = u[:, 0] >= fed.carbon_explore
    if skip is not None:
        live &= ~skip
    out = cand[:, 0].copy()
    if not live.any():
        return out
    starts = np.broadcast_to(np.asarray(starts, np.float64), (n,))
    cd = cand[live]
    stl = starts[live]
    m = len(cd)
    ctry = sampler.country_draw(cd.reshape(-1), version) \
        .reshape(m, _CARBON_PROBES)
    if k >= len(names):
        allowed = np.ones((m, _CARBON_PROBES), bool)
    else:
        # the allowed set is "intensity <= the k-th smallest" — a value
        # threshold, not an argpartition rank, so ties resolve identically
        # everywhere regardless of partition order
        tab = intensity.vocab_schedule(names)
        if not tab.any_dynamic:
            # static grid: the allowed-country mask is clock-independent —
            # one (V,) threshold serves every row (the window merge issues
            # many small dispatch batches; skip the per-row (n, V) work)
            allowed_row = tab.static <= np.partition(tab.static,
                                                     k - 1)[k - 1]
            allowed = allowed_row[ctry]
        else:
            seg = tab.segment_at(stl)                    # (m,) grid rows
            allowed = tab.allowed_masks(k)[seg[:, None], ctry]
    if sampler.has_avail:
        ua = sampler.admission_uniforms(cd.reshape(-1), version) \
            .reshape(m, _CARBON_PROBES)
        av = sampler._avail_tab
        if av.any_dynamic:
            _, evals = av.segment_table()
            e = evals[av.segment_at(stl)[:, None], ctry]
        else:
            e = av.static[ctry]
        adm = ua < e
        both = allowed & adm
        j = np.where(both.any(axis=1), np.argmax(both, axis=1),
                     np.where(adm.any(axis=1), np.argmax(adm, axis=1), 0))
    else:
        j = np.where(allowed.any(axis=1), np.argmax(allowed, axis=1), 0)
    out[live] = cd[np.arange(m), j]
    return out


def _pad3(mats: List[np.ndarray], vmax: int, fill, dtype) -> np.ndarray:
    """Stack ragged per-lane (S_i, V_i) tables into one padded
    (L, S_max, V_max) block (the 2-D analogue of ``events._pad2``).
    Pads are never gathered: segment indices stay < S_i and country
    draws stay < V_i for each lane."""
    smax = max(t.shape[0] for t in mats)
    out = np.full((len(mats), smax, vmax), fill, dtype)
    for i, t in enumerate(mats):
        out[i, :t.shape[0], :t.shape[1]] = t
    return out


def _group_grids(breaks_list) -> Tuple[np.ndarray, List[np.ndarray]]:
    """Group per-lane breakpoint grids by content so the per-wave segment
    lookup runs one searchsorted per DISTINCT grid (sweep packs usually
    share one or two environments), not one per lane. Lanes with no grid
    (clock-independent screens) get group -1 / segment 0."""
    grids: List[np.ndarray] = []
    grp = np.full(len(breaks_list), -1, np.int64)
    for i, b in enumerate(breaks_list):
        if b is None:
            continue
        for g, e in enumerate(grids):
            if e is b or np.array_equal(e, b):
                grp[i] = g
                break
        else:
            grids.append(b)
            grp[i] = len(grids) - 1
    return grp, grids


class _LaneCarbonScreen:
    """Pack-wide compiled carbon screen: per-lane explore floors and top-k
    widths plus padded (lane, segment, country) allowed-mask and
    eligibility tables, mirroring ``LaneSampler``'s padded fleet/country
    tables — so one batched gather screens a whole multi-lane dispatch
    wave instead of a Python loop of per-lane ``carbon_pick_ids`` calls.
    Per lane the math is identical to ``carbon_pick_ids``: same
    counter-keyed probe/country/admission draws (per-row-seed twins),
    same compiled segment grids, same value-threshold masks. Non-avail
    lanes screen admission against +inf eligibility — ``ua < inf`` is
    always true, which collapses the shared formula to the
    no-availability pick, row for row."""

    def __init__(self, pack: "_LanePack"):
        lanes = pack.lanes
        ss = lanes.samplers
        feds = pack.feds
        ints = [t.estimator.intensity for t in pack.tasks]
        n_lanes = pack.n_lanes
        self.lanes = lanes
        self.explore = np.asarray([f.carbon_explore for f in feds])
        nv = np.asarray([len(s.country_names) for s in ss], np.int64)
        self.kk = np.asarray([min(int(f.carbon_topk), int(v))
                              for f, v in zip(feds, nv)], np.int64)
        avail = np.asarray([s.has_avail for s in ss], bool)
        # k covers the whole vocabulary and nothing gates admission:
        # every pick is the unscreened first probe (the serial early-out)
        self.trivial = (self.kk >= nv) & ~avail
        amasks: List[np.ndarray] = []
        abreaks: List[Optional[np.ndarray]] = []
        for i in range(n_lanes):
            tab = ints[i].vocab_schedule(ss[i].country_names)
            k = int(self.kk[i])
            if k >= int(nv[i]):
                amasks.append(np.ones((1, int(nv[i])), bool))
                abreaks.append(None)
            elif not tab.any_dynamic:
                row = tab.static <= np.partition(tab.static, k - 1)[k - 1]
                amasks.append(row[None, :])
                abreaks.append(None)
            else:
                amasks.append(tab.allowed_masks(k))
                abreaks.append(tab.segment_table()[0])
        evals: List[np.ndarray] = []
        ebreaks: List[Optional[np.ndarray]] = []
        for i in range(n_lanes):
            if not avail[i]:
                evals.append(np.full((1, int(nv[i])), np.inf))
                ebreaks.append(None)
            elif ss[i]._avail_tab.any_dynamic:
                brk, ev = ss[i]._avail_tab.segment_table()
                evals.append(ev)
                ebreaks.append(brk)
            else:
                evals.append(ss[i]._avail_tab.static[None, :])
                ebreaks.append(None)
        vmax = int(nv.max())
        self.amask = _pad3(amasks, vmax, False, bool)
        self.evals = _pad3(evals, vmax, np.inf, np.float64)
        self.agrp, self.agrids = _group_grids(abreaks)
        self.egrp, self.egrids = _group_grids(ebreaks)

    def _segments(self, grp: np.ndarray, grids: List[np.ndarray],
                  li: np.ndarray, tl: np.ndarray) -> np.ndarray:
        seg = np.zeros(len(li), np.int64)
        g_of = grp[li]
        for g, brk in enumerate(grids):
            rows = g_of == g
            if rows.any():
                seg[rows] = np.searchsorted(brk, tl[rows],
                                            side="right") - 1
        return seg

    def pick(self, lane: np.ndarray, slots: np.ndarray, gens: np.ndarray,
             starts, version: int,
             skip: Optional[np.ndarray] = None) -> np.ndarray:
        """Batched ``carbon_pick_ids`` across lanes (see class doc)."""
        lanes = self.lanes
        lane = np.asarray(lane, np.intp)
        n = len(lane)
        u = lanes.probe_uniforms(lane, slots, gens, _CARBON_PROBES + 1)
        cand = (u[:, 1:] * _POPULATION).astype(np.int64)
        out = cand[:, 0].copy()
        live = (u[:, 0] >= self.explore[lane]) & ~self.trivial[lane]
        if skip is not None:
            live &= ~skip
        if not live.any():
            return out
        starts = np.broadcast_to(np.asarray(starts, np.float64), (n,))
        li = lane[live]
        cd = cand[live]
        m = len(li)
        lrep = np.repeat(li, _CARBON_PROBES)
        ctry = lanes.country_draw(lrep, cd.reshape(-1), version) \
            .reshape(m, _CARBON_PROBES)
        tl = np.mod(starts[live], SECONDS_PER_DAY)
        sa = self._segments(self.agrp, self.agrids, li, tl)
        allowed = self.amask[li[:, None], sa[:, None], ctry]
        ua = lanes.admission_uniforms(lrep, cd.reshape(-1), version) \
            .reshape(m, _CARBON_PROBES)
        se = self._segments(self.egrp, self.egrids, li, tl)
        adm = ua < self.evals[li[:, None], se[:, None], ctry]
        both = allowed & adm
        j = np.where(both.any(axis=1), np.argmax(both, axis=1),
                     np.where(adm.any(axis=1), np.argmax(adm, axis=1), 0))
        out[live] = cd[np.arange(m), j]
        return out


@register_strategy("carbon-aware")
class CarbonAwareStrategy(AsyncStrategy):
    """FedBuff with carbon-aware cohort selection: the same always-
    ``concurrency``-in-flight event loop as "async", but every replacement
    dispatch screens a short stream of candidate client ids and picks the
    first whose country (a deterministic sampler draw) sits in the k
    lowest-intensity countries *at the dispatch clock* — time/geo shifting
    in the CAFE mold, driven by ``Environment.carbon_intensity`` +
    ``intensity_schedule``. ``fed.carbon_topk`` sets the country filter
    width and ``fed.carbon_explore`` the exploration floor (unscreened
    dispatch probability), so no region is ever starved and convergence
    stats stay honest. The initial cohort (generation 0) goes out
    unscreened, exactly like "async" — the filter starts with the first
    replacement wave.

    Because picks stay pure counter functions of (seed, slot, generation,
    clock, version), the strategy inherits the async window-batched merge
    AND its lane_loop unchanged — only the id hooks differ — and stays
    seed-for-seed equal to its scalar oracle twin
    (``reference.run_scalar``) and to its own lanes under
    ``sweep(vectorize=True)``."""

    def _replacement_ids(self, sampler, fed, slots, gens, starts, version,
                         skip=None):
        return carbon_pick_ids(sampler, self._estimator.intensity, fed,
                               slots, gens, starts, version, skip=skip)

    def _lane_replacement_ids(self, pack, lane, slots, gens, starts,
                              version, skip=None):
        # one batched screen per dispatch wave over the pack's compiled
        # per-lane mask tables (built once per run, cached on the pack).
        # Picks are row-local — each row reads only its own lane's seed,
        # grids and knobs — so batching across lanes cannot change any
        # row's result vs a per-lane carbon_pick_ids call.
        screen = pack.carbon_screen
        if screen is None:
            screen = pack.carbon_screen = _LaneCarbonScreen(pack)
        return screen.pick(lane, slots, gens, starts, version, skip=skip)

    # explicit lane-pack opt-in (sweep._pack_key requires lane_loop in
    # cls.__dict__): the parent's lockstep loop dispatched through THIS
    # class's id hooks IS the correct lane semantics for this strategy
    lane_loop = AsyncStrategy.lane_loop


# ---------------------------------------------------------------------------
# Lane-batched execution: a pack of compatible experiments as ONE simulation
# ---------------------------------------------------------------------------

@dataclass
class LaneTask:
    """One lane of a lane-batched pack — everything ``Strategy.run`` would
    receive for a single experiment, pre-resolved (model config, learner,
    sampler, estimator) so the pack runner never touches spec plumbing."""
    model_cfg: ModelConfig
    fed: FederatedConfig
    run: RunConfig
    learner: object
    sampler: SessionSampler
    estimator: CarbonEstimator
    on_round: Optional[RoundCallback] = None


class _LaneStreamSink:
    """``LaneAccumulator``-compatible ``append`` surface for streaming
    packs: each appended block's rows forward to their lane's
    ``StreamedLog`` fold. ``np.flatnonzero`` keeps within-lane row order,
    which is the lane's serial log order (the lane-equivalence
    invariant), so per-lane reservoir global indices line up with a
    serial streaming run exactly."""

    def __init__(self, logs: List[StreamedLog]):
        self.logs = logs

    def append(self, lane: np.ndarray, **cols: np.ndarray) -> None:
        lane = np.asarray(lane)
        n = len(cols["client_id"])
        block = {f: (np.broadcast_to(np.asarray(a), (n,))
                     if np.ndim(a) == 0 else a) for f, a in cols.items()}
        for i in np.unique(lane):
            m = np.flatnonzero(lane == i)
            self.logs[int(i)].append(**{f: a[m] for f, a in block.items()})


class _LanePack:
    """Shared mutable state for one lockstep lane run: per-lane clocks,
    round counters, stoppers, logs and learners, plus the pack-wide
    ``LaneSampler`` and the single ``LaneAccumulator`` session store that
    per-lane TaskLogs are sliced out of at the end. Streaming packs
    (``run.telemetry == "streaming"``, uniform across lanes — the sweep
    packer splits mixed groups) swap the store for per-lane
    ``StreamedLog`` folds behind a ``_LaneStreamSink``."""

    def __init__(self, tasks: List[LaneTask]):
        self.tasks = tasks
        self.n_lanes = len(tasks)
        self.feds = [t.fed for t in tasks]
        self.runs = [t.run for t in tasks]
        self.learners = [t.learner for t in tasks]
        self.lanes = LaneSampler([t.sampler for t in tasks])
        self.stoppers = [_Stopper(t.run) for t in tasks]
        self.streaming = tasks[0].run.telemetry == "streaming"
        assert all((t.run.telemetry == "streaming") == self.streaming
                   for t in tasks), \
            "lane packs must not mix streaming and full telemetry"
        # per-lane effective checkpoint period (see Strategy.run)
        self.ckpt = [t.fed.checkpoint_period_s
                     if (t.sampler.has_avail and t.fed.retry_limit > 0)
                     else 0.0 for t in tasks]
        if self.streaming:
            self.logs: List[TaskLog] = [
                StreamedLog(t.estimator, t.sampler.device_names,
                            t.sampler.country_names, seed=t.fed.seed,
                            sample=t.run.telemetry_sample, mode=t.fed.mode,
                            checkpoint_period_s=self.ckpt[i])
                for i, t in enumerate(tasks)]
            self.acc = _LaneStreamSink(self.logs)
        else:
            self.logs = [TaskLog() for _ in tasks]
            for i, log in enumerate(self.logs):
                log.checkpoint_period_s = self.ckpt[i]
            self.acc = LaneAccumulator(self.lanes.device_names,
                                       self.lanes.country_names)
        self.t = np.zeros(self.n_lanes)
        self.rounds = np.zeros(self.n_lanes, np.int64)
        self.ppl = np.asarray([float(t.model_cfg.vocab_size) for t in tasks])
        self.active = np.ones(self.n_lanes, bool)
        self.n_logged = np.zeros(self.n_lanes, np.int64)
        # compiled carbon screen (built lazily by CarbonAwareStrategy)
        self.carbon_screen: Optional["_LaneCarbonScreen"] = None

    def close_round(self, i: int, round_idx: int, mode: str,
                    starved: bool = False) -> None:
        """Per-lane post-update bookkeeping (log + streamed RoundEvent),
        identical to the serial loops' tail."""
        stop = self.stoppers[i]
        sm = stop.smoothed or self.ppl[i]
        self.logs[i].log_round(self.t[i], starved=starved)
        self.logs[i].log_eval(self.t[i], round_idx, self.ppl[i], sm)
        cb = self.tasks[i].on_round
        if cb is not None:
            cb(RoundEvent(round_idx, float(self.t[i]), float(self.ppl[i]),
                          sm, int(self.n_logged[i]), mode))


class LaneRunner:
    """Run a pack of compatible experiments (same ``mode``) in lockstep as
    ONE columnar simulation: sampler draws become ``(lane, batch)``-shaped
    arrays keyed per lane, per-lane clocks advance under an active-lane
    mask, sessions accumulate into one lane-columnar store, and the
    estimator reduces per-lane segments. Results equal per-task
    ``Strategy.run`` **seed for seed** (same summaries, same session
    columns): lanes share no RNG state — all per-session randomness is
    counter-keyed on each lane's own seed — so batching changes only array
    shapes, never values. Lanes may differ in concurrency, aggregation
    goal, seeds, model size, run budgets and every Environment knob; they
    must share the event-loop mode (one lockstep window shape)."""

    def __init__(self, mode: str):
        self.mode = mode
        self.strategy = get_strategy(mode)
        if not hasattr(self.strategy, "lane_loop"):
            raise ValueError(
                f"strategy {mode!r} has no lane_loop; run specs serially")

    def run(self, tasks: Sequence[LaneTask]) -> List[TaskResult]:
        tasks = list(tasks)
        assert all(t.fed.mode == self.mode for t in tasks), \
            "lane packs must share the event-loop mode"
        pack = _LanePack(tasks)
        self.strategy.lane_loop(pack)
        assert not pack.active.any()
        if pack.streaming:
            # each lane's StreamedLog already holds its exact running
            # sums; estimate() reads them via carbon_components
            carbons = [t.estimator.estimate(pack.logs[i])
                       for i, t in enumerate(tasks)]
        else:
            batches = pack.acc.split()
            cols = pack.acc.raw()
            carbons = lane_carbon(cols, cols["lane"],
                                  [t.estimator for t in tasks],
                                  pack.lanes.device_names,
                                  pack.lanes.country_names,
                                  [log.duration_s for log in pack.logs],
                                  checkpoint_period_s=pack.ckpt)
        out: List[TaskResult] = []
        for i, task in enumerate(tasks):
            log = pack.logs[i]
            if not pack.streaming:
                log.log_batch(batches[i])
            stop = pack.stoppers[i]
            ppl = float(pack.ppl[i])
            out.append(TaskResult(log, carbons[i], stop.reached,
                                  int(pack.rounds[i]),
                                  float(pack.t[i]) / 3600.0, ppl,
                                  stop.smoothed or ppl,
                                  aborted=stop.aborted))
        return out


# ---------------------------------------------------------------------------
# Deprecated free-function shims (pre-`repro.api` entry points)
# ---------------------------------------------------------------------------

def run_sync(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
             learner, seq_len: int = 64,
             estimator: Optional[CarbonEstimator] = None) -> TaskResult:
    warnings.warn(
        "run_sync is deprecated; use repro.api.Experiment",
        DeprecationWarning, stacklevel=2)
    return SyncStrategy().run(model_cfg, fed, run, learner, seq_len=seq_len,
                              estimator=estimator)


def run_async(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
              learner, seq_len: int = 64,
              estimator: Optional[CarbonEstimator] = None) -> TaskResult:
    warnings.warn(
        "run_async is deprecated; use repro.api.Experiment",
        DeprecationWarning, stacklevel=2)
    return AsyncStrategy().run(model_cfg, fed, run, learner, seq_len=seq_len,
                               estimator=estimator)


def run_task(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
             learner, seq_len: int = 64) -> TaskResult:
    """Deprecated: build an `repro.api.ExperimentSpec` and run it through
    `repro.api.Experiment` instead."""
    warnings.warn(
        "run_task is deprecated; use repro.api.Experiment", DeprecationWarning,
        stacklevel=2)
    return get_strategy(fed.mode).run(model_cfg, fed, run, learner,
                                      seq_len=seq_len)
