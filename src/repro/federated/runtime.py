"""The FL task runtime: synchronous (FedAvg) and asynchronous (FedBuff)
event loops with full carbon telemetry (paper §3.1).

Both loops are ``Strategy`` classes registered in the string-keyed
``STRATEGIES`` registry ("sync", "async"; ``register_strategy`` is open for
carbon-aware variants). They drive a pluggable learner (RealLearner or
SurrogateLearner) through the same PAPAYA-shaped protocol:

sync  — each round selects `concurrency` clients ("users per round"); the
        round closes when the `aggregation_goal`-th result arrives; clients
        still running are cancelled (over-selection waste is charged);
        server updates once per round.
async — `concurrency` clients are always in flight; a finished client's
        (staleness-weighted) delta joins the buffer; every
        `aggregation_goal` arrivals the server updates and later clients
        train on the newer model (FedBuff). Stragglers never block.

Both loops are columnar end-to-end: cohorts are planned/resolved through
the vectorized ``SessionSampler.plan_batch``/``resolve_batch`` and logged
as ``SessionBatch`` columns, so the per-session cost is a few array ops
rather than Python-object allocation. Sync closes each round with a
partition on end_t; async is a window-batched exact merge — per-slot
splitmix64 replacement-id streams (``slot_stream_ids``) decouple
replacement identity from arrival order, so the span between two server
updates resolves as arrays instead of a per-session heap pop (see
``AsyncStrategy``). The returned TaskLog contains every session's vitals;
CarbonEstimator turns it into the paper's component breakdown. Strategies
emit a ``RoundEvent`` after every server eval so callers
(``repro.api.Experiment``) can stream progress. ``run_task`` survives
only as a deprecated shim over the registry — new code goes through
``repro.api``.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Type

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig, RunConfig
from repro.core.estimator import CarbonBreakdown, CarbonEstimator
from repro.core.telemetry import (OUTCOME_CODE, BatchAccumulator,
                                  SessionBatch, TaskLog)
from repro.federated.events import SessionSampler, slot_stream_ids

_SERVER_AGG_S = 2.0     # server-side aggregation latency per update
_POPULATION = 5_000_000  # eligible-device pool the coordinator selects from


@dataclass
class TaskResult:
    log: TaskLog
    carbon: CarbonBreakdown
    reached_target: bool
    rounds: int
    duration_h: float
    final_perplexity: float
    smoothed_perplexity: float

    def summary(self) -> Dict[str, float]:
        return {
            "rounds": self.rounds,
            "duration_h": self.duration_h,
            "reached_target": float(self.reached_target),
            "perplexity": self.final_perplexity,
            "carbon_total_kg": self.carbon.total_kg,
            **{k: v for k, v in self.carbon.as_dict().items()},
            "sessions": float(self.log.n_sessions),
        }


@dataclass(frozen=True)
class RoundEvent:
    """Streamed to `on_round` after every server eval (both strategies)."""
    round_idx: int               # server model updates so far
    t_s: float                   # task clock, seconds
    perplexity: float
    smoothed_perplexity: float
    n_sessions: int              # client sessions logged so far
    mode: str                    # strategy key ("sync" / "async")


RoundCallback = Callable[[RoundEvent], None]


class _Stopper:
    """Paper §3.2: stop when smoothed test perplexity has been at/below the
    target for `patience` consecutive evals, or at the time limit."""

    def __init__(self, run: RunConfig):
        self.run = run
        self.smoothed: Optional[float] = None
        self.hits = 0
        self.reached = False

    def update(self, ppl: float) -> None:
        a = self.run.ema_alpha
        self.smoothed = ppl if self.smoothed is None else \
            a * ppl + (1 - a) * self.smoothed
        if self.smoothed <= self.run.target_perplexity:
            self.hits += 1
        else:
            self.hits = 0
        if self.hits >= self.run.patience_rounds:
            self.reached = True

    def out_of_budget(self, t_s: float, rounds: int) -> bool:
        return (t_s >= self.run.max_hours * 3600.0
                or rounds >= self.run.max_rounds)


def _select_cohort(rng: np.random.Generator, k: int,
                   population: int) -> np.ndarray:
    """Coordinator client selection: eligible devices, unique per round.
    Sampled without replacement from the population directly (the old
    sample-from-a-larger-range-then-modulo trick silently reintroduced
    duplicates and a mild modulo bias)."""
    return rng.choice(population, size=k, replace=False).astype(np.int64)


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------

STRATEGIES: Dict[str, Type["Strategy"]] = {}


def register_strategy(name: str):
    """Class decorator: expose a Strategy under a string key (open for
    carbon-aware selection policies next)."""
    def deco(cls: Type["Strategy"]) -> Type["Strategy"]:
        cls.mode = name
        STRATEGIES[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> "Strategy":
    try:
        return STRATEGIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: {sorted(STRATEGIES)}"
        ) from None


class Strategy:
    """One FL orchestration policy. Subclasses implement `_loop`; the base
    handles sampler/estimator wiring so every strategy sees the same
    environment knobs (fleet, country mix, bandwidths, carbon models)."""

    mode: str = ""

    def run(self, model_cfg: ModelConfig, fed: FederatedConfig,
            run: RunConfig, learner, *, seq_len: int = 64,
            estimator: Optional[CarbonEstimator] = None,
            sampler: Optional[SessionSampler] = None,
            on_round: Optional[RoundCallback] = None) -> TaskResult:
        sampler = sampler or SessionSampler(model_cfg, fed, seq_len)
        est = estimator or CarbonEstimator()
        log = TaskLog()
        stop = _Stopper(run)
        t, rounds, ppl = self._loop(model_cfg, fed, learner, sampler, log,
                                    stop, on_round)
        return TaskResult(log, est.estimate(log), stop.reached, rounds,
                          t / 3600.0, ppl, stop.smoothed or ppl)

    # subclasses: run the event loop, return (t_s, rounds, perplexity)
    def _loop(self, model_cfg: ModelConfig, fed: FederatedConfig, learner,
              sampler: SessionSampler, log: TaskLog, stop: _Stopper,
              on_round: Optional[RoundCallback]) -> Tuple[float, int, float]:
        raise NotImplementedError

    def _emit(self, on_round: Optional[RoundCallback], n_sessions: int,
              round_idx: int, t: float, ppl: float, smoothed: float) -> None:
        if on_round is not None:
            on_round(RoundEvent(round_idx, t, ppl, smoothed,
                                n_sessions, self.mode))


@register_strategy("sync")
class SyncStrategy(Strategy):
    """FedAvg rounds with over-selection cancel (paper §3.1 sync)."""

    def _loop(self, model_cfg, fed, learner, sampler, log, stop, on_round):
        assert fed.mode == "sync"
        rng = np.random.default_rng(fed.seed + 1)
        t = 0.0
        rounds = 0
        ppl = float(model_cfg.vocab_size)
        goal = min(fed.aggregation_goal, fed.concurrency)

        while True:
            cohort = _select_cohort(rng, fed.concurrency,
                                    population=_POPULATION)
            pb = sampler.plan_batch(cohort, rounds)
            # pass 1: tentative outcomes, find when the goal-th result
            # arrives (a partition on end_t, not a full sort)
            tb, ok = sampler.resolve_batch(pb, rounds, t)
            ends = tb.end_t[ok]
            if len(ends) >= goal:
                round_end = float(np.partition(ends, goal - 1)[goal - 1])
                failed = False
            elif len(ends):
                # dropouts ate the over-selection slack: the round closes at
                # the last survivor (production would hit the round deadline)
                # and the server updates with what it received
                round_end = float(ends.max())
                failed = False
            else:
                round_end = float(tb.end_t.max()) if len(tb) else t
                failed = True
            # pass 2: sessions against the round deadline (cancel stragglers)
            fb, ok2 = sampler.resolve_batch(pb, rounds, t, deadline=round_end)
            log.log_batch(fb)
            contributors: List[int] = \
                cohort[np.nonzero(ok2)[0][:goal]].tolist()
            t = round_end + _SERVER_AGG_S
            rounds += 1
            if not failed and contributors:
                deltas, weights = [], []
                if getattr(learner, "real", True):
                    if hasattr(learner, "client_deltas"):
                        deltas, weights = learner.client_deltas(contributors)
                    else:
                        for c in contributors:
                            d, w = learner.client_delta(c, None)
                            deltas.append(d)
                            weights.append(w)
                else:
                    deltas, weights = [None], [1.0]
                learner.apply(deltas, weights, n_contributors=len(contributors))
                ppl = learner.eval_perplexity()
                stop.update(ppl)
            log.log_round(t)
            log.log_eval(t, rounds, ppl, stop.smoothed or ppl)
            self._emit(on_round, log.n_sessions, rounds, t, ppl,
                       stop.smoothed or ppl)
            if stop.reached or stop.out_of_budget(t, rounds):
                break
        return t, rounds, ppl


# async pool fields that only the window close needs (the expansion phase
# works on slot/gen/end/ok alone, so these stay as per-generation blocks
# and are concatenated once per window)
_DEFERRED = ("cid", "ver", "start", "d", "c", "u", "bd", "bu",
             "dev", "ctry", "out")


def _async_rows(slots: np.ndarray, gens: np.ndarray, version: int,
                batch: SessionBatch, ok: np.ndarray) -> Dict[str, np.ndarray]:
    """One column block of dispatched async sessions (slot + generation
    identify the session; everything else comes from ``resolve_batch``)."""
    n = len(ok)
    return dict(slot=np.asarray(slots, np.int64),
                gen=np.asarray(gens, np.int64),
                cid=batch.client_id,
                ver=np.full(n, version, np.int64),
                start=batch.start_t, end=batch.end_t,
                d=batch.download_s, c=batch.compute_s, u=batch.upload_s,
                bd=batch.bytes_down, bu=batch.bytes_up,
                dev=batch.device_idx, ctry=batch.country_idx,
                out=batch.outcome, ok=ok)


def _truncate_cancelled(flight: Dict[str, np.ndarray], idx: np.ndarray,
                        t_final: float) -> Dict[str, np.ndarray]:
    """In-flight sessions at task end: truncate the burned phases at the
    final task clock (a device stops the moment the task is torn down),
    prorate downlink bytes to the downloaded fraction, and zero uplink
    bytes (the result never reached the server). Mirrored scalar-ly by the
    reference oracle's flush — keep the two numerically identical."""
    d, c, u = flight["d"][idx], flight["c"][idx], flight["u"][idx]
    cap = np.maximum(0.0, t_final - flight["start"][idx])
    nd = np.minimum(d, cap)
    nc = np.minimum(c, np.maximum(0.0, cap - d))
    nu = np.minimum(u, np.maximum(0.0, cap - d - c))
    frac = np.divide(nd, d, out=np.zeros(len(idx)), where=d > 0)
    return dict(download_s=nd, compute_s=nc, upload_s=nu,
                bytes_down=flight["bd"][idx] * frac,
                bytes_up=np.zeros(len(idx)),
                end_t=np.minimum(flight["end"][idx], t_final))


@register_strategy("async")
class AsyncStrategy(Strategy):
    """FedBuff: always-`concurrency` in-flight clients, buffer size =
    aggregation_goal, staleness-weighted aggregation — vectorized as a
    window-batched exact merge (no event heap).

    Two facts make the merge exact:

    * arrivals are globally sorted by ``(end_t, slot, generation)``: every
      dispatch happens at the then-current clock, so a replacement's end
      never precedes its predecessor's — the old heap's pop order IS this
      sort order;
    * replacement *identity* is decoupled from pop *rank*: slot s draws
      its g-th replacement id from a counter-based splitmix64 stream
      (``slot_stream_ids``), so chained replacements inside a window can
      be planned/resolved as arrays without knowing global arrival order
      first (the circularity that previously forced per-pop dispatch).

    Each window (the span between two server updates) resolves all
    candidate arrivals columnar-ly, finds the update boundary with a
    cumsum over ok flags (the ``aggregation_goal``-th ok arrival), and
    expands chained replacements generation-by-generation until no
    undiscovered arrival precedes the boundary. A speculative chain row
    can never move the boundary wrongly: any row with key <= boundary has
    its whole ancestor chain at strictly smaller keys, so the ancestors
    all pop and the row is validly dispatched. Sessions still in flight
    when the task ends are logged as ``cancelled``, truncated at the
    final clock.
    """

    def _loop(self, model_cfg, fed, learner, sampler, log, stop, on_round):
        assert fed.mode == "async"
        rng = np.random.default_rng(fed.seed + 2)
        conc = fed.concurrency
        goal = fed.aggregation_goal
        seed = fed.seed
        t = 0.0
        version = 0
        ppl = float(model_cfg.vocab_size)
        max_t = stop.run.max_hours * 3600.0
        is_real = getattr(learner, "real", True)
        acc = BatchAccumulator(sampler.device_names, sampler.country_names)

        # initial cohort: one batched plan/resolve with jittered starts;
        # slot s starts out running cohort[s] at generation 0
        cohort = _select_cohort(rng, conc, population=_POPULATION)
        starts0 = rng.uniform(0, 5.0, size=conc)
        b0, ok0 = sampler.resolve_batch(sampler.plan_batch(cohort, version),
                                        version, starts0)
        flight = _async_rows(np.arange(conc, dtype=np.int64),
                             np.zeros(conc, np.int64), version, b0, ok0)
        alive = np.ones(conc, bool)

        while True:
            if t >= max_t or version >= stop.run.max_rounds:
                break
            t0 = t
            # ---- expansion phase: discover this window's arrivals -------
            # Chains are expanded against a cheap upper bound on the window
            # end — the goal-th smallest ok end (a partition, not a sort)
            # and/or the first end at/past the time budget. The bound only
            # tightens as rows join, so "every unexpanded row sits past the
            # bound" is a sound fixed point; the single exact lexsort below
            # then settles the boundary.
            slot_all, gen_all = flight["slot"], flight["gen"]
            end_all, ok_all = flight["end"], flight["ok"]
            parts: Dict[str, List[np.ndarray]] = \
                {f: [flight[f]] for f in _DEFERRED}
            succ = np.full(conc, -1, np.int64)   # row -> successor row
            n_rows = conc
            while True:
                bound = np.inf
                if int(np.count_nonzero(ok_all)) >= goal:
                    bound = float(np.partition(end_all[ok_all],
                                               goal - 1)[goal - 1])
                over = end_all[end_all >= max_t]
                if len(over):
                    # the budget check runs before each pop against the
                    # PREVIOUS arrival's clock, so the first arrival at/past
                    # max_t still pops before the loop stops
                    bound = min(bound, float(over.min()))
                frontier = succ < 0
                if not np.isinf(bound):
                    frontier &= end_all <= bound
                    if not frontier.any():
                        break
                need = np.nonzero(frontier)[0]
                slots_n = slot_all[need]
                gens_n = gen_all[need] + 1
                ids_n = slot_stream_ids(seed, slots_n, gens_n, _POPULATION)
                starts_n = np.maximum(t0, end_all[need])
                bn, okn = sampler.resolve_batch(
                    sampler.plan_batch(ids_n, version), version, starts_n)
                succ[need] = n_rows + np.arange(len(need))
                n_rows += len(need)
                succ = np.concatenate(
                    [succ, np.full(len(need), -1, np.int64)])
                slot_all = np.concatenate([slot_all, slots_n])
                gen_all = np.concatenate([gen_all, gens_n])
                end_all = np.concatenate([end_all, bn.end_t])
                ok_all = np.concatenate([ok_all, okn])
                new = _async_rows(slots_n, gens_n, version, bn, okn)
                for f in _DEFERRED:
                    parts[f].append(new[f])
            # ---- exact close: one lexsort settles the boundary ----------
            order = np.lexsort((gen_all, slot_all, end_all))
            ends_sorted = end_all[order]
            cum = np.cumsum(ok_all[order])
            b_pos = int(np.searchsorted(cum, goal)) \
                if cum[-1] >= goal else -1
            cut = int(np.searchsorted(ends_sorted, max_t, side="left"))
            if 0 <= b_pos <= cut:
                pops_to, closes = b_pos, "update"
            else:
                pops_to, closes = cut, "budget"   # cut < n_rows: bound was
            pop_idx = order[:pops_to + 1]         # finite via max_t
            # every pop precedes the bound, so its chain was expanded
            assert succ[pop_idx].min() >= 0
            A = {"slot": slot_all, "gen": gen_all,
                 "end": end_all, "ok": ok_all,
                 **{f: np.concatenate(p) if len(p) > 1 else p[0]
                    for f, p in parts.items()}}
            # ---- log pops, advance per-slot chains ----------------------
            okm = A["ok"][pop_idx]
            acc.append(client_id=A["cid"][pop_idx],
                       round_idx=A["ver"][pop_idx],
                       device_idx=A["dev"][pop_idx],
                       country_idx=A["ctry"][pop_idx],
                       download_s=A["d"][pop_idx],
                       compute_s=A["c"][pop_idx],
                       upload_s=A["u"][pop_idx],
                       bytes_down=A["bd"][pop_idx],
                       bytes_up=A["bu"][pop_idx],
                       start_t=A["start"][pop_idx],
                       end_t=A["end"][pop_idx],
                       outcome=A["out"][pop_idx],
                       staleness=version - A["ver"][pop_idx])
            # per-slot chain tip among the pops -> its successor goes
            # in-flight (fancy-index write is made unique by the tip mask)
            sl, gn = A["slot"][pop_idx], A["gen"][pop_idx]
            best = np.full(conc, -1, np.int64)
            np.maximum.at(best, sl, gn)
            is_tip = gn == best[sl]
            tip_slots = sl[is_tip]
            repl_rows = succ[pop_idx[is_tip]]
            for f in flight:
                flight[f][tip_slots] = A[f][repl_rows]
            if closes == "budget":
                t = max(t0, float(ends_sorted[pops_to]))
                break
            # ---- server update at the boundary arrival ------------------
            b_row = int(pop_idx[-1])
            vers_ok = A["ver"][pop_idx][okm]
            if is_real:
                staleness = (version - vers_ok).tolist()
                deltas, weights = [], []
                for bc, bv in zip(A["cid"][pop_idx][okm].tolist(),
                                  vers_ok.tolist()):
                    dd, w = learner.client_delta(bc, bv)
                    deltas.append(dd)
                    weights.append(w)
                kw_extra = {"staleness": staleness}
                mean_st = float(np.mean(staleness))
            else:
                deltas, weights, kw_extra = [None], [1.0], {}
                mean_st = version - (vers_ok.sum() / len(vers_ok))
            learner.apply(deltas, weights, n_contributors=len(vers_ok),
                          mean_staleness=mean_st, **kw_extra)
            version += 1
            t = max(t0, float(A["end"][b_row])) + _SERVER_AGG_S
            ppl = learner.eval_perplexity()
            stop.update(ppl)
            log.log_round(t)
            log.log_eval(t, version, ppl, stop.smoothed or ppl)
            self._emit(on_round, len(acc), version, t, ppl,
                       stop.smoothed or ppl)
            b_slot = int(A["slot"][b_row])
            if stop.reached or stop.out_of_budget(t, version):
                alive[b_slot] = False   # its replacement never went out
                break
            # the boundary slot's replacement goes out AFTER the update,
            # against the new model version (same slot-stream id either way)
            b_gen = int(A["gen"][b_row]) + 1
            nid = slot_stream_ids(seed, [b_slot], [b_gen], _POPULATION)
            b1, okb = sampler.resolve_batch(
                sampler.plan_batch(nid, version), version, t)
            row = _async_rows(np.asarray([b_slot], np.int64),
                              np.asarray([b_gen], np.int64), version, b1, okb)
            for f in flight:
                flight[f][b_slot] = row[f][0]

        # ---- task end: in-flight sessions are logged as cancelled -------
        idx = np.nonzero(alive)[0]
        if len(idx):
            acc.append(client_id=flight["cid"][idx],
                       round_idx=flight["ver"][idx],
                       device_idx=flight["dev"][idx],
                       country_idx=flight["ctry"][idx],
                       start_t=flight["start"][idx],
                       outcome=np.full(len(idx), OUTCOME_CODE["cancelled"],
                                       np.int8),
                       staleness=version - flight["ver"][idx],
                       **_truncate_cancelled(flight, idx, t))
        if len(acc):
            log.log_batch(acc.to_batch())
        return t, version, ppl


# ---------------------------------------------------------------------------
# Deprecated free-function shims (pre-`repro.api` entry points)
# ---------------------------------------------------------------------------

def run_sync(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
             learner, seq_len: int = 64,
             estimator: Optional[CarbonEstimator] = None) -> TaskResult:
    warnings.warn(
        "run_sync is deprecated; use repro.api.Experiment",
        DeprecationWarning, stacklevel=2)
    return SyncStrategy().run(model_cfg, fed, run, learner, seq_len=seq_len,
                              estimator=estimator)


def run_async(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
              learner, seq_len: int = 64,
              estimator: Optional[CarbonEstimator] = None) -> TaskResult:
    warnings.warn(
        "run_async is deprecated; use repro.api.Experiment",
        DeprecationWarning, stacklevel=2)
    return AsyncStrategy().run(model_cfg, fed, run, learner, seq_len=seq_len,
                               estimator=estimator)


def run_task(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
             learner, seq_len: int = 64) -> TaskResult:
    """Deprecated: build an `repro.api.ExperimentSpec` and run it through
    `repro.api.Experiment` instead."""
    warnings.warn(
        "run_task is deprecated; use repro.api.Experiment", DeprecationWarning,
        stacklevel=2)
    return get_strategy(fed.mode).run(model_cfg, fed, run, learner,
                                      seq_len=seq_len)
