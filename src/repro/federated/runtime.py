"""The FL task runtime: synchronous (FedAvg) and asynchronous (FedBuff)
event loops with full carbon telemetry (paper §3.1).

Both loops are ``Strategy`` classes registered in the string-keyed
``STRATEGIES`` registry ("sync", "async"; ``register_strategy`` is open for
carbon-aware variants). They drive a pluggable learner (RealLearner or
SurrogateLearner) through the same PAPAYA-shaped protocol:

sync  — each round selects `concurrency` clients ("users per round"); the
        round closes when the `aggregation_goal`-th result arrives; clients
        still running are cancelled (over-selection waste is charged);
        server updates once per round.
async — `concurrency` clients are always in flight; a finished client's
        (staleness-weighted) delta joins the buffer; every
        `aggregation_goal` arrivals the server updates and later clients
        train on the newer model (FedBuff). Stragglers never block.

The returned TaskLog contains every session's vitals; CarbonEstimator turns
it into the paper's component breakdown. Strategies emit a ``RoundEvent``
after every server eval so callers (``repro.api.Experiment``) can stream
progress. ``run_task`` survives only as a deprecated shim over the
registry — new code goes through ``repro.api``.
"""
from __future__ import annotations

import heapq
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Type

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig, RunConfig
from repro.core.estimator import CarbonBreakdown, CarbonEstimator
from repro.core.telemetry import ClientSession, TaskLog
from repro.federated.events import SessionSampler

_SERVER_AGG_S = 2.0     # server-side aggregation latency per update


@dataclass
class TaskResult:
    log: TaskLog
    carbon: CarbonBreakdown
    reached_target: bool
    rounds: int
    duration_h: float
    final_perplexity: float
    smoothed_perplexity: float

    def summary(self) -> Dict[str, float]:
        return {
            "rounds": self.rounds,
            "duration_h": self.duration_h,
            "reached_target": float(self.reached_target),
            "perplexity": self.final_perplexity,
            "carbon_total_kg": self.carbon.total_kg,
            **{k: v for k, v in self.carbon.as_dict().items()},
            "sessions": float(len(self.log.sessions)),
        }


@dataclass(frozen=True)
class RoundEvent:
    """Streamed to `on_round` after every server eval (both strategies)."""
    round_idx: int               # server model updates so far
    t_s: float                   # task clock, seconds
    perplexity: float
    smoothed_perplexity: float
    n_sessions: int              # client sessions logged so far
    mode: str                    # strategy key ("sync" / "async")


RoundCallback = Callable[[RoundEvent], None]


class _Stopper:
    """Paper §3.2: stop when smoothed test perplexity has been at/below the
    target for `patience` consecutive evals, or at the time limit."""

    def __init__(self, run: RunConfig):
        self.run = run
        self.smoothed: Optional[float] = None
        self.hits = 0
        self.reached = False

    def update(self, ppl: float) -> None:
        a = self.run.ema_alpha
        self.smoothed = ppl if self.smoothed is None else \
            a * ppl + (1 - a) * self.smoothed
        if self.smoothed <= self.run.target_perplexity:
            self.hits += 1
        else:
            self.hits = 0
        if self.hits >= self.run.patience_rounds:
            self.reached = True

    def out_of_budget(self, t_s: float, rounds: int) -> bool:
        return (t_s >= self.run.max_hours * 3600.0
                or rounds >= self.run.max_rounds)


def _select_cohort(rng: np.random.Generator, k: int, population: int,
                   exclude_eval: int = 10_000_000) -> np.ndarray:
    """Coordinator client selection: eligible devices, unique per round."""
    return rng.choice(exclude_eval, size=k, replace=False) % population


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------

STRATEGIES: Dict[str, Type["Strategy"]] = {}


def register_strategy(name: str):
    """Class decorator: expose a Strategy under a string key (open for
    carbon-aware selection policies next)."""
    def deco(cls: Type["Strategy"]) -> Type["Strategy"]:
        cls.mode = name
        STRATEGIES[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> "Strategy":
    try:
        return STRATEGIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: {sorted(STRATEGIES)}"
        ) from None


class Strategy:
    """One FL orchestration policy. Subclasses implement `_loop`; the base
    handles sampler/estimator wiring so every strategy sees the same
    environment knobs (fleet, country mix, bandwidths, carbon models)."""

    mode: str = ""

    def run(self, model_cfg: ModelConfig, fed: FederatedConfig,
            run: RunConfig, learner, *, seq_len: int = 64,
            estimator: Optional[CarbonEstimator] = None,
            sampler: Optional[SessionSampler] = None,
            on_round: Optional[RoundCallback] = None) -> TaskResult:
        sampler = sampler or SessionSampler(model_cfg, fed, seq_len)
        est = estimator or CarbonEstimator()
        log = TaskLog()
        stop = _Stopper(run)
        t, rounds, ppl = self._loop(model_cfg, fed, learner, sampler, log,
                                    stop, on_round)
        return TaskResult(log, est.estimate(log), stop.reached, rounds,
                          t / 3600.0, ppl, stop.smoothed or ppl)

    # subclasses: run the event loop, return (t_s, rounds, perplexity)
    def _loop(self, model_cfg: ModelConfig, fed: FederatedConfig, learner,
              sampler: SessionSampler, log: TaskLog, stop: _Stopper,
              on_round: Optional[RoundCallback]) -> Tuple[float, int, float]:
        raise NotImplementedError

    def _emit(self, on_round: Optional[RoundCallback], log: TaskLog,
              round_idx: int, t: float, ppl: float, smoothed: float) -> None:
        if on_round is not None:
            on_round(RoundEvent(round_idx, t, ppl, smoothed,
                                len(log.sessions), self.mode))


@register_strategy("sync")
class SyncStrategy(Strategy):
    """FedAvg rounds with over-selection cancel (paper §3.1 sync)."""

    def _loop(self, model_cfg, fed, learner, sampler, log, stop, on_round):
        assert fed.mode == "sync"
        rng = np.random.default_rng(fed.seed + 1)
        t = 0.0
        rounds = 0
        ppl = float(model_cfg.vocab_size)

        while True:
            cohort = _select_cohort(rng, fed.concurrency, population=5_000_000)
            plans = [sampler.plan(int(c), rounds) for c in cohort]
            # pass 1: tentative outcomes, find when the goal-th result arrives
            tentative = [sampler.resolve(p, rounds, t) for p in plans]
            ends = sorted(s["end_t"] for s, ok in tentative if ok)
            goal = min(fed.aggregation_goal, fed.concurrency)
            if len(ends) >= goal:
                round_end = ends[goal - 1]
                failed = False
            elif ends:
                # dropouts ate the over-selection slack: the round closes at
                # the last survivor (production would hit the round deadline)
                # and the server updates with what it received
                round_end = ends[-1]
                failed = False
            else:
                round_end = max((s["end_t"] for s, _ in tentative), default=t)
                failed = True
            # pass 2: sessions against the round deadline (cancel stragglers)
            contributors: List[int] = []
            for p in plans:
                kw, ok = sampler.resolve(p, rounds, t, deadline=round_end)
                log.log_session(ClientSession(**kw))
                if ok and len(contributors) < goal:
                    contributors.append(p.client_id)
            t = round_end + _SERVER_AGG_S
            rounds += 1
            if not failed and contributors:
                deltas, weights = [], []
                if getattr(learner, "real", True):
                    if hasattr(learner, "client_deltas"):
                        deltas, weights = learner.client_deltas(contributors)
                    else:
                        for c in contributors:
                            d, w = learner.client_delta(c, None)
                            deltas.append(d)
                            weights.append(w)
                else:
                    deltas, weights = [None], [1.0]
                learner.apply(deltas, weights, n_contributors=len(contributors))
                ppl = learner.eval_perplexity()
                stop.update(ppl)
            log.log_round(t)
            log.log_eval(t, rounds, ppl, stop.smoothed or ppl)
            self._emit(on_round, log, rounds, t, ppl, stop.smoothed or ppl)
            if stop.reached or stop.out_of_budget(t, rounds):
                break
        return t, rounds, ppl


@register_strategy("async")
class AsyncStrategy(Strategy):
    """FedBuff: always-`concurrency` in-flight clients, buffer size =
    aggregation_goal, staleness-weighted aggregation."""

    def _loop(self, model_cfg, fed, learner, sampler, log, stop, on_round):
        assert fed.mode == "async"
        rng = np.random.default_rng(fed.seed + 2)
        t = 0.0
        version = 0
        ppl = float(model_cfg.vocab_size)
        buffer: List[Tuple[int, int]] = []        # (client_id, version_sent)
        heap: List[Tuple[float, int, int, object]] = []  # (end, cid, ver, plan)
        counter = 0

        def dispatch(cid: int, now: float):
            nonlocal counter
            plan = sampler.plan(cid, version)
            kw, ok = sampler.resolve(plan, version, now)
            heapq.heappush(heap, (kw["end_t"], counter, cid, (kw, ok, version)))
            counter += 1

        for c in _select_cohort(rng, fed.concurrency, population=5_000_000):
            dispatch(int(c), t + float(rng.uniform(0, 5.0)))

        while heap:
            if stop.out_of_budget(t, version):
                break
            end, _, cid, (kw, ok, ver_sent) = heapq.heappop(heap)
            t = max(t, end)
            log.log_session(ClientSession(staleness=version - ver_sent, **kw))
            if ok:
                buffer.append((cid, ver_sent))
                if len(buffer) >= fed.aggregation_goal:
                    staleness = [version - v for _, v in buffer]
                    deltas, weights = [], []
                    is_real = getattr(learner, "real", True)
                    if is_real:
                        for bc, bv in buffer:
                            d, w = learner.client_delta(bc, bv)
                            deltas.append(d)
                            weights.append(w)
                    else:
                        deltas, weights = [None], [1.0]
                    kw_extra = {"staleness": staleness} if is_real else {}
                    learner.apply(deltas, weights,
                                  n_contributors=len(buffer),
                                  mean_staleness=float(np.mean(staleness)),
                                  **kw_extra)
                    buffer = []
                    version += 1
                    t += _SERVER_AGG_S
                    ppl = learner.eval_perplexity()
                    stop.update(ppl)
                    log.log_round(t)
                    log.log_eval(t, version, ppl, stop.smoothed or ppl)
                    self._emit(on_round, log, version, t, ppl,
                               stop.smoothed or ppl)
                    if stop.reached or stop.out_of_budget(t, version):
                        break
            # keep concurrency in-flight: replace this client immediately
            nxt = int(rng.choice(5_000_000))
            dispatch(nxt, t)
        return t, version, ppl


# ---------------------------------------------------------------------------
# Deprecated free-function shims (pre-`repro.api` entry points)
# ---------------------------------------------------------------------------

def run_sync(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
             learner, seq_len: int = 64,
             estimator: Optional[CarbonEstimator] = None) -> TaskResult:
    warnings.warn(
        "run_sync is deprecated; use repro.api.Experiment",
        DeprecationWarning, stacklevel=2)
    return SyncStrategy().run(model_cfg, fed, run, learner, seq_len=seq_len,
                              estimator=estimator)


def run_async(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
              learner, seq_len: int = 64,
              estimator: Optional[CarbonEstimator] = None) -> TaskResult:
    warnings.warn(
        "run_async is deprecated; use repro.api.Experiment",
        DeprecationWarning, stacklevel=2)
    return AsyncStrategy().run(model_cfg, fed, run, learner, seq_len=seq_len,
                               estimator=estimator)


def run_task(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
             learner, seq_len: int = 64) -> TaskResult:
    """Deprecated: build an `repro.api.ExperimentSpec` and run it through
    `repro.api.Experiment` instead."""
    warnings.warn(
        "run_task is deprecated; use repro.api.Experiment", DeprecationWarning,
        stacklevel=2)
    return get_strategy(fed.mode).run(model_cfg, fed, run, learner,
                                      seq_len=seq_len)
