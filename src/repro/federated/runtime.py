"""The FL task runtime: synchronous (FedAvg) and asynchronous (FedBuff)
event loops with full carbon telemetry (paper §3.1).

Both loops are ``Strategy`` classes registered in the string-keyed
``STRATEGIES`` registry ("sync", "async"; ``register_strategy`` is open for
carbon-aware variants). They drive a pluggable learner (RealLearner or
SurrogateLearner) through the same PAPAYA-shaped protocol:

sync  — each round selects `concurrency` clients ("users per round"); the
        round closes when the `aggregation_goal`-th result arrives; clients
        still running are cancelled (over-selection waste is charged);
        server updates once per round.
async — `concurrency` clients are always in flight; a finished client's
        (staleness-weighted) delta joins the buffer; every
        `aggregation_goal` arrivals the server updates and later clients
        train on the newer model (FedBuff). Stragglers never block.

Both loops are columnar: cohorts are planned/resolved through the
vectorized ``SessionSampler.plan_batch``/``resolve_batch`` and logged as
``SessionBatch`` columns (sync: one batch per round; async: one flush at
the end of the task), so the per-session cost is a few array ops rather
than Python-object allocation. The returned TaskLog contains every
session's vitals; CarbonEstimator turns it into the paper's component
breakdown. Strategies emit a ``RoundEvent`` after every server eval so
callers (``repro.api.Experiment``) can stream progress. ``run_task``
survives only as a deprecated shim over the registry — new code goes
through ``repro.api``.
"""
from __future__ import annotations

import heapq
import warnings
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Type

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig, RunConfig
from repro.core.estimator import CarbonBreakdown, CarbonEstimator
from repro.core.telemetry import SessionBatch, TaskLog
from repro.federated.events import SessionSampler

_SERVER_AGG_S = 2.0     # server-side aggregation latency per update
_POPULATION = 5_000_000  # eligible-device pool the coordinator selects from


@dataclass
class TaskResult:
    log: TaskLog
    carbon: CarbonBreakdown
    reached_target: bool
    rounds: int
    duration_h: float
    final_perplexity: float
    smoothed_perplexity: float

    def summary(self) -> Dict[str, float]:
        return {
            "rounds": self.rounds,
            "duration_h": self.duration_h,
            "reached_target": float(self.reached_target),
            "perplexity": self.final_perplexity,
            "carbon_total_kg": self.carbon.total_kg,
            **{k: v for k, v in self.carbon.as_dict().items()},
            "sessions": float(self.log.n_sessions),
        }


@dataclass(frozen=True)
class RoundEvent:
    """Streamed to `on_round` after every server eval (both strategies)."""
    round_idx: int               # server model updates so far
    t_s: float                   # task clock, seconds
    perplexity: float
    smoothed_perplexity: float
    n_sessions: int              # client sessions logged so far
    mode: str                    # strategy key ("sync" / "async")


RoundCallback = Callable[[RoundEvent], None]


class _Stopper:
    """Paper §3.2: stop when smoothed test perplexity has been at/below the
    target for `patience` consecutive evals, or at the time limit."""

    def __init__(self, run: RunConfig):
        self.run = run
        self.smoothed: Optional[float] = None
        self.hits = 0
        self.reached = False

    def update(self, ppl: float) -> None:
        a = self.run.ema_alpha
        self.smoothed = ppl if self.smoothed is None else \
            a * ppl + (1 - a) * self.smoothed
        if self.smoothed <= self.run.target_perplexity:
            self.hits += 1
        else:
            self.hits = 0
        if self.hits >= self.run.patience_rounds:
            self.reached = True

    def out_of_budget(self, t_s: float, rounds: int) -> bool:
        return (t_s >= self.run.max_hours * 3600.0
                or rounds >= self.run.max_rounds)


def _select_cohort(rng: np.random.Generator, k: int,
                   population: int) -> np.ndarray:
    """Coordinator client selection: eligible devices, unique per round.
    Sampled without replacement from the population directly (the old
    sample-from-a-larger-range-then-modulo trick silently reintroduced
    duplicates and a mild modulo bias)."""
    return rng.choice(population, size=k, replace=False).astype(np.int64)


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------

STRATEGIES: Dict[str, Type["Strategy"]] = {}


def register_strategy(name: str):
    """Class decorator: expose a Strategy under a string key (open for
    carbon-aware selection policies next)."""
    def deco(cls: Type["Strategy"]) -> Type["Strategy"]:
        cls.mode = name
        STRATEGIES[name] = cls
        return cls
    return deco


def get_strategy(name: str) -> "Strategy":
    try:
        return STRATEGIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: {sorted(STRATEGIES)}"
        ) from None


class Strategy:
    """One FL orchestration policy. Subclasses implement `_loop`; the base
    handles sampler/estimator wiring so every strategy sees the same
    environment knobs (fleet, country mix, bandwidths, carbon models)."""

    mode: str = ""

    def run(self, model_cfg: ModelConfig, fed: FederatedConfig,
            run: RunConfig, learner, *, seq_len: int = 64,
            estimator: Optional[CarbonEstimator] = None,
            sampler: Optional[SessionSampler] = None,
            on_round: Optional[RoundCallback] = None) -> TaskResult:
        sampler = sampler or SessionSampler(model_cfg, fed, seq_len)
        est = estimator or CarbonEstimator()
        log = TaskLog()
        stop = _Stopper(run)
        t, rounds, ppl = self._loop(model_cfg, fed, learner, sampler, log,
                                    stop, on_round)
        return TaskResult(log, est.estimate(log), stop.reached, rounds,
                          t / 3600.0, ppl, stop.smoothed or ppl)

    # subclasses: run the event loop, return (t_s, rounds, perplexity)
    def _loop(self, model_cfg: ModelConfig, fed: FederatedConfig, learner,
              sampler: SessionSampler, log: TaskLog, stop: _Stopper,
              on_round: Optional[RoundCallback]) -> Tuple[float, int, float]:
        raise NotImplementedError

    def _emit(self, on_round: Optional[RoundCallback], n_sessions: int,
              round_idx: int, t: float, ppl: float, smoothed: float) -> None:
        if on_round is not None:
            on_round(RoundEvent(round_idx, t, ppl, smoothed,
                                n_sessions, self.mode))


@register_strategy("sync")
class SyncStrategy(Strategy):
    """FedAvg rounds with over-selection cancel (paper §3.1 sync)."""

    def _loop(self, model_cfg, fed, learner, sampler, log, stop, on_round):
        assert fed.mode == "sync"
        rng = np.random.default_rng(fed.seed + 1)
        t = 0.0
        rounds = 0
        ppl = float(model_cfg.vocab_size)
        goal = min(fed.aggregation_goal, fed.concurrency)

        while True:
            cohort = _select_cohort(rng, fed.concurrency,
                                    population=_POPULATION)
            pb = sampler.plan_batch(cohort, rounds)
            # pass 1: tentative outcomes, find when the goal-th result
            # arrives (a partition on end_t, not a full sort)
            tb, ok = sampler.resolve_batch(pb, rounds, t)
            ends = tb.end_t[ok]
            if len(ends) >= goal:
                round_end = float(np.partition(ends, goal - 1)[goal - 1])
                failed = False
            elif len(ends):
                # dropouts ate the over-selection slack: the round closes at
                # the last survivor (production would hit the round deadline)
                # and the server updates with what it received
                round_end = float(ends.max())
                failed = False
            else:
                round_end = float(tb.end_t.max()) if len(tb) else t
                failed = True
            # pass 2: sessions against the round deadline (cancel stragglers)
            fb, ok2 = sampler.resolve_batch(pb, rounds, t, deadline=round_end)
            log.log_batch(fb)
            contributors: List[int] = \
                cohort[np.nonzero(ok2)[0][:goal]].tolist()
            t = round_end + _SERVER_AGG_S
            rounds += 1
            if not failed and contributors:
                deltas, weights = [], []
                if getattr(learner, "real", True):
                    if hasattr(learner, "client_deltas"):
                        deltas, weights = learner.client_deltas(contributors)
                    else:
                        for c in contributors:
                            d, w = learner.client_delta(c, None)
                            deltas.append(d)
                            weights.append(w)
                else:
                    deltas, weights = [None], [1.0]
                learner.apply(deltas, weights, n_contributors=len(contributors))
                ppl = learner.eval_perplexity()
                stop.update(ppl)
            log.log_round(t)
            log.log_eval(t, rounds, ppl, stop.smoothed or ppl)
            self._emit(on_round, log.n_sessions, rounds, t, ppl,
                       stop.smoothed or ppl)
            if stop.reached or stop.out_of_budget(t, rounds):
                break
        return t, rounds, ppl


class _ReplacementPool:
    """Batched dispatch for the async loop: replacement client sessions are
    planned AND resolved `block` at a time against the current server
    version (outcome randomness depends only on (client_id, version), and
    durations are start-time-shift-invariant, so resolving at relative
    start 0 and shifting to the dispatch time is exact). When the version
    advances, the not-yet-dispatched remainder is re-planned at the new
    version — exactly what per-pop scalar dispatch would have done."""

    CHUNK = 256   # rows materialized into python tuples at a time

    def __init__(self, sampler: SessionSampler, rng: np.random.Generator,
                 population: int, block: int = 512):
        self.sampler = sampler
        self.rng = rng
        self.population = population
        self.block = block
        self._ids = np.empty(0, np.int64)
        self._version = -1
        self._consumed = 0     # rows of the planned block handed out
        self._mat = 0          # rows of the planned block materialized
        self._batch = None

    def _plan(self, version: int) -> None:
        """(Re)plan the pending block at `version`. Not-yet-consumed ids
        survive a version change and are re-resolved — exactly what per-pop
        scalar dispatch at the new version would have produced. Fresh ids
        are drawn `block` at a time; rows are materialized lazily in CHUNK
        slices so a re-plan never pays tuple-building for rows it drops."""
        ids = self._ids[self._consumed:]
        if not len(ids):
            ids = self.rng.integers(0, self.population, size=self.block)
        self._ids = np.asarray(ids, np.int64)
        self._version = version
        self._consumed = 0
        self._mat = 0
        self._batch = self.sampler.resolve_batch(
            self.sampler.plan_batch(self._ids, version), version, 0.0)

    def chunk(self, version: int, used: int) -> List[tuple]:
        """Report `used` rows consumed from the previous chunk, then return
        the next chunk of rows — 11-tuples ``(cid, dev, ctry, download_s,
        compute_s, upload_s, bytes_down, bytes_up, end_rel, outcome, ok)``
        resolved at `version` with durations relative to dispatch time."""
        self._consumed += used
        if self._version != version or self._consumed >= len(self._ids):
            self._plan(version)
        b, ok = self._batch
        lo, hi = self._mat, min(self._mat + self.CHUNK, len(self._ids))
        self._mat = hi
        return list(zip(
            self._ids[lo:hi].tolist(), b.device_idx[lo:hi].tolist(),
            b.country_idx[lo:hi].tolist(), b.download_s[lo:hi].tolist(),
            b.compute_s[lo:hi].tolist(), b.upload_s[lo:hi].tolist(),
            b.bytes_down[lo:hi].tolist(), b.bytes_up[lo:hi].tolist(),
            b.end_t[lo:hi].tolist(), b.outcome[lo:hi].tolist(),
            ok[lo:hi].tolist()))


@register_strategy("async")
class AsyncStrategy(Strategy):
    """FedBuff: always-`concurrency` in-flight clients, buffer size =
    aggregation_goal, staleness-weighted aggregation. The event heap stays
    (arrival order is inherently sequential) but sessions are planned and
    resolved in batches and logged as one SessionBatch at the end."""

    def _loop(self, model_cfg, fed, learner, sampler, log, stop, on_round):
        assert fed.mode == "async"
        rng = np.random.default_rng(fed.seed + 2)
        t = 0.0
        version = 0
        ppl = float(model_cfg.vocab_size)
        buffer: List[Tuple[int, int]] = []        # (client_id, version_sent)
        # heap rows: (end_abs, counter, payload, start_abs, version_sent)
        # where payload is the pool's 11-tuple (cid, dev, ctry, d, c, u,
        # bdown, bup, end_rel, outcome_code, ok)
        heap: List[tuple] = []
        counter = 0
        pool = _ReplacementPool(
            sampler, rng, _POPULATION,
            block=max(256, min(4096, 2 * fed.aggregation_goal)))
        popped: List[tuple] = []       # heap rows, in arrival order
        update_pops: List[int] = []    # len(popped) at each server update
        # hot-loop locals (the pop loop runs once per session)
        heappop, heappush = heapq.heappop, heapq.heappush
        popped_append = popped.append
        goal = fed.aggregation_goal
        max_t = stop.run.max_hours * 3600.0
        max_rounds = stop.run.max_rounds
        blk: List[tuple] = []
        bpos = 0

        # initial cohort: one batched plan/resolve with jittered starts
        cohort = _select_cohort(rng, fed.concurrency, population=_POPULATION)
        starts = rng.uniform(0, 5.0, size=fed.concurrency)
        b0, ok0 = sampler.resolve_batch(
            sampler.plan_batch(cohort, version), version, starts)
        for end0, start0, payload in zip(
                b0.end_t.tolist(), b0.start_t.tolist(),
                zip(cohort.tolist(), b0.device_idx.tolist(),
                    b0.country_idx.tolist(), b0.download_s.tolist(),
                    b0.compute_s.tolist(), b0.upload_s.tolist(),
                    b0.bytes_down.tolist(), b0.bytes_up.tolist(),
                    b0.end_t.tolist(), b0.outcome.tolist(), ok0.tolist())):
            heapq.heappush(heap, (end0, counter, payload, start0, version))
            counter += 1

        is_real = getattr(learner, "real", True)
        buf_append = buffer.append
        blk_n = 0
        if version >= max_rounds:
            heap = []
        while heap:
            # the version budget can only trip right after an update, where
            # it is checked before the loop resumes — only time stays here
            if t >= max_t:
                break
            row = heappop(heap)
            end = row[0]
            if end > t:
                t = end
            popped_append(row)
            payload = row[2]
            if payload[10]:  # ok -> contributes to the aggregation buffer
                buf_append((payload[0], row[4]))
                if len(buffer) >= goal:
                    if is_real:
                        staleness = [version - v for _, v in buffer]
                        deltas, weights = [], []
                        for bc, bv in buffer:
                            dd, w = learner.client_delta(bc, bv)
                            deltas.append(dd)
                            weights.append(w)
                        kw_extra = {"staleness": staleness}
                        mean_st = float(np.mean(staleness))
                    else:
                        deltas, weights, kw_extra = [None], [1.0], {}
                        mean_st = version - (sum(v for _, v in buffer)
                                             / len(buffer))
                    learner.apply(deltas, weights,
                                  n_contributors=len(buffer),
                                  mean_staleness=mean_st, **kw_extra)
                    buffer.clear()
                    version += 1
                    blk_n = bpos       # force a chunk refresh (new version)
                    t += _SERVER_AGG_S
                    update_pops.append(len(popped))
                    ppl = learner.eval_perplexity()
                    stop.update(ppl)
                    log.log_round(t)
                    log.log_eval(t, version, ppl, stop.smoothed or ppl)
                    self._emit(on_round, len(popped), version, t,
                               ppl, stop.smoothed or ppl)
                    if stop.reached or stop.out_of_budget(t, version):
                        break
            # keep concurrency in-flight: replace this client immediately
            # (inlined pool fast path: one pre-resolved row per dispatch;
            # blk_n is forced to bpos on version bumps to refresh the chunk)
            if bpos >= blk_n:
                blk = pool.chunk(version, bpos)
                blk_n = len(blk)
                bpos = 0
            r = blk[bpos]
            bpos += 1
            heappush(heap, (t + r[8], counter, r, t, version))
            counter += 1

        if popped:
            # transpose the arrival-ordered heap rows into columns; the
            # server version at each arrival is recovered from the update
            # boundaries (update_pops) instead of a per-pop append
            end_c, _, payload_c, st_c, ver_c = zip(*popped)
            (cid_c, dev_c, ctry_c, d_c, c_c, u_c, bd_c, bu_c, _,
             out_c, _) = zip(*payload_c)
            ver_sent = np.asarray(ver_c, np.int64)
            ver_at_pop = np.searchsorted(
                np.asarray(update_pops, np.int64),
                np.arange(len(popped), dtype=np.int64), side="right")
            log.log_batch(SessionBatch(
                device_names=sampler.device_names,
                country_names=sampler.country_names,
                client_id=np.asarray(cid_c, np.int64),
                round_idx=ver_sent,
                device_idx=np.asarray(dev_c, np.int32),
                country_idx=np.asarray(ctry_c, np.int32),
                download_s=np.asarray(d_c),
                compute_s=np.asarray(c_c),
                upload_s=np.asarray(u_c),
                bytes_down=np.asarray(bd_c),
                bytes_up=np.asarray(bu_c),
                start_t=np.asarray(st_c),
                end_t=np.asarray(end_c),
                outcome=np.asarray(out_c, np.int8),
                staleness=(ver_at_pop - ver_sent).astype(np.int32)))
        return t, version, ppl


# ---------------------------------------------------------------------------
# Deprecated free-function shims (pre-`repro.api` entry points)
# ---------------------------------------------------------------------------

def run_sync(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
             learner, seq_len: int = 64,
             estimator: Optional[CarbonEstimator] = None) -> TaskResult:
    warnings.warn(
        "run_sync is deprecated; use repro.api.Experiment",
        DeprecationWarning, stacklevel=2)
    return SyncStrategy().run(model_cfg, fed, run, learner, seq_len=seq_len,
                              estimator=estimator)


def run_async(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
              learner, seq_len: int = 64,
              estimator: Optional[CarbonEstimator] = None) -> TaskResult:
    warnings.warn(
        "run_async is deprecated; use repro.api.Experiment",
        DeprecationWarning, stacklevel=2)
    return AsyncStrategy().run(model_cfg, fed, run, learner, seq_len=seq_len,
                               estimator=estimator)


def run_task(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
             learner, seq_len: int = 64) -> TaskResult:
    """Deprecated: build an `repro.api.ExperimentSpec` and run it through
    `repro.api.Experiment` instead."""
    warnings.warn(
        "run_task is deprecated; use repro.api.Experiment", DeprecationWarning,
        stacklevel=2)
    return get_strategy(fed.mode).run(model_cfg, fed, run, learner,
                                      seq_len=seq_len)
