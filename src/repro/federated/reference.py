"""Scalar reference engine — the pre-columnar event loops, kept verbatim.

The production strategies in ``repro.federated.runtime`` are vectorized
end-to-end (``plan_batch``/``resolve_batch``/``SessionBatch``). This module
preserves the original per-session Python loops, driven by the sampler's
``plan_scalar``/``resolve_scalar`` and the estimator's ``estimate_scalar``,
for two purposes only:

* seed-for-seed equivalence tests (``tests/test_columnar.py``) prove the
  columnar sync AND async engines reproduce this loop's TaskLog stats and
  CarbonBreakdown;
* ``benchmarks/bench_runtime.py`` measures sessions/sec against it, so the
  vectorization speedup is tracked across PRs.

The async loop here still pops a heap one session at a time, but it is
keyed the same way as the vectorized window merge: heap order is
``(end_t, slot, generation)`` and replacement client ids come from the
per-slot counter-based splitmix64 streams (``slot_stream_id``) rather
than the shared rng — identity decoupled from pop rank is exactly what
makes the columnar engine's batched merge reproduce this loop.

Do not grow features here — it intentionally trails the real engine except
where equivalence demands parity (cohort selection, byte proration, the
cancelled-session flush at task end).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig, RunConfig
from repro.core.estimator import CarbonEstimator
from repro.core.telemetry import OUTCOME_CODE, ClientSession, TaskLog
from repro.federated.events import (SessionSampler, retry_stream_id,
                                    slot_stream_id)
from repro.federated.runtime import (_POPULATION, _SERVER_AGG_S, TaskResult,
                                     _retry_rem, _select_cohort,
                                     _sync_dispatch_n, _Stopper)


def _rem_after(kw: dict, planned_c: float, rem: float,
               period_s: float) -> float:
    """Scalar twin of the engine's per-row remainder bookkeeping: wraps
    ``_retry_rem`` batch-of-1 so the float op sequence (floor, divide,
    multiply) is shared verbatim with the columnar loops."""
    return float(_retry_rem(
        np.asarray([OUTCOME_CODE[kw["outcome"]]], np.int8),
        np.asarray([planned_c]), np.asarray([kw["compute_s"]]),
        np.asarray([rem]), period_s)[0])


def run_scalar(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
               learner, *, seq_len: int = 64,
               estimator: Optional[CarbonEstimator] = None,
               sampler: Optional[SessionSampler] = None) -> TaskResult:
    """Run one FL task through the scalar reference loop for `fed.mode`."""
    sampler = sampler or SessionSampler(model_cfg, fed, seq_len)
    est = estimator or CarbonEstimator()
    log = TaskLog()
    # mirror Strategy.run's effective salvage period for estimate_scalar
    log.checkpoint_period_s = fed.checkpoint_period_s \
        if (sampler.has_avail and fed.retry_limit > 0) else 0.0
    stop = _Stopper(run)
    if fed.mode == "sync":
        t, rounds, ppl = _sync_loop(model_cfg, fed, learner, sampler, log,
                                    stop)
    elif fed.mode == "carbon-aware":
        t, rounds, ppl = _async_loop(model_cfg, fed, learner, sampler, log,
                                     stop,
                                     pick_id=_carbon_pick(sampler, est, fed))
    else:
        t, rounds, ppl = _async_loop(model_cfg, fed, learner, sampler, log,
                                     stop)
    return TaskResult(log, est.estimate_scalar(log), stop.reached, rounds,
                      t / 3600.0, ppl, stop.smoothed or ppl,
                      aborted=stop.aborted)


def _carbon_pick(sampler: SessionSampler, est: CarbonEstimator,
                 fed: FederatedConfig):
    """Per-pop replacement picker for the carbon-aware oracle: delegates to
    the engine's own columnar ``carbon_pick_ids`` with a batch of one, so
    the oracle is keyed to the SAME probe draws / country screens and the
    heap loop stays a pure event-order reference. That call also shares
    the engine's compiled schedule-segment tables
    (``_VocabSchedule.segment_table``/``allowed_masks``), so the oracle's
    batch-of-1 screen reads the exact float values the batched engine
    gathers — pick identity holds by construction, not by luck. The
    oracle never passes ``skip``: its retry rows are re-keyed before the
    pick, so every row here is a live screen."""
    from repro.federated.runtime import carbon_pick_ids

    def pick(slot: int, gen: int, now: float, version: int) -> int:
        return int(carbon_pick_ids(sampler, est.intensity, fed,
                                   np.asarray([slot], np.int64),
                                   np.asarray([gen], np.int64),
                                   np.asarray([now]), version)[0])
    return pick


def _sync_loop(model_cfg, fed, learner, sampler, log, stop):
    rng = np.random.default_rng(fed.seed + 1)
    t = 0.0
    rounds = 0
    ppl = float(model_cfg.vocab_size)
    goal = min(fed.aggregation_goal, fed.concurrency)
    ndisp = _sync_dispatch_n(fed, goal)
    lo = "cancelled" if fed.over_select_fraction > 0 else None
    quorum = max(1, int(np.ceil(fed.min_report_fraction * goal)))
    streak = 0

    while True:
        cohort = _select_cohort(rng, ndisp, population=_POPULATION)
        if sampler.has_faults or (sampler.has_avail
                                  and fed.retry_limit > 0):
            n_ok, contributors, round_end = _sync_faulty_round(
                fed, sampler, log, cohort, rounds, t, goal,
                late_outcome=lo)
        else:
            plans = [sampler.plan_scalar(int(c), rounds) for c in cohort]
            tentative = [sampler.resolve_scalar(p, rounds, t) for p in plans]
            ends = sorted(s["end_t"] for s, ok in tentative if ok)
            if len(ends) >= goal:
                round_end = ends[goal - 1]
            elif ends:
                round_end = ends[-1]
            else:
                round_end = max((s["end_t"] for s, _ in tentative),
                                default=t)
            n_ok = 0
            contributors: List[int] = []
            for p in plans:
                kw, ok = sampler.resolve_scalar(p, rounds, t,
                                                deadline=round_end,
                                                late_outcome=lo)
                log.log_session(ClientSession(**kw))
                if ok:
                    n_ok += 1
                    if len(contributors) < goal:
                        contributors.append(p.client_id)
        starved = n_ok < quorum
        t = round_end + _SERVER_AGG_S
        rounds += 1
        if not starved and contributors:
            if getattr(learner, "real", True):
                deltas, weights = [], []
                for c in contributors:
                    d, w = learner.client_delta(c, None)
                    deltas.append(d)
                    weights.append(w)
            else:
                deltas, weights = [None], [1.0]
            learner.apply(deltas, weights, n_contributors=len(contributors))
            ppl = learner.eval_perplexity()
            stop.update(ppl)
        log.log_round(t, starved=starved)
        log.log_eval(t, rounds, ppl, stop.smoothed or ppl)
        if starved:
            streak += 1
            if fed.starvation_patience and streak >= fed.starvation_patience:
                stop.aborted = True
                break
        else:
            streak = 0
        if stop.reached or stop.out_of_budget(t, rounds):
            break
    return t, rounds, ppl


def _sync_faulty_round(fed, sampler, log, cohort, rounds, t, goal,
                       late_outcome=None):
    """Scalar twin of ``SyncStrategy._faulty_round``: chase failed AND
    churn-interrupted slots through retry re-dispatches (distinct
    counter-keyed ids, exponential backoff; an interrupted attempt's
    retry redoes only the un-checkpointed remainder when
    ``checkpoint_period_s`` > 0), close the round over all attempts'
    survivors, then re-resolve every row WITH the deadline for logging
    (bit-identical to the engine's in-place ``apply_deadline`` patch).
    Returns (n_ok, contributors, round_end)."""
    salv_on = sampler.has_avail and fed.retry_limit > 0 \
        and fed.checkpoint_period_s > 0
    pos = list(range(len(cohort)))
    ids = [int(c) for c in cohort]
    starts = [t] * len(cohort)
    rems = [1.0] * len(cohort)
    blocks = []      # per attempt: list of (scaled_plan, start, kw_nodl)
    for att in range(fed.retry_limit + 1):
        rows = []
        for cid, s0, rm in zip(ids, starts, rems):
            plan = sampler.plan_scalar(cid, rounds)
            if salv_on and att:
                plan = dataclasses.replace(plan,
                                           compute_s=plan.compute_s * rm)
            kw, _ = sampler.resolve_scalar(plan, rounds, s0)
            rows.append((plan, s0, kw))
        blocks.append(rows)
        fm = [j for j, (_, _, kw) in enumerate(rows)
              if kw["outcome"] in ("failed", "interrupted")]
        if att == fed.retry_limit or not fm:
            break
        if salv_on:
            rems = [_rem_after(rows[j][2], rows[j][0].compute_s, rems[j],
                               fed.checkpoint_period_s) for j in fm]
        else:
            rems = [1.0] * len(fm)
        pos = [pos[j] for j in fm]
        ids = [retry_stream_id(fed.seed, p,
                               rounds * (fed.retry_limit + 1) + att + 1,
                               _POPULATION) for p in pos]
        starts = [rows[j][2]["end_t"] + fed.retry_backoff_s * 2.0 ** att
                  for j in fm]
    ok_ends = sorted(kw["end_t"] for rows in blocks
                     for _, _, kw in rows if kw["outcome"] == "completed")
    if len(ok_ends) >= goal:
        round_end = ok_ends[goal - 1]
    elif ok_ends:
        round_end = ok_ends[-1]
    else:
        round_end = max(kw["end_t"] for rows in blocks for _, _, kw in rows)
    n_ok = 0
    contributors: List[int] = []
    for att, rows in enumerate(blocks):
        for plan, s0, _ in rows:
            kw, ok = sampler.resolve_scalar(plan, rounds, s0,
                                            deadline=round_end,
                                            late_outcome=late_outcome)
            if att < fed.retry_limit and kw["outcome"] == "failed":
                # a retry went out for this failure (interrupted rows
                # keep their label — churn vs crash stays separable)
                kw = dict(kw, outcome="retried")
            log.log_session(ClientSession(**kw))
            if ok:
                n_ok += 1
                if len(contributors) < goal:
                    contributors.append(plan.client_id)
    return n_ok, contributors, round_end


def _cancel_scalar(kw: dict, t_final: float) -> dict:
    """Scalar twin of the columnar engine's ``_truncate_cancelled``: an
    in-flight session at task end burns until the final clock, downlink
    bytes prorate, uplink bytes zero (never reached the server)."""
    d, c, u = kw["download_s"], kw["compute_s"], kw["upload_s"]
    cap = max(0.0, t_final - kw["start_t"])
    nd = min(d, cap)
    nc = min(c, max(0.0, cap - d))
    nu = min(u, max(0.0, cap - d - c))
    frac = nd / d if d > 0 else 0.0
    out = dict(kw)
    # a pending retry may start past the task end (backoff delay): it
    # burned nothing, but never let end_t precede start_t
    out.update(download_s=nd, compute_s=nc, upload_s=nu,
               bytes_down=kw["bytes_down"] * frac, bytes_up=0.0,
               end_t=min(kw["end_t"], max(t_final, kw["start_t"])),
               outcome="cancelled")
    return out


def _async_loop(model_cfg, fed, learner, sampler, log, stop, pick_id=None):
    """The FedBuff heap oracle. ``pick_id(slot, gen, now, version)``
    overrides replacement identity (default: the per-slot counter streams)
    — how the carbon-aware twin reuses this loop unchanged."""
    if pick_id is None:
        def pick_id(slot, gen, now, version):
            return slot_stream_id(fed.seed, slot, gen, _POPULATION)
    retry_on = (sampler.has_faults or sampler.has_avail) \
        and fed.retry_limit > 0
    salv_on = retry_on and sampler.has_avail \
        and fed.checkpoint_period_s > 0
    rng = np.random.default_rng(fed.seed + 2)
    t = 0.0
    version = 0
    ppl = float(model_cfg.vocab_size)
    buffer: List[Tuple[int, int]] = []
    # heap rows ordered by (end_t, slot, generation) — the same key the
    # vectorized window merge sorts on. Replacement ids come from the
    # per-slot counter-based streams (slot_stream_id), NOT from `rng`, so
    # identity is independent of pop order in both engines.
    heap: List[tuple] = []

    def dispatch(slot: int, gen: int, cid: int, now: float, att: int = 0,
                 rem: float = 1.0):
        plan = sampler.plan_scalar(cid, version)
        if salv_on:
            # checkpoint/resume: a retry redoes only its parent's
            # remainder (x * 1.0 is exact for fresh dispatches)
            plan = dataclasses.replace(plan,
                                       compute_s=plan.compute_s * rem)
        kw, ok = sampler.resolve_scalar(plan, version, now)
        nrem = _rem_after(kw, plan.compute_s, rem,
                          fed.checkpoint_period_s) if salv_on else 1.0
        heapq.heappush(heap, (kw["end_t"], slot, gen, cid,
                              (kw, ok, version, att, nrem)))

    for slot, c in enumerate(_select_cohort(rng, fed.concurrency,
                                            population=_POPULATION)):
        dispatch(slot, 0, int(c), t + float(rng.uniform(0, 5.0)))

    while heap:
        if stop.out_of_budget(t, version):
            break
        end, slot, gen, cid, (kw, ok, ver_sent, att, nrem) = \
            heapq.heappop(heap)
        t = max(t, end)
        # a failed/interrupted pop with attempt budget left schedules a
        # retry below (distinct id stream, exponential backoff) -> a
        # failure logs as "retried"; churn keeps its "interrupted" label
        will_retry = retry_on \
            and kw["outcome"] in ("failed", "interrupted") \
            and att < fed.retry_limit
        log.log_session(ClientSession(
            staleness=version - ver_sent,
            **(dict(kw, outcome="retried")
               if will_retry and kw["outcome"] == "failed" else kw)))
        if ok:
            buffer.append((cid, ver_sent))
            if len(buffer) >= fed.aggregation_goal:
                staleness = [version - v for _, v in buffer]
                if getattr(learner, "real", True):
                    deltas, weights = [], []
                    for bc, bv in buffer:
                        d, w = learner.client_delta(bc, bv)
                        deltas.append(d)
                        weights.append(w)
                    kw_extra = {"staleness": staleness}
                else:
                    deltas, weights, kw_extra = [None], [1.0], {}
                learner.apply(deltas, weights, n_contributors=len(buffer),
                              mean_staleness=float(np.mean(staleness)),
                              **kw_extra)
                buffer = []
                version += 1
                t += _SERVER_AGG_S
                ppl = learner.eval_perplexity()
                stop.update(ppl)
                log.log_round(t)
                log.log_eval(t, version, ppl, stop.smoothed or ppl)
                if stop.reached or stop.out_of_budget(t, version):
                    break
        if will_retry:
            nid = retry_stream_id(fed.seed, slot, gen + 1, _POPULATION)
            dispatch(slot, gen + 1, nid,
                     t + fed.retry_backoff_s * 2.0 ** att, att + 1,
                     rem=nrem)
        else:
            nid = pick_id(slot, gen + 1, t, version)
            dispatch(slot, gen + 1, nid, t)
    # task end: sessions still in flight are logged as cancelled,
    # truncated at the final clock (keeps energy accounting complete)
    for end, slot, gen, cid, (kw, ok, ver_sent, att, nrem) in sorted(
            heap, key=lambda r: r[1]):
        log.log_session(ClientSession(staleness=version - ver_sent,
                                      **_cancel_scalar(kw, t)))
    return t, version, ppl
