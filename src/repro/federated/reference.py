"""Scalar reference engine — the pre-columnar event loops, kept verbatim.

The production strategies in ``repro.federated.runtime`` are vectorized
end-to-end (``plan_batch``/``resolve_batch``/``SessionBatch``). This module
preserves the original per-session Python loops, driven by the sampler's
``plan_scalar``/``resolve_scalar`` and the estimator's ``estimate_scalar``,
for two purposes only:

* seed-for-seed equivalence tests (``tests/test_columnar.py``) prove the
  columnar sync AND async engines reproduce this loop's TaskLog stats and
  CarbonBreakdown;
* ``benchmarks/bench_runtime.py`` measures sessions/sec against it, so the
  vectorization speedup is tracked across PRs.

The async loop here still pops a heap one session at a time, but it is
keyed the same way as the vectorized window merge: heap order is
``(end_t, slot, generation)`` and replacement client ids come from the
per-slot counter-based splitmix64 streams (``slot_stream_id``) rather
than the shared rng — identity decoupled from pop rank is exactly what
makes the columnar engine's batched merge reproduce this loop.

Do not grow features here — it intentionally trails the real engine except
where equivalence demands parity (cohort selection, byte proration, the
cancelled-session flush at task end).
"""
from __future__ import annotations

import heapq
from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig, RunConfig
from repro.core.estimator import CarbonEstimator
from repro.core.telemetry import ClientSession, TaskLog
from repro.federated.events import SessionSampler, slot_stream_id
from repro.federated.runtime import (_POPULATION, _SERVER_AGG_S, TaskResult,
                                     _select_cohort, _Stopper)


def run_scalar(model_cfg: ModelConfig, fed: FederatedConfig, run: RunConfig,
               learner, *, seq_len: int = 64,
               estimator: Optional[CarbonEstimator] = None,
               sampler: Optional[SessionSampler] = None) -> TaskResult:
    """Run one FL task through the scalar reference loop for `fed.mode`."""
    sampler = sampler or SessionSampler(model_cfg, fed, seq_len)
    est = estimator or CarbonEstimator()
    log = TaskLog()
    stop = _Stopper(run)
    if fed.mode == "sync":
        t, rounds, ppl = _sync_loop(model_cfg, fed, learner, sampler, log,
                                    stop)
    elif fed.mode == "carbon-aware":
        t, rounds, ppl = _async_loop(model_cfg, fed, learner, sampler, log,
                                     stop,
                                     pick_id=_carbon_pick(sampler, est, fed))
    else:
        t, rounds, ppl = _async_loop(model_cfg, fed, learner, sampler, log,
                                     stop)
    return TaskResult(log, est.estimate_scalar(log), stop.reached, rounds,
                      t / 3600.0, ppl, stop.smoothed or ppl)


def _carbon_pick(sampler: SessionSampler, est: CarbonEstimator,
                 fed: FederatedConfig):
    """Per-pop replacement picker for the carbon-aware oracle: delegates to
    the engine's own columnar ``carbon_pick_ids`` with a batch of one, so
    the oracle is keyed to the SAME probe draws / country screens and the
    heap loop stays a pure event-order reference."""
    from repro.federated.runtime import carbon_pick_ids

    def pick(slot: int, gen: int, now: float, version: int) -> int:
        return int(carbon_pick_ids(sampler, est.intensity, fed,
                                   np.asarray([slot], np.int64),
                                   np.asarray([gen], np.int64),
                                   np.asarray([now]), version)[0])
    return pick


def _sync_loop(model_cfg, fed, learner, sampler, log, stop):
    rng = np.random.default_rng(fed.seed + 1)
    t = 0.0
    rounds = 0
    ppl = float(model_cfg.vocab_size)

    while True:
        cohort = _select_cohort(rng, fed.concurrency, population=_POPULATION)
        plans = [sampler.plan_scalar(int(c), rounds) for c in cohort]
        tentative = [sampler.resolve_scalar(p, rounds, t) for p in plans]
        ends = sorted(s["end_t"] for s, ok in tentative if ok)
        goal = min(fed.aggregation_goal, fed.concurrency)
        if len(ends) >= goal:
            round_end = ends[goal - 1]
            failed = False
        elif ends:
            round_end = ends[-1]
            failed = False
        else:
            round_end = max((s["end_t"] for s, _ in tentative), default=t)
            failed = True
        contributors: List[int] = []
        for p in plans:
            kw, ok = sampler.resolve_scalar(p, rounds, t, deadline=round_end)
            log.log_session(ClientSession(**kw))
            if ok and len(contributors) < goal:
                contributors.append(p.client_id)
        t = round_end + _SERVER_AGG_S
        rounds += 1
        if not failed and contributors:
            if getattr(learner, "real", True):
                deltas, weights = [], []
                for c in contributors:
                    d, w = learner.client_delta(c, None)
                    deltas.append(d)
                    weights.append(w)
            else:
                deltas, weights = [None], [1.0]
            learner.apply(deltas, weights, n_contributors=len(contributors))
            ppl = learner.eval_perplexity()
            stop.update(ppl)
        log.log_round(t)
        log.log_eval(t, rounds, ppl, stop.smoothed or ppl)
        if stop.reached or stop.out_of_budget(t, rounds):
            break
    return t, rounds, ppl


def _cancel_scalar(kw: dict, t_final: float) -> dict:
    """Scalar twin of the columnar engine's ``_truncate_cancelled``: an
    in-flight session at task end burns until the final clock, downlink
    bytes prorate, uplink bytes zero (never reached the server)."""
    d, c, u = kw["download_s"], kw["compute_s"], kw["upload_s"]
    cap = max(0.0, t_final - kw["start_t"])
    nd = min(d, cap)
    nc = min(c, max(0.0, cap - d))
    nu = min(u, max(0.0, cap - d - c))
    frac = nd / d if d > 0 else 0.0
    out = dict(kw)
    out.update(download_s=nd, compute_s=nc, upload_s=nu,
               bytes_down=kw["bytes_down"] * frac, bytes_up=0.0,
               end_t=min(kw["end_t"], t_final), outcome="cancelled")
    return out


def _async_loop(model_cfg, fed, learner, sampler, log, stop, pick_id=None):
    """The FedBuff heap oracle. ``pick_id(slot, gen, now, version)``
    overrides replacement identity (default: the per-slot counter streams)
    — how the carbon-aware twin reuses this loop unchanged."""
    if pick_id is None:
        def pick_id(slot, gen, now, version):
            return slot_stream_id(fed.seed, slot, gen, _POPULATION)
    rng = np.random.default_rng(fed.seed + 2)
    t = 0.0
    version = 0
    ppl = float(model_cfg.vocab_size)
    buffer: List[Tuple[int, int]] = []
    # heap rows ordered by (end_t, slot, generation) — the same key the
    # vectorized window merge sorts on. Replacement ids come from the
    # per-slot counter-based streams (slot_stream_id), NOT from `rng`, so
    # identity is independent of pop order in both engines.
    heap: List[tuple] = []

    def dispatch(slot: int, gen: int, cid: int, now: float):
        plan = sampler.plan_scalar(cid, version)
        kw, ok = sampler.resolve_scalar(plan, version, now)
        heapq.heappush(heap, (kw["end_t"], slot, gen, cid,
                              (kw, ok, version)))

    for slot, c in enumerate(_select_cohort(rng, fed.concurrency,
                                            population=_POPULATION)):
        dispatch(slot, 0, int(c), t + float(rng.uniform(0, 5.0)))

    while heap:
        if stop.out_of_budget(t, version):
            break
        end, slot, gen, cid, (kw, ok, ver_sent) = heapq.heappop(heap)
        t = max(t, end)
        log.log_session(ClientSession(staleness=version - ver_sent, **kw))
        if ok:
            buffer.append((cid, ver_sent))
            if len(buffer) >= fed.aggregation_goal:
                staleness = [version - v for _, v in buffer]
                if getattr(learner, "real", True):
                    deltas, weights = [], []
                    for bc, bv in buffer:
                        d, w = learner.client_delta(bc, bv)
                        deltas.append(d)
                        weights.append(w)
                    kw_extra = {"staleness": staleness}
                else:
                    deltas, weights, kw_extra = [None], [1.0], {}
                learner.apply(deltas, weights, n_contributors=len(buffer),
                              mean_staleness=float(np.mean(staleness)),
                              **kw_extra)
                buffer = []
                version += 1
                t += _SERVER_AGG_S
                ppl = learner.eval_perplexity()
                stop.update(ppl)
                log.log_round(t)
                log.log_eval(t, version, ppl, stop.smoothed or ppl)
                if stop.reached or stop.out_of_budget(t, version):
                    break
        nid = pick_id(slot, gen + 1, t, version)
        dispatch(slot, gen + 1, nid, t)
    # task end: sessions still in flight are logged as cancelled,
    # truncated at the final clock (keeps energy accounting complete)
    for end, slot, gen, cid, (kw, ok, ver_sent) in sorted(
            heap, key=lambda r: r[1]):
        log.log_session(ClientSession(staleness=version - ver_sent,
                                      **_cancel_scalar(kw, t)))
    return t, version, ppl
