"""Client-side local training (paper §3.3): plain SGD, E local epochs.

``make_client_update`` builds a jitted function running a fixed number of
local SGD steps via ``lax.scan`` (stacked batches + per-step mask so ragged
client datasets fit one compiled shape) and returning the model DELTA and
the example count (FedAvg weighting).
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def make_client_update(loss_fn: Callable, client_lr: float,
                       max_grad_norm: float = 10.0) -> Callable:
    """loss_fn(params, batch) -> (loss, metrics).

    Returns f(params, batches, step_mask) -> (delta, total_examples, mean_loss)
    where batches is a dict of (n_steps, B, ...) stacked arrays and
    step_mask (n_steps,) zeroes out padding steps.
    """

    grad_fn = jax.grad(lambda p, b: loss_fn(p, b)[0])

    def one_step(params, batch_and_mask):
        batch, m = batch_and_mask
        g = grad_fn(params, batch)
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(v.astype(jnp.float32)))
                          for v in g.values()))
        scale = jnp.minimum(1.0, max_grad_norm / (gn + 1e-9)) * m
        new = {k: params[k] - (client_lr * scale) * g[k].astype(params[k].dtype)
               for k in params}
        loss = loss_fn(params, batch)[0]
        return new, loss * m

    def client_update(params, batches, step_mask):
        final, losses = lax.scan(
            one_step, params, (batches, step_mask.astype(jnp.float32)))
        delta = {k: final[k] - params[k] for k in params}
        n_steps = jnp.maximum(jnp.sum(step_mask), 1.0)
        return delta, jnp.sum(losses) / n_steps

    return jax.jit(client_update)


def stack_batches(batches, n_steps: int):
    """Pad a list of batch dicts to n_steps and build the step mask."""
    import numpy as np
    assert batches, "client has no data"
    batches = batches[:n_steps]
    mask = np.zeros((n_steps,), np.float32)
    mask[: len(batches)] = 1.0
    out = {}
    for k in batches[0]:
        arrs = [b[k] for b in batches]
        while len(arrs) < n_steps:
            arrs.append(np.zeros_like(arrs[0]))
        out[k] = np.stack(arrs)
    return out, mask
