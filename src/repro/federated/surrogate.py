"""Surrogate convergence model for large hyperparameter sweeps.

The paper measures *hundreds* of production runs. Re-training for every
sweep point is wasteful on CPU, so benchmarks can swap the real JAX learner
for this calibrated response-surface: perplexity decays exponentially in
log-space with server updates, at a rate set by hyperparameter quality
(learning rates / betas / batch size), local-epoch gain with non-IID drift
penalty (paper §5.2: E>3 hurts), cohort-size diminishing returns (Charles
et al. 2021, paper Fig. 7), and FedBuff staleness penalty. The surrogate
reproduces the paper's *relationships*; the real learner (federated.real)
validates the trainer end-to-end at small scale.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.configs.base import FederatedConfig, ModelConfig, RunConfig

TAU0 = 120.0           # updates to e-fold log-ppl at reference quality
REF_COHORT = 800.0     # cohort size where diminishing returns kick in (Fig.7)
PPL_FLOOR = 90.0       # model-capacity floor for the charlm task


def _log_bell(x: float, opt: float, width_decades: float) -> float:
    return math.exp(-((math.log10(x) - math.log10(opt)) / width_decades) ** 2)


@dataclass
class SurrogateLearner:
    real = False

    model_cfg: ModelConfig
    fed: FederatedConfig
    run: RunConfig

    def __post_init__(self):
        f = self.fed
        q = _log_bell(f.client_lr, 0.1, 0.8)
        q *= _log_bell(f.server_lr, 0.01, 1.0) if f.server_optimizer == "adam" \
            else _log_bell(f.server_lr, 0.3, 0.8)
        if f.server_optimizer == "adam":
            q *= math.exp(-((f.adam_beta1 - 0.9) / 0.45) ** 2)
            q *= math.exp(-((f.adam_beta2 - 0.995) / 0.05) ** 2)
        q *= _log_bell(f.client_batch_size, 16.0, 1.5)
        # local epochs: sublinear gain, non-IID drift beyond ~3 (paper §5.2)
        e = f.local_epochs
        gain = min(e, 3) ** 0.25
        if e > 3:
            gain *= max(0.7, 1.0 - 0.04 * (e - 3))
        q *= gain
        self._base_quality = q
        self._ppl0 = float(self.model_cfg.vocab_size)
        self.updates = 0
        self._staleness_ema = 0.0

    def quality(self, cohort_examples_clients: int, mean_staleness: float
                ) -> float:
        g = (max(cohort_examples_clients, 1) / REF_COHORT) ** 0.3
        s = 1.0 / (1.0 + 0.2 * mean_staleness ** 0.8) if mean_staleness > 0 else 1.0
        return self._base_quality * g * s

    # ------------------------------------------------------- learner api
    def client_delta(self, client_id: int, version: int):
        return None, 1.0     # no actual compute in surrogate mode

    def apply(self, deltas, weights, *, n_contributors: int,
              mean_staleness: float = 0.0) -> None:
        q = self.quality(n_contributors, mean_staleness)
        # one update advances log-ppl toward the floor by 1/tau e-fold
        self._staleness_ema = 0.8 * self._staleness_ema + 0.2 * mean_staleness
        tau = TAU0 / max(q, 1e-4)
        self.updates += 1
        self._progress = getattr(self, "_progress", 0.0) + 1.0 / tau

    def eval_perplexity(self) -> float:
        lo, hi = math.log(PPL_FLOOR), math.log(self._ppl0)
        prog = getattr(self, "_progress", 0.0)
        return math.exp(lo + (hi - lo) * math.exp(-prog))

    # ------------------------------------------------------- snapshot state
    def state(self) -> dict:
        """The mutable training state (everything ``apply`` touches); the
        quality surface itself is a pure function of the configs and is
        rebuilt from the spec on resume."""
        return {"updates": self.updates,
                "staleness_ema": self._staleness_ema,
                "progress": getattr(self, "_progress", 0.0)}

    def load_state(self, state) -> None:
        self.updates = int(state["updates"])
        self._staleness_ema = float(state["staleness_ema"])
        self._progress = float(state["progress"])
