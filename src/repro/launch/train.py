"""Production train driver: run a federated task end-to-end with full
carbon telemetry, on any model-zoo architecture.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch paper-charlm --reduced \\
      --mode sync --concurrency 8 --rounds 50
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \\
      --mode async --concurrency 6 --rounds 20 --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from repro.checkpoint import save_checkpoint
from repro.configs import (FederatedConfig, RunConfig, get_config, reduced)
from repro.data import FederatedDataset
from repro.federated import RealLearner, SurrogateLearner, run_task


def build_dataset(cfg, seq_len):
    return FederatedDataset(vocab_size=cfg.vocab_size, seq_len=seq_len,
                            char_vocab=cfg.char_vocab,
                            max_word_len=cfg.max_word_len)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-charlm")
    p.add_argument("--mode", default="sync", choices=("sync", "async"))
    p.add_argument("--concurrency", type=int, default=8)
    p.add_argument("--aggregation-goal", type=int, default=0)
    p.add_argument("--client-lr", type=float, default=0.3)
    p.add_argument("--server-lr", type=float, default=0.02)
    p.add_argument("--local-epochs", type=int, default=1)
    p.add_argument("--batch-size", type=int, default=8)
    p.add_argument("--rounds", type=int, default=50)
    p.add_argument("--target-ppl", type=float, default=1.0)
    p.add_argument("--seq-len", type=int, default=16)
    p.add_argument("--compression", default="none", choices=("none", "int8"))
    p.add_argument("--reduced", action="store_true",
                   help="tiny same-family variant (CPU-trainable)")
    p.add_argument("--surrogate", action="store_true",
                   help="carbon-only simulation, no real training")
    p.add_argument("--ckpt", default="")
    p.add_argument("--json", default="")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        layers = 3 if cfg.family == "hybrid" else 2
        cfg = reduced(cfg, layers=layers, d_model=128, d_ff=256, vocab=512)
        if cfg.family == "charlm":
            cfg = dataclasses.replace(cfg, lstm_hidden=128, max_context=16)
    fed = FederatedConfig(
        mode=args.mode, concurrency=args.concurrency,
        aggregation_goal=args.aggregation_goal or
        max(1, int(args.concurrency * 0.8)),
        client_lr=args.client_lr, server_lr=args.server_lr,
        local_epochs=args.local_epochs, client_batch_size=args.batch_size,
        compression=args.compression)
    run = RunConfig(target_perplexity=args.target_ppl,
                    max_rounds=args.rounds, max_hours=1e9)

    t0 = time.time()
    if args.surrogate:
        learner = SurrogateLearner(cfg, fed, run)
    else:
        ds = build_dataset(cfg, args.seq_len)
        learner = RealLearner(cfg, fed, run, ds)
        print(f"[train] initial perplexity {learner.eval_perplexity():.1f}")
    res = run_task(cfg, fed, run, learner, seq_len=args.seq_len)
    s = res.summary()
    print(f"[train] {args.arch} {args.mode} rounds={s['rounds']:.0f} "
          f"ppl={s['perplexity']:.1f} simulated={s['duration_h']:.2f}h "
          f"carbon={s['carbon_total_kg']*1000:.2f} gCO2e "
          f"(wall {time.time()-t0:.0f}s)")
    print(f"[train] carbon shares: "
          + " ".join(f"{k}={v:.2f}" for k, v in res.carbon.shares().items()))
    if args.ckpt and not args.surrogate:
        save_checkpoint(args.ckpt, {"params": learner.params},
                        meta={"rounds": res.rounds, "arch": args.arch})
        print(f"[train] checkpoint -> {args.ckpt}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(s, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
