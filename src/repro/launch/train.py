"""Production train driver: run a federated task end-to-end with full
carbon telemetry, on any model-zoo architecture — a thin CLI over
`repro.api.Experiment`.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch paper-charlm --reduced \\
      --mode sync --concurrency 8 --rounds 50
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \\
      --mode async --concurrency 6 --rounds 20 --ckpt /tmp/ck
  PYTHONPATH=src python -m repro.launch.train --spec exp.json   # replay one
"""
from __future__ import annotations

import json
import time

import argparse

from repro.api import Experiment, ExperimentSpec, ModelRef
from repro.checkpoint import save_checkpoint
from repro.configs import FederatedConfig, RunConfig, get_config


def reduced_model_ref(arch: str) -> ModelRef:
    """The driver's CPU-trainable shrink recipe, recorded declaratively."""
    family = get_config(arch).family
    overrides = {}
    if family == "charlm":
        overrides = dict(lstm_hidden=128, max_context=16)
    return ModelRef(arch=arch, reduced=True,
                    reduced_kw=dict(layers=3 if family == "hybrid" else 2,
                                    d_model=128, d_ff=256, vocab=512),
                    overrides=overrides)


def spec_from_args(args) -> ExperimentSpec:
    model = reduced_model_ref(args.arch) if args.reduced \
        else ModelRef(arch=args.arch)
    fed = FederatedConfig(
        mode=args.mode, concurrency=args.concurrency,
        aggregation_goal=args.aggregation_goal or
        max(1, int(args.concurrency * 0.8)),
        client_lr=args.client_lr, server_lr=args.server_lr,
        local_epochs=args.local_epochs, client_batch_size=args.batch_size,
        compression=args.compression)
    run = RunConfig(target_perplexity=args.target_ppl,
                    max_rounds=args.rounds, max_hours=1e9)
    return ExperimentSpec(
        model=model, federated=fed, run=run,
        learner="surrogate" if args.surrogate else "real",
        seq_len=args.seq_len)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="paper-charlm")
    p.add_argument("--mode", default="sync", choices=("sync", "async"))
    p.add_argument("--concurrency", type=int, default=8)
    p.add_argument("--aggregation-goal", type=int, default=0)
    p.add_argument("--client-lr", type=float, default=0.3)
    p.add_argument("--server-lr", type=float, default=0.02)
    p.add_argument("--local-epochs", type=int, default=1)
    p.add_argument("--batch-size", type=int, default=8)
    p.add_argument("--rounds", type=int, default=50)
    p.add_argument("--target-ppl", type=float, default=1.0)
    p.add_argument("--seq-len", type=int, default=16)
    p.add_argument("--compression", default="none", choices=("none", "int8"))
    p.add_argument("--reduced", action="store_true",
                   help="tiny same-family variant (CPU-trainable)")
    p.add_argument("--surrogate", action="store_true",
                   help="carbon-only simulation, no real training")
    p.add_argument("--spec", default="",
                   help="load an ExperimentSpec JSON (overrides other args)")
    p.add_argument("--save-spec", default="",
                   help="write the assembled ExperimentSpec JSON and exit")
    p.add_argument("--ckpt", default="")
    p.add_argument("--checkpoint", default="",
                   help="engine-snapshot path: checkpoint the mid-run "
                        "engine state there (surrogate learner only)")
    p.add_argument("--checkpoint-every", type=int, default=50,
                   help="rounds between engine snapshots (with "
                        "--checkpoint)")
    p.add_argument("--resume", default="",
                   help="resume from an engine snapshot (the spec "
                        "travels inside it; other args are ignored)")
    p.add_argument("--json", default="")
    args = p.parse_args(argv)

    if args.resume:
        t0 = time.time()
        res = Experiment.resume(
            args.resume,
            checkpoint_path=args.checkpoint or None,
            checkpoint_every_rounds=args.checkpoint_every
            if args.checkpoint else 0)
        s = res.summary()
        print(f"[train] resumed {args.resume} -> rounds={s['rounds']:.0f} "
              f"ppl={s['perplexity']:.1f} "
              f"carbon={s['carbon_total_kg']*1000:.2f} gCO2e "
              f"(wall {time.time()-t0:.0f}s)")
        if args.json:
            with open(args.json, "w") as f:
                json.dump(s, f, indent=1)
        return 0

    spec = ExperimentSpec.load(args.spec) if args.spec else \
        spec_from_args(args)
    if args.save_spec:
        spec.save(args.save_spec)
        print(f"[train] spec -> {args.save_spec}")
        return 0

    exp = Experiment(spec)
    if spec.learner == "real":
        print(f"[train] initial perplexity "
              f"{exp.build_learner().eval_perplexity():.1f}")
    t0 = time.time()
    res = exp.run(checkpoint_path=args.checkpoint or None,
                  checkpoint_every_rounds=args.checkpoint_every
                  if args.checkpoint else 0)
    s = res.summary()
    arch = spec.model.arch or exp.model_config.name
    print(f"[train] {arch} {spec.federated.mode} rounds={s['rounds']:.0f} "
          f"ppl={s['perplexity']:.1f} simulated={s['duration_h']:.2f}h "
          f"carbon={s['carbon_total_kg']*1000:.2f} gCO2e "
          f"(wall {time.time()-t0:.0f}s)")
    print(f"[train] carbon shares: "
          + " ".join(f"{k}={v:.2f}" for k, v in res.carbon.shares().items()))
    if args.ckpt and spec.learner == "real":
        save_checkpoint(args.ckpt, {"params": exp.learner.params},
                        meta={"rounds": res.rounds, "arch": arch})
        print(f"[train] checkpoint -> {args.ckpt}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(s, f, indent=1)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
