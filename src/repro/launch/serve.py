"""Serving driver: batched prefill + autoregressive decode on any model-zoo
architecture (reduced configs run for real on CPU; full configs belong to
the dry-run). Demonstrates the framework's serving path — the same
decode_step the decode_32k / long_500k dry-run shapes lower.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \\
      --batch 4 --prompt-len 12 --gen 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ModelRef
from repro.configs import get_config
from repro.models import get_model


def serve_model_ref(arch: str, reduced: bool) -> ModelRef:
    """Declarative model reference for the serving path (repro.api)."""
    if not reduced:
        return ModelRef(arch=arch)
    family = get_config(arch).family
    overrides = dict(lstm_hidden=256, max_context=16) \
        if family == "charlm" else {}
    return ModelRef(arch=arch, reduced=True,
                    reduced_kw=dict(layers=3 if family == "hybrid" else 2),
                    overrides=overrides)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--reduced", action="store_true", default=True)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=12)
    p.add_argument("--gen", type=int, default=8)
    p.add_argument("--greedy", action="store_true")
    args = p.parse_args(argv)

    cfg = serve_model_ref(args.arch, args.reduced).resolve()
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params, _ = model.init(rng)
    B, S = args.batch, args.prompt_len
    toks = jax.random.randint(rng, (B, S), 0, cfg.vocab_size)

    kwargs = {}
    if cfg.family in ("vlm", "audio"):
        kwargs["frontend"] = jax.random.normal(
            rng, (B, cfg.num_frontend_tokens, cfg.d_model), jnp.float32)
    t0 = time.time()
    if cfg.family == "charlm":
        chars = jax.random.randint(rng, (B, S, cfg.max_word_len), 0,
                                   cfg.char_vocab)
        lg, cache = model.prefill(params, toks, chars=chars)
    else:
        lg, cache = model.prefill(params, toks, pad_to=S + args.gen, **kwargs)
    print(f"[serve] prefill B={B} S={S}: {time.time()-t0:.2f}s "
          f"logits {lg.shape}")

    step = jax.jit(model.decode_step)
    out = []
    t0 = time.time()
    for i in range(args.gen):
        nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out.append(np.asarray(nxt))
        if cfg.family == "charlm":
            step_in = chars[:, -1]  # charlm decodes word-by-word via chars
        else:
            step_in = nxt
        lg, cache = step(params, cache, step_in)
    dt = time.time() - t0
    toks_out = np.stack(out, axis=1)
    print(f"[serve] decoded {args.gen} tokens/seq in {dt:.2f}s "
          f"({B*args.gen/dt:.1f} tok/s); sample: {toks_out[0][:8]}")
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
