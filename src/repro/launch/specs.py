"""Per-(arch x shape) dry-run program construction (MULTI-POD DRY-RUN §2-3).

For each assigned architecture and input shape this module builds:
  * the step function — a FEDERATED ROUND for train shapes (the paper's
    technique at datacenter scale: K cross-silo clients scanned, each running
    `local_steps` of local SGD from the broadcast server params, deltas
    accumulated sharded and applied through the FedAdam server optimizer),
    or prefill / single-token decode for serving shapes;
  * ``input_specs()`` — ShapeDtypeStruct stand-ins for every input
    (weak-type-correct, shardable, no device allocation);
  * in/out shardings derived from the models' logical param axes.

long_500k uses each family's sub-quadratic decode state: native recurrent
state (rwkv6), RG-LRU + SWA ring (recurrentgemma), arch SWA ring (mixtral),
and a window-4096 ring-buffer variant for the full-attention decoders.
seamless-m4t skips long_500k (DESIGN.md §4).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import sharding as sh
from repro.configs.base import INPUT_SHAPES, ModelConfig, ShapeConfig
from repro.models import get_model, param_shapes_and_axes
from repro.optim import adam

# federated round structure lowered for train shapes
DRYRUN_CLIENTS = 4          # silo clients per round (scan)
DRYRUN_LOCAL_STEPS = 2      # local SGD steps per client
DRYRUN_CLIENT_LR = 0.1
LONG_DECODE_WINDOW = 4096   # SWA ring for full-attention archs at 500k

SKIPS: Dict[Tuple[str, str], str] = {
    ("seamless-m4t-medium", "long_500k"):
        "enc-dec speech-to-text has no 500k-token autoregressive decode "
        "regime (decoder is full-attention over a short encoder memory)",
}


@dataclass
class DryRunProgram:
    arch: str
    shape: str
    step_fn: Callable
    input_specs: Dict[str, Any]          # kwargs of ShapeDtypeStructs
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def _batch_sds(cfg: ModelConfig, shape: ShapeConfig, n_clients: int):
    """Train-round batch: leaves (K, Bc, ...)."""
    Bc = shape.global_batch // n_clients
    S = shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((n_clients, Bc, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n_clients, Bc, S), jnp.int32),
    }
    if cfg.num_frontend_tokens:
        batch["frontend"] = jax.ShapeDtypeStruct(
            (n_clients, Bc, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "charlm":
        batch["chars"] = jax.ShapeDtypeStruct(
            (n_clients, Bc, S, cfg.max_word_len), jnp.int32)
    return batch


def _batch_specs(batch, mesh):
    out = {}
    for k, v in batch.items():
        out[k] = sh.batch_spec(mesh, v.ndim, batch_dim=1, shape=v.shape)
    return out


def model_for(cfg: ModelConfig, shape: ShapeConfig, *, remat: bool = True):
    if shape.name == "long_500k":
        return get_model(cfg, decode_window=cfg.sliding_window
                         or LONG_DECODE_WINDOW, remat=remat)
    return get_model(cfg, remat=remat)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_train_round(cfg: ModelConfig, *, n_clients: int = DRYRUN_CLIENTS,
                     local_steps: int = DRYRUN_LOCAL_STEPS,
                     client_lr: float = DRYRUN_CLIENT_LR,
                     server_lr: float = 1e-3):
    """One synchronous federated round as a single SPMD program."""
    model = get_model(cfg, remat=True)
    opt = adam(server_lr)

    def loss_fn(p, b):
        return model.loss(p, b)[0]

    def train_round(params, opt_state, batch):
        def client_fn(acc, client_batch):
            def local(p, _):
                g = jax.grad(loss_fn)(p, client_batch)
                p = {k: (p[k] - client_lr * g[k].astype(jnp.float32)
                         ).astype(p[k].dtype) for k in p}
                return p, None

            p_fin, _ = lax.scan(local, params, None, length=local_steps)
            acc = {k: acc[k] + (p_fin[k].astype(jnp.float32)
                                - params[k].astype(jnp.float32))
                   for k in acc}
            return acc, None

        acc0 = {k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()}
        acc, _ = lax.scan(client_fn, acc0, batch)
        grads = {k: -(v / n_clients) for k, v in acc.items()}
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state

    return train_round, opt


def make_train_round_vmapped(cfg: ModelConfig, *, n_clients: int,
                             local_steps: int = DRYRUN_LOCAL_STEPS,
                             client_lr: float = DRYRUN_CLIENT_LR,
                             server_lr: float = 1e-3):
    """Cross-device variant: the whole cohort trains in parallel via vmap
    (per-client param replicas on the data axis) — the faithful simulation
    mode for phone-sized models (smollm / charlm)."""
    model = get_model(cfg, remat=True)
    opt = adam(server_lr)

    def loss_fn(p, b):
        return model.loss(p, b)[0]

    def client_fn(params, cbatch):
        def local(p, _):
            g = jax.grad(loss_fn)(p, cbatch)
            p = {k: (p[k] - client_lr * g[k].astype(jnp.float32)
                     ).astype(p[k].dtype) for k in p}
            return p, None

        p_fin, _ = lax.scan(local, params, None, length=local_steps)
        return {k: p_fin[k].astype(jnp.float32) - params[k].astype(jnp.float32)
                for k in params}

    def train_round(params, opt_state, batch):
        deltas = jax.vmap(client_fn, in_axes=(None, 0))(params, batch)
        grads = {k: -jnp.mean(v, axis=0) for k, v in deltas.items()}
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state

    return train_round, opt


def make_prefill(cfg: ModelConfig, shape: ShapeConfig):
    model = model_for(cfg, shape, remat=False)

    if cfg.num_frontend_tokens:
        def prefill(params, tokens, frontend):
            return model.prefill(params, tokens, frontend)
    else:
        def prefill(params, tokens):
            return model.prefill(params, tokens)
    return prefill, model


def make_decode(cfg: ModelConfig, shape: ShapeConfig):
    model = model_for(cfg, shape, remat=False)

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    return serve_step, model


# ---------------------------------------------------------------------------
# program assembly
# ---------------------------------------------------------------------------

def build_program(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                  rules=None, variant: str = "") -> DryRunProgram:
    """variant: "" (baseline) | "flash_decode" (§Perf: shard_map
    flash-decoding + decode-consumable prefill cache) | "vmap_clients"
    (cross-device simulation: vmapped cohort, per-client replicas on the
    data axis — small models only)."""
    shape = INPUT_SHAPES[shape_name]
    key = (cfg.name, shape_name)
    if key in SKIPS:
        raise ValueError(f"skip {key}: {SKIPS[key]}")
    if rules is None:
        # decode keeps weights resident (2D-sharded), train/prefill use
        # FSDP+TP rules — see sharding.SERVE_RULES rationale.
        rules = sh.SERVE_RULES if shape.kind == "decode" else sh.DEFAULT_RULES
    if variant in ("flash_decode", "flash_decode_q8") \
            and INPUT_SHAPES[shape_name].kind != "train":
        # the cache-length sharding only helps models that actually run the
        # shard_map flash-decode path (DecoderLM); ring-window hybrids and
        # recurrent states keep the plain serve rules.
        probe = model_for(cfg, INPUT_SHAPES[shape_name], remat=False)
        if hasattr(probe, "flash_decode"):
            rules = dict(rules)
            rules["cache"] = ("model",)
        else:
            variant = ''

    pshapes, paxes = param_shapes_and_axes(cfg)
    pspecs = sh.tree_specs(paxes, pshapes, mesh, rules)
    pshard = {k: NamedSharding(mesh, s) for k, s in pspecs.items()}

    if shape.kind == "train":
        if variant == "vmap_clients":
            # cross-device mode: 16 parallel clients on the data axis
            rules = sh.XDEVICE_RULES
            pspecs2 = sh.tree_specs(paxes, pshapes, mesh, rules)
            pshard = {k: NamedSharding(mesh, s) for k, s in pspecs2.items()}
            n_clients = mesh.shape.get("data", 16)
            step, opt = make_train_round_vmapped(cfg, n_clients=n_clients)
        else:
            n_clients = DRYRUN_CLIENTS
            step, opt = make_train_round(cfg)
        ostate_shapes = jax.eval_shape(opt.init, pshapes)
        ospec = {
            "step": NamedSharding(mesh, P()),
            "m": pshard, "v": pshard,
        }
        batch = _batch_sds(cfg, shape, n_clients)
        if variant == "vmap_clients":
            bspecs = {k: NamedSharding(mesh, P("data"))
                      for k in batch}
        else:
            bspecs = {k: NamedSharding(mesh, s)
                      for k, s in _batch_specs(batch, mesh).items()}
        inputs = {
            "params": pshapes,
            "opt_state": ostate_shapes,
            "batch": batch,
        }
        in_sh = (pshard, ospec, bspecs)
        out_sh = (pshard, ospec)
        return DryRunProgram(cfg.name, shape_name, step, inputs, in_sh, out_sh,
                             donate_argnums=(0, 1))

    model = model_for(cfg, shape, remat=False)
    B, S = shape.global_batch, shape.seq_len
    bspec = sh.batch_spec(mesh, 1, batch_dim=0, shape=(B,))
    baxes = bspec[0] if len(bspec) else None

    vspec = vocab_logit_spec(cfg, mesh)

    if shape.kind == "prefill":
        step, model = make_prefill(cfg, shape)
        tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
        tok_spec = NamedSharding(mesh, P(baxes, None))
        inputs = {"params": pshapes, "tokens": tokens}
        in_sh = [pshard, tok_spec]
        cache_rules = rules
        if variant in ("flash_decode", "flash_decode_q8"):
            # §Perf H2.1: land the prefill cache in the decode-consumable
            # sharding (length over "model") — kills the 2x6GB f32 output
            # all-gathers that dominate the baseline's collective term.
            cache_rules = dict(sh.SERVE_RULES)
            cache_rules["cache"] = ("model",)
        out_sh = (NamedSharding(mesh, P(baxes, vspec)),
                  _cache_shardings(model, cfg, B, S, mesh, cache_rules))
        if cfg.num_frontend_tokens:
            inputs["frontend"] = jax.ShapeDtypeStruct(
                (B, cfg.num_frontend_tokens, cfg.d_model), jnp.bfloat16)
            in_sh.append(NamedSharding(mesh, P(baxes, None, None)))
        return DryRunProgram(cfg.name, shape_name, step, inputs,
                             tuple(in_sh), out_sh)

    # decode
    step, model = make_decode(cfg, shape)
    if variant in ("flash_decode", "flash_decode_q8") \
            and hasattr(model, "flash_decode"):
        model.flash_decode = True
        if variant == "flash_decode_q8":
            model.kv_quant = True
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, S, dtype=jnp.bfloat16)[0])
    cache_sh = _cache_shardings(model, cfg, B, S, mesh, rules)
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    inputs = {"params": pshapes, "cache": cache_sds, "tokens": tokens}
    in_sh = (pshard, cache_sh, NamedSharding(mesh, P(baxes)))
    out_sh = (NamedSharding(mesh, P(baxes, vspec)), cache_sh)
    return DryRunProgram(cfg.name, shape_name, step, inputs, in_sh, out_sh,
                         donate_argnums=(1,))


def _cache_shardings(model, cfg: ModelConfig, B: int, S: int, mesh: Mesh,
                     rules):
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(B, S, dtype=jnp.bfloat16)[0])
    # shapes from eval_shape; logical axes from the (tiny) concrete builder
    _, cache_axes = model.init_cache(1, 8, dtype=jnp.bfloat16)
    out = {}
    for k, sds in cache_sds.items():
        spec = sh.spec_for(cache_axes[k], sds.shape, mesh, rules)
        out[k] = NamedSharding(mesh, spec)
    return out


def vocab_logit_spec(cfg: ModelConfig, mesh: Mesh) -> Optional[str]:
    return "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
