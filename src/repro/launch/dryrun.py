import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (MULTI-POD DRY-RUN §0-4).

For every (assigned architecture x input shape) pair, lower + compile the
step program on the production mesh — (16,16)=("data","model") single pod
and (2,16,16)=("pod","data","model") for two pods — with ShapeDtypeStruct
inputs (no allocation), then report memory_analysis / cost_analysis /
collective bytes for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import INPUT_SHAPES, get_config
from repro.configs.registry import ASSIGNED_ARCHS
from repro.launch import analysis
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SKIPS, build_program
from repro.models import step_flops


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               verbose: bool = True, rules=None, save_hlo: str = "",
               variant: str = ""):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    key = (arch, shape_name)
    if key in SKIPS:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": SKIPS[key]}
    t0 = time.time()
    prog = build_program(cfg, shape_name, mesh, rules=rules, variant=variant)
    with mesh:
        jitted = jax.jit(prog.step_fn,
                         in_shardings=prog.in_shardings,
                         out_shardings=prog.out_shardings,
                         donate_argnums=prog.donate_argnums)
        lowered = jitted.lower(*prog.input_specs.values())
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    flops_dev, bytes_dev = analysis.extract_cost(compiled)
    peak = analysis.extract_peak_memory(compiled)
    hlo = compiled.as_text()
    coll = analysis.collective_stats(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    shape = INPUT_SHAPES[shape_name]
    model_flops = step_flops(cfg, shape.global_batch, shape.seq_len,
                             shape.kind)
    if shape.kind == "train":
        # dry-run round: K clients x local_steps fwd+bwd on the same batch
        from repro.launch.specs import DRYRUN_LOCAL_STEPS
        model_flops = model_flops * DRYRUN_LOCAL_STEPS

    roof = analysis.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops_per_dev=flops_dev, model_flops_global=model_flops,
        bytes_per_dev=bytes_dev, collective_bytes_per_dev=coll["total"],
        peak_mem_per_dev=peak)
    row = roof.row()
    row.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1),
               coll_ops=int(coll.get("n_ops", 0)),
               coll_by_kind={k: v for k, v in coll.items()
                             if k not in ("total", "n_ops")})
    if verbose:
        mem = compiled.memory_analysis()
        print(f"== {arch} x {shape_name} on {mesh_name} "
              f"({chips} chips) ==")
        print(f"   memory_analysis: {mem}")
        print(f"   cost: flops/dev={flops_dev:.3e} bytes/dev={bytes_dev:.3e}")
        print(f"   collectives/dev: {coll['total']:.3e} B over "
              f"{int(coll.get('n_ops', 0))} ops")
        print(f"   roofline: compute={roof.compute_s*1e3:.2f}ms "
              f"memory={roof.memory_s*1e3:.2f}ms "
              f"collective={roof.collective_s*1e3:.2f}ms "
              f"-> dominant={roof.dominant}")
    return row


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    p.add_argument("--all", action="store_true")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--variant", default="",
                   help='"" (baseline) | "flash_decode" (§Perf optimized '
                        'serving: shard_map flash-decoding + decode-'
                        'consumable prefill cache)')
    p.add_argument("--json", default="")
    args = p.parse_args(argv)

    pairs = []
    archs = list(ASSIGNED_ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or not args.shape) else [args.shape]
    for a in archs:
        for s in shapes:
            pairs.append((a, s))

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    rows = []
    for mp in meshes:
        for a, s in pairs:
            try:
                rows.append(dryrun_one(a, s, multi_pod=mp,
                                       variant=args.variant))
            except Exception as e:
                traceback.print_exc()
                rows.append({"arch": a, "shape": s,
                             "mesh": "2x16x16" if mp else "16x16",
                             "status": "FAILED", "error": repr(e)[:500]})
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1, default=str)
    n_fail = sum(1 for r in rows if r.get("status") == "FAILED")
    print(f"\n{len(rows) - n_fail}/{len(rows)} dry-runs OK")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
