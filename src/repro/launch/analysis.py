"""Compiled-artifact analysis: collective bytes, roofline terms (§ROOFLINE).

collective_bytes parses the post-SPMD HLO (compiled.as_text()) and sums the
RESULT sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (per-device bytes moved per op invocation). Ops inside
while-loop bodies are multiplied by the loop trip count when it is statically
recoverable from the HLO (scan layers/blocks would otherwise be undercounted);
the trip-count map is produced alongside.

Roofline terms (per device, seconds):
  compute    = flops / PEAK_FLOPS_BF16
  memory     = bytes_accessed / HBM_BW
  collective = collective_bytes / ICI_BW

cost_analysis() counts a while body ONCE; `scan_correction` rescales with
analytic model FLOPs (repro.models.step_flops) so the compute term reflects
the real trip counts (DESIGN.md §5).
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?:[a-z0-9]+)\[[^\]]*\][^ ]*)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_WHILE_TRIP_RE = re.compile(
    r"while\(.*?trip_count[^0-9]*(\d+)", re.DOTALL)


def _line_result_bytes(line: str) -> float:
    """Sum byte sizes of the result shapes on an HLO op line (LHS of '=')."""
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split("(", 1)[0]
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(lhs):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, float]:
    """Per-device collective bytes by op kind (single invocation of the
    program; while-body collectives are scaled by trip count when present
    in backend_config/metadata)."""
    out: Dict[str, float] = {}
    counts: Dict[str, int] = {}
    # map computation name -> trip count when known
    trip: Dict[str, int] = {}
    for m in re.finditer(
            r'body=%?([\w.\-]+).*?"known_trip_count":\{"n":"(\d+)"\}',
            hlo_text):
        trip[m.group(1)] = int(m.group(2))
    # which computation each line belongs to
    current_comp = ""
    comp_mult: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        ls = line.strip()
        mdef = re.match(r"%?([\w.\-]+)\s*\([\w.,%: \[\]\-]*\)\s*->", ls)
        if (ls.startswith("ENTRY") or mdef) and "{" in ls:
            name = ls.split()[1].lstrip("%").split("(")[0].split(".")[0] \
                if not ls.startswith("ENTRY") else "__entry__"
            current_comp = ls.split("{")[0].strip()
        m = _COLL_RE.search(ls)
        if m:
            kind = m.group(1)
            b = _line_result_bytes(ls)
            mult = 1
            for body_name, n in trip.items():
                if body_name in current_comp:
                    mult = n
                    break
            out[kind] = out.get(kind, 0.0) + b * mult
            counts[kind] = counts.get(kind, 0) + 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["n_ops"] = float(sum(counts.values()))
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_dev: float
    model_flops_global: float       # analytic (exact-schedule) whole step
    bytes_per_dev: float
    collective_bytes_per_dev: float
    peak_mem_per_dev: Optional[float]

    @property
    def compute_s(self) -> float:
        # analytic global flops spread over chips (scan-corrected)
        return self.model_flops_global / self.chips / PEAK_FLOPS_BF16

    @property
    def compute_s_hlo(self) -> float:
        return self.hlo_flops_per_dev / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes_per_dev / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs(global): >1 means the while-once HLO
        undercount dominates; <1 means remat/redundant compute."""
        hlo_global = self.hlo_flops_per_dev * self.chips
        return self.model_flops_global / max(hlo_global, 1.0)

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "hlo_flops_per_dev": self.hlo_flops_per_dev,
            "model_flops_global": self.model_flops_global,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.collective_bytes_per_dev,
            "useful_ratio": self.useful_flops_ratio,
            "peak_mem_gb": (self.peak_mem_per_dev or 0) / 2**30,
        }


def extract_cost(compiled) -> Tuple[float, float]:
    """(flops, bytes accessed) from compiled.cost_analysis(), per device."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    return flops, bytes_acc


def extract_peak_memory(compiled) -> Optional[float]:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    for attr in ("temp_size_in_bytes",):
        if hasattr(ma, attr):
            try:
                return float(ma.argument_size_in_bytes
                             + ma.output_size_in_bytes
                             + ma.temp_size_in_bytes)
            except Exception:
                return None
    return None
