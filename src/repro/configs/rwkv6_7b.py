"""RWKV-6 (Finch) 7B. [arXiv:2404.05892]

Attention-free SSM: 32L, d_model=4096, d_ff=14336 (channel-mix), vocab=65536,
data-dependent decay, token-shift. Constant-size recurrent decode state.
"""
from repro.configs.base import ModelConfig, SSM

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family=SSM,
    num_layers=32,
    d_model=4096,
    num_heads=64,      # WKV head count (head_dim=64); attention-free
    num_kv_heads=0,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    max_context=1 << 20,   # unbounded in principle (recurrent)
    citation="arXiv:2404.05892",
)
