"""RecurrentGemma-2B (Griffin). [arXiv:2402.19427]

Hybrid: RG-LRU recurrent blocks + local (sliding-window 2048) attention in a
2:1 pattern, 26L, d_model=2560, 10 heads (GQA kv=1), d_ff=7680, vocab=256000.
Sub-quadratic: native long_500k citizen.
"""
from repro.configs.base import ModelConfig, HYBRID

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family=HYBRID,
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    sliding_window=2048,
    block_pattern=("recurrent", "recurrent", "local_attn"),
    lru_width=2560,
    max_context=1 << 20,
    tie_embeddings=True,
    citation="arXiv:2402.19427",
)
