"""Config system: model architectures, input shapes, federated/run configs.

Every assigned architecture gets one module in this package defining a
``ModelConfig`` named ``CONFIG`` (full size, cited) plus ``reduced()`` for
CPU smoke tests. ``repro.configs.registry`` resolves ``--arch <id>``.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------

DENSE = "dense"
MOE = "moe"
SSM = "ssm"          # RWKV6
HYBRID = "hybrid"    # RecurrentGemma (RG-LRU + local attention)
VLM = "vlm"          # vision frontend stub + dense LM
AUDIO = "audio"      # audio frontend stub + encoder-decoder
CHARLM = "charlm"    # the paper's char-aware CNN-LSTM LM

FAMILIES = (DENSE, MOE, SSM, HYBRID, VLM, AUDIO, CHARLM)


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # router aux loss weight (load-balance loss, Switch-style)
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description. Dimensions follow the assignment block."""

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int          # 0 for attention-free (rwkv)
    num_kv_heads: int       # GQA kv heads (== num_heads for MHA; 0 for rwkv)
    d_ff: int
    vocab_size: int
    citation: str = ""
    # --- optional / family-specific ---
    head_dim: int = 0                      # 0 -> d_model // num_heads
    max_context: int = 131072
    moe: Optional[MoEConfig] = None
    sliding_window: int = 0                # 0 = full attention; >0 = SWA width
    # hybrid (recurrentgemma): pattern of block kinds, tiled over layers
    block_pattern: Tuple[str, ...] = ()    # e.g. ("recurrent","recurrent","local_attn")
    lru_width: int = 0                     # RG-LRU recurrence width (0 -> d_model)
    # enc-dec (seamless)
    encoder_layers: int = 0                # >0 => encoder-decoder
    # frontend stubs (vlm/audio): number of precomputed embedding tokens
    num_frontend_tokens: int = 0
    # charlm specifics
    char_vocab: int = 0
    char_emb: int = 0
    cnn_filters: Tuple[Tuple[int, int], ...] = ()   # (kernel_width, n_filters)
    lstm_hidden: int = 0
    max_word_len: int = 0
    tie_embeddings: bool = False
    # numerics
    dtype: str = "bfloat16"
    # rope
    rope_theta: float = 10000.0

    def __post_init__(self):
        assert self.family in FAMILIES, self.family

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == SSM

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for hybrid models ('' pattern => uniform)."""
        if not self.block_pattern:
            return ()
        reps = math.ceil(self.num_layers / len(self.block_pattern))
        return tuple((self.block_pattern * reps)[: self.num_layers])

    # -- parameter / FLOP accounting (feeds the Green-FL energy model) ------
    def param_count(self) -> int:
        from repro.models import registry as _m  # lazy, avoids cycle
        return _m.param_count(self)

    def active_param_count(self) -> int:
        from repro.models import registry as _m
        return _m.param_count(self, active_only=True)

    def train_flops_per_token(self) -> float:
        """~6*N(active) per token (fwd+bwd)."""
        return 6.0 * self.active_param_count()

    def decode_flops_per_token(self) -> float:
        return 2.0 * self.active_param_count()


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Federated / green configs (the paper's Table 1 hyperparameter space)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FederatedConfig:
    # "sync" (FedAvg) | "async" (FedBuff) | "carbon-aware" (FedBuff with
    # grid-intensity-biased cohort selection, CAFE-style time/geo shifting)
    mode: str = "sync"
    concurrency: int = 100              # users training simultaneously
    aggregation_goal: int = 80          # min client responses before update
    local_epochs: int = 1
    client_batch_size: int = 16
    client_lr: float = 0.1
    server_lr: float = 0.01
    server_optimizer: str = "adam"      # FedAdam (paper) | "sgd" | "momentum"
    adam_beta1: float = 0.9
    adam_beta2: float = 0.999
    adam_eps: float = 1e-8
    staleness_cap: int = 16             # FedBuff max tracked staleness
    staleness_exponent: float = 0.5     # update *= (1+staleness)^-exp (FedBuff)
    client_timeout_s: float = 240.0     # the paper's 4-minute timeout
    dropout_rate: float = 0.05          # mid-round client dropout probability
    over_selection: float = 1.0         # sync: selected = goal * over_selection
    seed: int = 0
    # update compression on the wire (paper §6 / Prasad et al.)
    compression: str = "none"           # "none" | "int8"
    quant_block: int = 256
    # carbon-aware selection (mode="carbon-aware"): dispatch is biased
    # toward the `carbon_topk` lowest-intensity countries at the current
    # clock; `carbon_explore` is the exploration floor — the probability a
    # dispatch skips the filter entirely, keeping every country in the
    # cohort mix (honest convergence stats, no starved regions)
    carbon_topk: int = 6
    carbon_explore: float = 0.1
    # recovery policy (pairs with Environment.fault): a session that ends
    # "failed" re-dispatches its slot up to `retry_limit` times, each wave
    # delayed by retry_backoff_s * 2**attempt (exponential backoff); every
    # attempt is charged. Sync rounds degrade gracefully: a round whose
    # completers fall below ceil(min_report_fraction * aggregation_goal)
    # is `starved` (no server update), and `starvation_patience`
    # consecutive starved rounds abort the task (0 = never abort).
    retry_limit: int = 0
    retry_backoff_s: float = 30.0
    min_report_fraction: float = 0.0
    starvation_patience: int = 0
    # availability recovery (pairs with Environment.availability): an
    # interrupted session keeps the local steps it checkpointed every
    # `checkpoint_period_s` of compute (0 = no checkpointing, everything
    # is lost), and its retry redoes only the remainder. Sync rounds may
    # over-select — dispatch ceil((1 + over_select_fraction) * goal)
    # clients, close on the goal-th completer, surplus relabeled
    # "cancelled" and charged as wasted (the paper's over-commitment).
    checkpoint_period_s: float = 0.0
    over_select_fraction: float = 0.0

    def __post_init__(self):
        if self.mode not in ("sync", "async", "carbon-aware"):
            raise ValueError(f"unknown federated mode {self.mode!r}; "
                             "known: 'sync', 'async', 'carbon-aware'")
        if self.concurrency < 1:
            raise ValueError(
                f"concurrency must be >= 1, got {self.concurrency!r}")
        if self.aggregation_goal < 1:
            raise ValueError(f"aggregation_goal must be >= 1, got "
                             f"{self.aggregation_goal!r}")
        if self.aggregation_goal > self.concurrency:
            raise ValueError(
                f"aggregation_goal ({self.aggregation_goal}) cannot exceed "
                f"concurrency ({self.concurrency})")
        if not 0.0 <= self.dropout_rate <= 1.0:
            raise ValueError("dropout_rate must be a probability in "
                             f"[0, 1], got {self.dropout_rate!r}")
        if self.client_timeout_s <= 0:
            raise ValueError(f"client_timeout_s must be > 0, got "
                             f"{self.client_timeout_s!r}")
        if self.carbon_topk < 1:
            raise ValueError(
                f"carbon_topk must be >= 1, got {self.carbon_topk!r}")
        if not 0.0 <= self.carbon_explore <= 1.0:
            raise ValueError("carbon_explore must be a probability in "
                             f"[0, 1], got {self.carbon_explore!r}")
        if self.retry_limit < 0:
            raise ValueError(
                f"retry_limit must be >= 0, got {self.retry_limit!r}")
        if self.retry_backoff_s < 0:
            raise ValueError(f"retry_backoff_s must be >= 0, got "
                             f"{self.retry_backoff_s!r}")
        if not 0.0 <= self.min_report_fraction <= 1.0:
            raise ValueError("min_report_fraction must be in [0, 1], got "
                             f"{self.min_report_fraction!r}")
        if self.starvation_patience < 0:
            raise ValueError(f"starvation_patience must be >= 0, got "
                             f"{self.starvation_patience!r}")
        if not (math.isfinite(self.checkpoint_period_s)
                and self.checkpoint_period_s >= 0):
            raise ValueError(f"checkpoint_period_s must be finite and >= 0, "
                             f"got {self.checkpoint_period_s!r}")
        if not (math.isfinite(self.over_select_fraction)
                and self.over_select_fraction >= 0):
            raise ValueError(f"over_select_fraction must be finite and >= 0, "
                             f"got {self.over_select_fraction!r}")


@dataclass(frozen=True)
class RunConfig:
    """Stopping criteria per paper §3.2 + telemetry memory model."""
    target_perplexity: float = 175.0
    patience_rounds: int = 5            # target held for 5 consecutive rounds
    max_hours: float = 48.0
    max_rounds: int = 10_000
    eval_every: int = 1
    eval_clients: int = 20              # paper: 20 held-out clients
    ema_alpha: float = 0.3              # paper's EWMA smoothing of test ppl
    # telemetry memory model: "full" materializes every session as columns;
    # "streaming" folds sessions into constant-memory exact running sums
    # (carbon/energy/bytes/counters — summaries stay bit-for-bit) and keeps
    # only a seed-deterministic reservoir of `telemetry_sample` session rows
    # for the figs (population-scale tasks: 10^8 sessions in O(sample) RAM)
    telemetry: str = "full"             # "full" | "streaming"
    telemetry_sample: int = 4096        # reservoir size (streaming mode)

    def __post_init__(self):
        assert self.telemetry in ("full", "streaming")
        assert self.telemetry_sample > 0


# ---------------------------------------------------------------------------
# (De)serialization — ModelConfig as a JSON-safe dict (repro.api specs)
# ---------------------------------------------------------------------------

def normalize_model_kwargs(d: dict) -> dict:
    """JSON round-trips turn tuples into lists and MoEConfig into a dict;
    convert the affected ModelConfig fields back (no-op when absent)."""
    d = dict(d)
    if isinstance(d.get("moe"), dict):
        d["moe"] = MoEConfig(**d["moe"])
    if "block_pattern" in d:
        d["block_pattern"] = tuple(d["block_pattern"])
    if "cnn_filters" in d:
        d["cnn_filters"] = tuple(tuple(f) for f in d["cnn_filters"])
    return d


def model_config_to_dict(cfg: ModelConfig) -> dict:
    return dataclasses.asdict(cfg)


def model_config_from_dict(d: dict) -> ModelConfig:
    return ModelConfig(**normalize_model_kwargs(d))


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 256,
            heads: int = 4, kv_heads: int = 0, d_ff: int = 512,
            vocab: int = 512, experts: int = 4) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (<=4 experts, d<=512)."""
    kv = kv_heads or max(1, heads // 2)
    changes = dict(
        num_layers=layers,
        d_model=d_model,
        num_heads=0 if cfg.family == SSM else heads,
        num_kv_heads=0 if cfg.family == SSM else kv,
        d_ff=d_ff,
        vocab_size=vocab,
        max_context=2048,
    )
    if cfg.moe is not None:
        changes["moe"] = MoEConfig(num_experts=min(experts, cfg.moe.num_experts),
                                   top_k=min(2, cfg.moe.top_k))
    if cfg.sliding_window:
        changes["sliding_window"] = 64
    if cfg.block_pattern:
        changes["block_pattern"] = cfg.block_pattern
    if cfg.lru_width:
        changes["lru_width"] = d_model
    if cfg.encoder_layers:
        changes["encoder_layers"] = 2
    if cfg.num_frontend_tokens:
        changes["num_frontend_tokens"] = 16
    if cfg.family == CHARLM:
        changes.update(num_heads=0, num_kv_heads=0, char_vocab=64, char_emb=16,
                       cnn_filters=((2, 16), (3, 16)), lstm_hidden=d_model,
                       max_word_len=12)
    return dataclasses.replace(cfg, name=cfg.name + "-reduced", **changes)
