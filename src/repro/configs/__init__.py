from repro.configs.base import (
    FederatedConfig, ModelConfig, MoEConfig, RunConfig, ShapeConfig,
    INPUT_SHAPES, model_config_from_dict, model_config_to_dict,
    normalize_model_kwargs, reduced,
)
from repro.configs.registry import ALL_ARCHS, ASSIGNED_ARCHS, all_configs, get_config
