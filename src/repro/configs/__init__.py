from repro.configs.base import (
    FederatedConfig, ModelConfig, MoEConfig, RunConfig, ShapeConfig,
    INPUT_SHAPES, reduced,
)
from repro.configs.registry import ALL_ARCHS, ASSIGNED_ARCHS, all_configs, get_config
