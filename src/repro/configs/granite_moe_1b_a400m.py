"""Granite-3.0-1B-A400M. [hf:ibm-granite/granite-3.0-1b-a400m-base]

MoE decoder: 24L, d_model=1024, 16 heads (GQA kv=8), expert d_ff=512,
vocab=49155, 32 experts top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig, MOE

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family=MOE,
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    moe=MoEConfig(num_experts=32, top_k=8),
    max_context=4096,
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
