"""SeamlessM4T-medium text backbone. [arXiv:2308.11596]

Encoder-decoder: 12L encoder + 12L decoder, d_model=1024, 16 heads (MHA),
d_ff=4096, vocab=256206. The speech frontend (mel + conformer feature
extractor) is a STUB: input_specs provides precomputed frame embeddings.
No long_500k decode (enc-dec speech-to-text has no 500k-token decode regime).
"""
from repro.configs.base import ModelConfig, AUDIO

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family=AUDIO,
    num_layers=12,            # decoder layers
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256206,
    num_frontend_tokens=1024,  # precomputed audio frame embeddings
    max_context=4096,
    citation="arXiv:2308.11596",
)
