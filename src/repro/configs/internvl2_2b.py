"""InternVL2-2B language backbone (InternLM2-1.8B arch). [arXiv:2404.16821]

VLM: InternViT vision frontend is a STUB (precomputed patch embeddings via
input_specs); this config is the 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 decoder that consumes interleaved visual+text tokens.
"""
from repro.configs.base import ModelConfig, VLM

CONFIG = ModelConfig(
    name="internvl2-2b",
    family=VLM,
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    num_frontend_tokens=256,   # one ViT tile -> 256 visual tokens
    max_context=32768,
    citation="arXiv:2404.16821",
)
