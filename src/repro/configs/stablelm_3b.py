"""StableLM-2 ~3B-class config. [hf:stabilityai/stablelm-2-1_6b family]

Dense decoder: 32L, d_model=2560, 32 heads (kv=32, MHA), d_ff=6912,
vocab=50304.
"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="stablelm-3b",
    family=DENSE,
    num_layers=32,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=6912,
    vocab_size=50304,
    max_context=4096,
    citation="hf:stabilityai/stablelm-2-1_6b",
)
