"""Mistral-Nemo-12B base. [hf:mistralai/Mistral-Nemo-Base-2407]

Dense decoder, 40L, d_model=5120, 32 heads (GQA kv=8, head_dim=128),
d_ff=14336, vocab=131072, 128k context.
"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family=DENSE,
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    max_context=131072,
    rope_theta=1e6,
    citation="hf:mistralai/Mistral-Nemo-Base-2407",
)
