"""StableLM-2-1.6B. [hf:stabilityai/stablelm-2-1_6b]

Dense decoder: 24L, d_model=2048, 32 heads (kv=32, MHA), d_ff=5632,
vocab=100352.
"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family=DENSE,
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100352,
    max_context=4096,
    citation="hf:stabilityai/stablelm-2-1_6b",
)
