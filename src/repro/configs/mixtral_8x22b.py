"""Mixtral 8x22B. [arXiv:2401.04088]

MoE decoder, 56L, d_model=6144, 48 heads (GQA kv=8), d_ff=16384,
vocab=32768, 8 experts top-2, sliding-window attention.
"""
from repro.configs.base import ModelConfig, MoEConfig, MOE

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family=MOE,
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    moe=MoEConfig(num_experts=8, top_k=2),
    sliding_window=4096,
    max_context=65536,
    rope_theta=1e6,
    citation="arXiv:2401.04088",
)
