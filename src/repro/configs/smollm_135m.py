"""SmolLM-135M. [hf:HuggingFaceTB/SmolLM-135M]

Llama-arch small dense decoder: 30L, d_model=576, 9 heads (GQA kv=3),
d_ff=1536, vocab=49152.
"""
from repro.configs.base import ModelConfig, DENSE

CONFIG = ModelConfig(
    name="smollm-135m",
    family=DENSE,
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49152,
    max_context=2048,
    tie_embeddings=True,
    citation="hf:HuggingFaceTB/SmolLM-135M",
)
