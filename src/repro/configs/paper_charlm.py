"""The paper's own workload: character-aware CNN-LSTM next-word LM
(Kim et al. 2016, as used in Green Federated Learning §3.2).

Char-CNN word encoder -> 2-layer LSTM -> MLP decoder -> softmax over a
fixed word vocabulary. Sized for cross-device FL (~19M params).
"""
from repro.configs.base import ModelConfig, CHARLM

CONFIG = ModelConfig(
    name="paper-charlm",
    family=CHARLM,
    num_layers=2,              # LSTM layers
    d_model=512,               # word embedding / LSTM input dim
    num_heads=0,
    num_kv_heads=0,
    d_ff=512,                  # MLP decoder hidden
    vocab_size=16384,          # word vocab
    char_vocab=256,
    char_emb=16,
    cnn_filters=((1, 32), (2, 32), (3, 64), (4, 128), (5, 256), (6, 512)),
    lstm_hidden=512,
    max_word_len=16,
    max_context=64,            # words per example (keyboard-style)
    citation="Kim et al. 2016; Green FL paper §3.2",
)
