"""--arch <id> resolution for launchers, tests, and benchmarks."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_ARCH_MODULES = {
    "mistral-nemo-12b": "mistral_nemo_12b",
    "mixtral-8x22b": "mixtral_8x22b",
    "smollm-135m": "smollm_135m",
    "internvl2-2b": "internvl2_2b",
    "rwkv6-7b": "rwkv6_7b",
    "stablelm-3b": "stablelm_3b",
    "stablelm-1.6b": "stablelm_1_6b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "paper-charlm": "paper_charlm",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if k != "paper-charlm")
ALL_ARCHS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    try:
        mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    except KeyError:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in _ARCH_MODULES}
