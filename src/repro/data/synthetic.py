"""Synthetic non-IID federated LM data with the pushift.io-Reddit shape.

The paper trains on pushift.io's Reddit (LEAF): millions of users, mean ~34
comments/user, power-law sample counts, naturally non-IID per-user language.
We reproduce the *statistics* (the carbon study depends on compute/comm
volume and client heterogeneity, not on lexical content):

* sample counts: Pareto-tail distribution, mean ≈ 34, deterministic per
  client id;
* per-user language: a global Zipf unigram-with-bigram-state generator mixed
  with a user-specific "dialect" (a preferred vocab slice + preferred bigram
  shift), giving natural label skew across clients;
* char-level view for the paper's char-CNN-LSTM: word id -> deterministic
  pseudo-word over a 26-letter alphabet with word-length ~ Zipf rank.

All generation is stateless + deterministic in (seed, client_id), so tens of
millions of "clients" exist without storing anything.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

_MEAN_SAMPLES = 34.0
_PARETO_SHAPE = 1.8      # heavy tail like comment counts


def client_num_samples(client_id: int, seed: int = 0,
                       mean: float = _MEAN_SAMPLES) -> int:
    rng = np.random.default_rng(np.uint64(seed * 1_000_003 + client_id))
    # numpy's pareto is Lomax: E[x] = 1/(shape-1), so scale = mean*(shape-1)
    scale = mean * (_PARETO_SHAPE - 1)
    n = int(rng.pareto(_PARETO_SHAPE) * scale + 1)
    return max(2, min(n, 4096))


@dataclasses.dataclass
class FederatedDataset:
    """Deterministic synthetic federated corpus."""

    vocab_size: int
    seq_len: int
    num_clients: int = 1_000_000
    seed: int = 0
    dialect_frac: float = 0.35      # prob of drawing from the user dialect
    dialect_size: int = 512         # size of each user's preferred slice
    char_vocab: int = 0             # >0: also emit char decomposition
    max_word_len: int = 16

    # ---------------------------------------------------------- word level
    def _zipf_probs(self, n: int) -> np.ndarray:
        ranks = np.arange(1, n + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        return p / p.sum()

    def client_tokens(self, client_id: int, n_samples: Optional[int] = None
                      ) -> np.ndarray:
        """(n, seq_len) int32 token ids for one client."""
        if n_samples is None:
            n_samples = client_num_samples(client_id, self.seed)
        rng = np.random.default_rng(
            np.uint64(self.seed * 7_777_777 + client_id * 13 + 1))
        V = self.vocab_size
        n_zipf = min(V, 4096)
        probs = self._zipf_probs(n_zipf)
        # user dialect: a contiguous slice + offset keyed by the client
        d_start = int(rng.integers(0, max(1, V - self.dialect_size)))
        shift = int(rng.integers(0, V))
        total = n_samples * self.seq_len
        base = rng.choice(n_zipf, size=total, p=probs)
        # weak bigram structure: odd positions correlate with previous token
        prev = np.roll(base, 1)
        bigram_mask = rng.random(total) < 0.3
        base = np.where(bigram_mask, (prev + shift) % n_zipf, base)
        use_dialect = rng.random(total) < self.dialect_frac
        dialect = d_start + (base % self.dialect_size)
        toks = np.where(use_dialect, dialect, base).astype(np.int32) % V
        return toks.reshape(n_samples, self.seq_len)

    # ---------------------------------------------------------- char level
    def word_chars(self, word_ids: np.ndarray) -> np.ndarray:
        """Deterministic pseudo-word spelling. word_ids: (...,) ->
        (..., max_word_len) int32 (0 = pad, ids 1..char_vocab-1)."""
        assert self.char_vocab > 0
        flat = word_ids.reshape(-1).astype(np.int64)
        W = self.max_word_len
        # word length grows ~log(rank): frequent words are short
        lens = np.clip(2 + (np.log1p(flat) * 1.7).astype(np.int64), 2, W)
        # char sequence via multiplicative hash chain
        out = np.zeros((flat.size, W), dtype=np.int32)
        state = flat * 2654435761 % (2 ** 31)
        nchars = min(self.char_vocab - 1, 26)
        for i in range(W):
            state = (state * 1103515245 + 12345) % (2 ** 31)
            out[:, i] = 1 + (state % nchars)
        mask = np.arange(W)[None, :] < lens[:, None]
        out = np.where(mask, out, 0)
        return out.reshape(word_ids.shape + (W,)).astype(np.int32)

    # ---------------------------------------------------------- batching
    def client_batches(self, client_id: int, batch_size: int,
                       local_epochs: int = 1) -> list:
        """List of batch dicts covering the client's data E times."""
        toks = self.client_tokens(client_id)
        n = toks.shape[0]
        batches = []
        for _ in range(local_epochs):
            for i in range(0, n, batch_size):
                chunk = toks[i: i + batch_size]
                if chunk.shape[0] < batch_size:  # pad + mask
                    pad = np.zeros((batch_size - chunk.shape[0], self.seq_len),
                                   np.int32)
                    mask = np.concatenate([
                        np.ones((chunk.shape[0], self.seq_len - 1), np.float32),
                        np.zeros((pad.shape[0], self.seq_len - 1), np.float32)])
                    chunk = np.concatenate([chunk, pad], axis=0)
                else:
                    mask = np.ones((batch_size, self.seq_len - 1), np.float32)
                batch = {"tokens": chunk, "labels": chunk,
                         "mask": mask}
                if self.char_vocab:
                    batch["chars"] = self.word_chars(chunk)
                batches.append(batch)
        return batches

    def eval_batch(self, n_clients: int, batch_size: int,
                   offset: int = 10_000_000) -> Dict[str, np.ndarray]:
        """Held-out eval batch from `n_clients` disjoint clients (the paper
        evaluates on 20 held-out clients)."""
        rows = []
        for c in range(n_clients):
            t = self.client_tokens(offset + c, n_samples=max(1, batch_size // n_clients))
            rows.append(t)
        toks = np.concatenate(rows, axis=0)[:batch_size]
        if toks.shape[0] < batch_size:
            reps = -(-batch_size // toks.shape[0])
            toks = np.tile(toks, (reps, 1))[:batch_size]
        batch = {"tokens": toks, "labels": toks,
                 "mask": np.ones((batch_size, self.seq_len - 1), np.float32)}
        if self.char_vocab:
            batch["chars"] = self.word_chars(toks)
        return batch
