from repro.data.synthetic import FederatedDataset, client_num_samples
