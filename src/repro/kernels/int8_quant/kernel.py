"""Pallas TPU kernels for blockwise int8 quantize / fused dequant-accumulate.

TPU adaptation (DESIGN.md §3): the quantization block (256 lanes) maps onto
the VPU lane width (multiples of 128); tiles of ROWS_PER_TILE x block live
in VMEM so each grid step streams one tile HBM->VMEM, reduces |max| on the
sublane axis, and writes int8 + scales back. The dequant-accumulate kernel
fuses the FedBuff buffer update (acc += w * q*scale) into a single pass so
the server never materializes the dequantized f32 update in HBM.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_TILE = 8  # quant blocks per grid step (sublane dim)


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                 # (R, block)
    amax = jnp.max(jnp.abs(x), axis=1)                 # (R,)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def quantize_pallas(x: jnp.ndarray, block: int = 256, interpret: bool = False
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: any shape; returns (q (nb, block) int8, scales (nb,) f32).
    nb is padded up to a multiple of ROWS_PER_TILE."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    pad = (-n) % (block * ROWS_PER_TILE)
    flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, block)
    nb = xb.shape[0]
    grid = (nb // ROWS_PER_TILE,)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, block), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(xb)
    return q, s


def _deq_acc_kernel(q_ref, s_ref, w_ref, acc_ref, out_ref):
    q = q_ref[...].astype(jnp.float32)                 # (R, block)
    s = s_ref[...]                                     # (R,)
    w = w_ref[0]
    out_ref[...] = acc_ref[...] + w * (q * s[:, None])


@functools.partial(jax.jit, static_argnames=("interpret",))
def dequant_accumulate_pallas(acc2d: jnp.ndarray, q: jnp.ndarray,
                              s: jnp.ndarray, weight, interpret: bool = False
                              ) -> jnp.ndarray:
    """acc2d: (nb, block) f32 accumulator laid out like q."""
    nb, block = q.shape
    assert nb % ROWS_PER_TILE == 0
    grid = (nb // ROWS_PER_TILE,)
    w = jnp.asarray([weight], jnp.float32)
    return pl.pallas_call(
        _deq_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0)),
            pl.BlockSpec((ROWS_PER_TILE,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS_PER_TILE, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(q, s, w, acc2d)
