"""Public int8-codec ops: jit'd wrappers that dispatch Pallas on TPU and the
pure-jnp oracle elsewhere (CPU dry-run / tests use interpret=True Pallas)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.int8_quant import kernel as K
from repro.kernels.int8_quant import ref as R


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def quantize(x: jnp.ndarray, block: int = 256, use_pallas: bool | None = None
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return K.quantize_pallas(x, block=block, interpret=not _on_tpu())
    return R.quantize_ref(x, block)


def dequantize(q, s, shape, block: int = 256):
    return R.dequantize_ref(q, s, shape, block)


def quant_dequant(x: jnp.ndarray, block: int = 256,
                  use_pallas: bool | None = None) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        q, s = K.quantize_pallas(x, block=block, interpret=not _on_tpu())
        return R.dequantize_ref(q, s, x.shape, block).astype(x.dtype)
    return R.quant_dequant_ref(x, block)


def dequant_accumulate(acc: jnp.ndarray, q: jnp.ndarray, s: jnp.ndarray,
                       weight, block: int = 256,
                       use_pallas: bool | None = None) -> jnp.ndarray:
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        nb = q.shape[0]
        flat = acc.astype(jnp.float32).reshape(-1)
        pad = nb * block - flat.shape[0]
        acc2d = jnp.pad(flat, (0, pad)).reshape(nb, block)
        out = K.dequant_accumulate_pallas(acc2d, q, s, weight,
                                          interpret=not _on_tpu())
        return out.reshape(-1)[: flat.shape[0]].reshape(acc.shape).astype(acc.dtype)
    return R.dequant_accumulate_ref(acc, q, s, weight, block)


def wire_bytes(x_size: int, block: int = 256) -> int:
    """Bytes on the wire for an int8-compressed tensor of x_size elements."""
    nb = -(-x_size // block)
    return x_size + 4 * nb  # int8 payload + f32 scale per block
