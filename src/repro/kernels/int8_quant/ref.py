"""Pure-jnp oracle for blockwise symmetric int8 quantization.

The paper's compression lever (§6, Prasad et al. 2022): client update
tensors are flattened, padded to a multiple of `block`, and quantized per
block with a symmetric scale max|x|/127. The oracle defines bit-exact
semantics for the Pallas kernel tests.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def _blocked(x: jnp.ndarray, block: int) -> Tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % block
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), n


def quantize_ref(x: jnp.ndarray, block: int = 256
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (q int8 (nb, block), scales f32 (nb,))."""
    xb, _ = _blocked(x.astype(jnp.float32), block)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ref(q: jnp.ndarray, scale: jnp.ndarray, shape, block: int = 256
                   ) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def quant_dequant_ref(x: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    q, s = quantize_ref(x, block)
    return dequantize_ref(q, s, x.shape, block).astype(x.dtype)


def dequant_accumulate_ref(acc: jnp.ndarray, q: jnp.ndarray,
                           scale: jnp.ndarray, weight: float | jnp.ndarray,
                           block: int = 256) -> jnp.ndarray:
    """acc += weight * dequant(q): the FedBuff buffer update, fused."""
    upd = dequantize_ref(q, scale, acc.shape, block)
    return acc + jnp.asarray(weight, acc.dtype) * upd.astype(acc.dtype)
