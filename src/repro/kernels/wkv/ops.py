"""Public WKV op: Pallas on TPU, chunked pure-jnp scan elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.wkv import kernel as K
from repro.kernels.wkv import ref as R


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def wkv(r, k, v, w, u, state, *, use_pallas: bool | None = None,
        interpret: bool = False):
    """Single-panel WKV; see ref.wkv_ref for semantics."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        rb, kb, vb, wb = (t[None] for t in (r, k, v, w))
        o, sT = K.wkv_pallas(rb, kb, vb, wb, u[None], state[None],
                             interpret=interpret or not _on_tpu())
        return o[0], sT[0]
    return R.wkv_ref(r, k, v, w, u, state)
