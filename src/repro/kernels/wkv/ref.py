"""Pure-jnp oracle for the RWKV6 WKV recurrence (single head panel).

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

r,k,v,w: (T, D); u: (D,); state: (D, D). Returns (o (T, D), S_T).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def wkv_ref(r, k, v, w, u, state):
    def step(S, rkvw):
        rt, kt, vt, wt = rkvw
        kv = jnp.outer(kt, vt)
        o = rt @ (S + u[:, None] * kv)
        S = wt[:, None] * S + kv
        return S, o

    state, outs = lax.scan(step, state.astype(jnp.float32),
                           (r.astype(jnp.float32), k.astype(jnp.float32),
                            v.astype(jnp.float32), w.astype(jnp.float32)))
    return outs, state
