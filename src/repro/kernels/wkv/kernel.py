"""Pallas TPU kernel for the RWKV6 WKV recurrence.

TPU adaptation: one (batch*head) panel per grid row; the (D, D) state lives
in VMEM scratch across the sequential chunk axis, so the recurrence never
round-trips HBM between timesteps — the defining win over the pure-jnp scan
whose carry is an HBM tensor. In-chunk steps run as a fori_loop over VMEM
tiles; D (head_dim, typically 64) maps onto VPU lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                S_scr, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        S_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # (chunk, D)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (D,)

    def step(t, _):
        S = S_scr[...]
        kt, vt, rt, wt = k[t], v[t], r[t], w[t]
        kv = kt[:, None] * vt[None, :]        # (D, D) outer product
        o_ref[0, t, :] = (rt @ (S + u[:, None] * kv)).astype(o_ref.dtype)
        S_scr[...] = wt[:, None] * S + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ci == n_chunks - 1)
    def _fin():
        sT_ref[0] = S_scr[...].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_pallas(r, k, v, w, u, state, chunk: int = 64,
               interpret: bool = False):
    """r,k,v,w: (BH, T, D); u: (BH, D); state: (BH, D, D) f32.
    Returns (o (BH, T, D), S_T (BH, D, D))."""
    BH, T, D = r.shape
    chunk = min(chunk, T)
    assert T % chunk == 0
    n_chunks = T // chunk
    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks)
    o, sT = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, D), lambda b, c: (b, 0)),
            pl.BlockSpec((1, D, D), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, D), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, D, D), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, D), r.dtype),
            jax.ShapeDtypeStruct((BH, D, D), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, state)
    return o, sT
