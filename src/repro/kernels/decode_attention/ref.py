"""Naive pure-jnp oracle for single-token GQA decode attention."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, valid_len) -> jnp.ndarray:
    """q: (B,Hq,D); caches: (B,C,Hkv,D); valid_len: () or (B,) -> (B,Hq,D)."""
    B, C, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, D)
    s = jnp.einsum("bhgd,bchd->bhgc", qf, k_cache.astype(jnp.float32))
    s = s / math.sqrt(D)
    vl = jnp.asarray(valid_len)
    if vl.ndim == 0:
        vl = jnp.full((B,), vl)
    mask = jnp.arange(C)[None, :] < vl[:, None]
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bchd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(B, Hq, D).astype(q.dtype)
