"""Pallas TPU decode attention: one query token vs. a (ring-buffer) KV cache.

Grid (B*Hkv, n_cache_blocks): the cache streams through VMEM in
(BLOCK_C, D) tiles while the (group, D) query tile stays resident; online
softmax state (m, l, acc) sits in VMEM scratch across the sequential cache
axis. valid_len masks ring-buffer slots (prefetched as a scalar). This is
the serving-path hot spot for decode_32k / long_500k shapes.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(vl_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, block_c: int, n_c: int, scale: float):
    ci = pl.program_id(1)
    b = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # (g, D)
    k = k_ref[0].astype(jnp.float32)          # (bc, D)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale  # (g, bc)
    cpos = ci * block_c + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = cpos < vl_ref[b]
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ci == n_c - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def decode_attention_pallas(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, valid_len,
                            block_c: int = 512, interpret: bool = False
                            ) -> jnp.ndarray:
    """q: (B,Hq,D); caches: (B,C,Hkv,D); valid_len: () or (B,)."""
    B, C, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    g = Hq // Hkv
    block_c = min(block_c, C)
    assert C % block_c == 0
    n_c = C // block_c
    scale = 1.0 / math.sqrt(D)

    vl = jnp.asarray(valid_len, jnp.int32)
    if vl.ndim == 0:
        vl = jnp.full((B,), vl, jnp.int32)
    # per (batch, kv head) panels: q (B*Hkv, g, D); kv (B*Hkv, C, D)
    qr = q.reshape(B, Hkv, g, D).reshape(B * Hkv, g, D)
    kr = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    vr = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, C, D)
    vl_bh = jnp.repeat(vl, Hkv)

    kernel = functools.partial(_decode_kernel, block_c=block_c, n_c=n_c,
                               scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hkv, n_c),
        in_specs=[
            pl.BlockSpec((1, g, D), lambda bh, ci, vl_ref: (bh, 0, 0)),
            pl.BlockSpec((1, block_c, D), lambda bh, ci, vl_ref: (bh, ci, 0)),
            pl.BlockSpec((1, block_c, D), lambda bh, ci, vl_ref: (bh, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, D), lambda bh, ci, vl_ref: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, g, D), q.dtype),
        interpret=interpret,
    )(vl_bh, qr, kr, vr)
    return out.reshape(B, Hq, D)
