"""Public decode-attention op: Pallas on TPU, pure-jnp path elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.decode_attention import kernel as K
from repro.models import common as cm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def decode_attention(q, k_cache, v_cache, valid_len, *,
                     use_pallas: bool | None = None, interpret: bool = False):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return K.decode_attention_pallas(q, k_cache, v_cache, valid_len,
                                         interpret=interpret or not _on_tpu())
    return cm.decode_attention(q, k_cache, v_cache, valid_len)
