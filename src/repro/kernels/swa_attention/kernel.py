"""Pallas TPU flash attention with causal + sliding-window masking.

TPU adaptation (DESIGN.md §3): classic FlashAttention online-softmax, tiled
for VMEM — q tile (BLOCK_Q, D) and kv tiles (BLOCK_KV, D) with D padded to
lane width 128 and block sizes multiples of the MXU dim. Grid is
(batch*kv_head*group, n_q, n_kv); the LAST grid axis is sequential on TPU,
so the running (m, l, acc) state lives in VMEM scratch across kv steps and
the output tile is written once on the final kv block. Sliding-window
banding prunes work via `pl.when` on the block-level mask (a kv block
strictly outside the band contributes nothing and skips its matmuls).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 block_q: int, block_kv: int, window: int, causal: bool,
                 scale: float, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_kv
    # block-level band check (python-level constants + program ids)
    diag_ok = jnp.asarray(True)
    if causal:
        diag_ok &= k_start <= q_start + block_q - 1
    if window:
        diag_ok &= k_start + block_kv - 1 > q_start - window

    @pl.when(diag_ok)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                      # (bq, D)
        k = k_ref[0].astype(jnp.float32)                      # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
        mask = jnp.ones((block_q, block_kv), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                           causal: bool = True, window: int = 0,
                           block_q: int = 256, block_kv: int = 256,
                           interpret: bool = False) -> jnp.ndarray:
    """q: (B,S,Hq,D); k/v: (B,S,Hkv,D). GQA via head grouping."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0
    n_q, n_kv = S // block_q, S // block_kv
    scale = 1.0 / math.sqrt(D)

    # (B*Hq, S, D) for q/o; (B*Hkv, S, D) for k/v; q head bh -> kv head bh//g
    qr = q.transpose(0, 2, 1, 3).reshape(B * Hq, S, D)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_kv=block_kv, window=window,
        causal=causal, scale=scale, n_kv=n_kv)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_kv, D), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
            pl.BlockSpec((1, block_kv, D), lambda bh, qi, ki, g=g: (bh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max m
            pltpu.VMEM((block_q,), jnp.float32),      # running denom l
            pltpu.VMEM((block_q, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, Hq, S, D).transpose(0, 2, 1, 3)
