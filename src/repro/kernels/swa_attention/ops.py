"""Public attention op: Pallas on TPU, blocked pure-JAX path elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.swa_attention import kernel as K
from repro.models import common as cm


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, causal: bool = True, window: int = 0,
              use_pallas: bool | None = None, interpret: bool = False):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas:
        return K.flash_attention_pallas(q, k, v, causal=causal, window=window,
                                        interpret=interpret or not _on_tpu())
    return cm.flash_attention(q, k, v, causal=causal, window=window)
