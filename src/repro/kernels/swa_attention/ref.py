"""Naive pure-jnp oracle for (sliding-window) causal GQA attention.

Materializes the full (S, S) score matrix — test sizes only. This is an
INDEPENDENT oracle: both the Pallas kernel and the blocked pure-JAX
production path (models.common.flash_attention) are validated against it.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q: (B,S,Hq,D); k/v: (B,S,Hkv,D) -> (B,S,Hq,D)."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, S, Hkv, g, D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf) / math.sqrt(D)
    pos_q = jnp.arange(S)[:, None]
    pos_k = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos_k <= pos_q
    if window:
        mask &= pos_k > pos_q - window
    s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, S, Hq, D).astype(q.dtype)
