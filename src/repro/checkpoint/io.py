"""Flat-pytree checkpointing: .npz payload + json manifest.

Works on the framework's flat-dict param/opt-state trees. Nested dicts are
flattened with '::' separators; dtypes/shapes round-trip exactly. Atomic
write (tmp + rename) so a crashed run never leaves a torn checkpoint.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}::"))
    else:
        out[prefix[:-2]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split("::")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


def save_checkpoint(path: str, tree: Any, meta: Dict[str, Any] | None = None
                    ) -> None:
    os.makedirs(path, exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    fd, tmp = tempfile.mkstemp(dir=path, suffix=".tmp.npz")
    os.close(fd)
    np.savez(tmp, **flat)
    os.replace(tmp, os.path.join(path, "arrays.npz"))
    manifest = {
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "meta": meta or {},
    }
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str) -> Tuple[Any, Dict[str, Any]]:
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in manifest["keys"]}
    return _unflatten(flat), manifest["meta"]
