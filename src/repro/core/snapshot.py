"""Engine snapshots — versioned mid-run checkpoints that resume exactly.

A production FL task runs for days; the harness must survive its own
interruptions the same way PR 8's device checkpointing survives client
churn. This module serializes the full mid-run state of the serial event
loops (`SyncStrategy._loop` / `AsyncStrategy._loop`, carbon-aware
included) as a single ``.npz`` file:

* ``header`` — a 0-d unicode array holding a JSON dict: format tag +
  ``SNAPSHOT_VERSION``, the producing spec (embedded, plus its
  ``content_hash``), the loop's scalar state (clock, round/version,
  perplexity, the sync cohort RNG state), the ``_Stopper`` and surrogate
  learner state, telemetry counters and eval history, and the streaming
  ``ExactSum`` states (hex-mantissa, exact). Python's ``json`` round-trips
  int and float64 values exactly, so every scalar restores bit-for-bit.
* array payloads — namespaced npz members: the async in-flight slot
  columns (``engine/flight_*``) and the streaming reservoir /
  grouped-table arrays (``stream/*``).
* materialized session rows live in an append-only sidecar,
  ``<path>.rows`` (``_RowStore``): each checkpoint appends ONE segment
  holding only the rows logged since the previous checkpoint
  (``np.lib.format`` arrays in ``_ACC_DTYPES`` field order), and the
  header's ``sessions`` meta records the segment table and valid byte
  length. That keeps per-checkpoint cost O(new rows); re-serializing the
  whole cumulative log every 50 windows would be quadratic over a run.

Checkpoints are written at round (sync) / server-version (async) window
boundaries only. Crash safety: the rows segment is appended and flushed
FIRST, then the head file is replaced atomically (tmp + ``os.replace``)
— a crash between the two leaves the old head pointing at a valid
prefix of the rows file, and the torn tail is truncated when the next
run adopts the store. Because every per-session draw is a counter-keyed
pure function of ``(seed, slot, generation, ...)`` — never of global
history — the state above is *sufficient*: a resumed loop replays the
remaining rounds bit-for-bit, and work done after the last checkpoint
is simply redone.

``_CrashInjector`` is the test-only fault hook: armed by env vars
(``REPRO_CRASH_ROUND``, ``REPRO_CRASH_KIND=raise|kill|hang``,
``REPRO_CRASH_SEED`` to target one spec of a sweep, ``REPRO_CRASH_ONCE``
pointing at a marker file so the crash fires exactly once), it raises
``InjectedCrash``, hard-exits the worker, or hangs it at a chosen round —
driving the resume property tests, the fault-tolerant sweep tests and the
smoke step.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.telemetry import SessionBatch, TaskLog, _ACC_DTYPES

SNAPSHOT_VERSION = 1
_FORMAT = "repro-engine-snapshot"

# exit code a kill-injected worker dies with (distinguishable from crashes
# of the interpreter itself in test assertions)
KILL_EXIT_CODE = 87


class InjectedCrash(RuntimeError):
    """Raised by an armed ``_CrashInjector`` (kind="raise")."""


class _CrashInjector:
    """Test-only: crash the current run when the loop reaches a round.

    ``tick(round_idx)`` fires once ``round_idx >= at_round``: ``raise``
    raises :class:`InjectedCrash` in-process, ``kill`` hard-exits the
    worker (``os._exit`` — simulates a dead sweep worker: no exception,
    no result), ``hang`` sleeps forever (simulates a wedged worker for
    timeout detection). With ``once_path`` set the injector creates that
    marker file *before* crashing and stays disarmed while it exists, so
    a retried attempt succeeds.
    """

    def __init__(self, at_round: int, kind: str = "raise",
                 once_path: Optional[str] = None):
        assert kind in ("raise", "kill", "hang"), kind
        self.at_round = int(at_round)
        self.kind = kind
        self.once_path = once_path

    @classmethod
    def from_env(cls, environ=None, seed: Optional[int] = None
                 ) -> Optional["_CrashInjector"]:
        env = os.environ if environ is None else environ
        at = env.get("REPRO_CRASH_ROUND")
        if at is None:
            return None
        target = env.get("REPRO_CRASH_SEED")
        if target is not None and seed is not None \
                and int(target) != int(seed):
            return None
        return cls(int(at), env.get("REPRO_CRASH_KIND", "raise"),
                   env.get("REPRO_CRASH_ONCE") or None)

    def tick(self, round_idx: int) -> None:
        if round_idx < self.at_round:
            return
        if self.once_path:
            if os.path.exists(self.once_path):
                return
            with open(self.once_path, "w"):
                pass
        if self.kind == "kill":
            os._exit(KILL_EXIT_CODE)
        if self.kind == "hang":
            while True:   # parent terminates us on timeout
                time.sleep(0.25)
        raise InjectedCrash(
            f"injected crash at round {round_idx} "
            f"(>= REPRO_CRASH_ROUND {self.at_round})")


# ---------------------------------------------------------------------------
# Save / load
# ---------------------------------------------------------------------------

_ROWS_SUFFIX = ".rows"


class _RowStore:
    """Append-only session-row sidecar beside the head checkpoint file.

    Every checkpoint appends ONE segment holding the materialized rows
    logged since the previous one — ``np.lib.format`` arrays, one per
    SessionBatch column in ``_ACC_DTYPES`` order — so periodic
    checkpointing costs O(new rows) per save instead of re-serializing
    the whole cumulative log. The segment table (offsets + row counts)
    and the valid byte length travel in the HEAD file: bytes past
    ``valid_bytes`` are a torn tail from a crash between segment append
    and head replace, truncated when the store is adopted on resume."""

    def __init__(self, path: str, meta: Optional[Dict] = None):
        self.path = path
        if meta is None:
            self.segments: list = []
            self.valid_bytes = 0
            self.rows = 0
            self.names: Optional[Tuple[tuple, tuple]] = None
            self._adopted = True            # fresh store, nothing to trim
        else:                               # continue a resumed store
            self.segments = [dict(s) for s in meta["segments"]]
            self.valid_bytes = int(meta["valid_bytes"])
            self.rows = int(meta["rows"])
            self.names = (tuple(meta["device_names"]),
                          tuple(meta["country_names"])) \
                if meta.get("device_names") else None
            self._adopted = False

    def meta(self, owner: str) -> Dict:
        dev, ctry = self.names if self.names else ((), ())
        return {"owner": owner, "file": os.path.basename(self.path),
                "rows": self.rows, "valid_bytes": self.valid_bytes,
                "segments": self.segments,
                "device_names": list(dev), "country_names": list(ctry)}

    def append(self, dev: tuple, ctry: tuple,
               cols: Dict[str, np.ndarray]) -> None:
        n = len(cols["client_id"])
        if not n:
            return
        if self.names is None:
            self.names = (tuple(dev), tuple(ctry))
        elif (tuple(dev), tuple(ctry)) != self.names:
            raise ValueError("session vocabularies changed mid-run; "
                             "cannot checkpoint incrementally")
        if not self._adopted:
            with open(self.path, "r+b") as f:   # drop any torn tail
                f.truncate(self.valid_bytes)
            self._adopted = True
        mode = "wb" if self.valid_bytes == 0 else "ab"
        with open(self.path, mode) as f:
            off = f.tell()
            for field in _ACC_DTYPES:
                np.lib.format.write_array(
                    f, np.ascontiguousarray(cols[field]),
                    allow_pickle=False)
            end = f.tell()
        self.segments.append({"offset": off, "rows": n})
        self.valid_bytes = end
        self.rows += n

    @staticmethod
    def read(path: str, meta: Dict) -> Dict[str, np.ndarray]:
        """Concatenate every segment back into full columns."""
        parts: Dict[str, list] = {f: [] for f in _ACC_DTYPES}
        with open(path, "rb") as f:
            for seg in meta["segments"]:
                f.seek(int(seg["offset"]))
                for field in _ACC_DTYPES:
                    parts[field].append(
                        np.lib.format.read_array(f, allow_pickle=False))
        return {f: (np.concatenate(v) if v else np.zeros(0, _ACC_DTYPES[f]))
                for f, v in parts.items()}


def save_snapshot(path: str, *, spec, mode: str, every: int, round_idx: int,
                  engine: Dict, log: TaskLog, learner, stop,
                  sessions: Optional[Dict] = None) -> None:
    """Write one head checkpoint atomically (tmp file + ``os.replace``).

    ``engine`` mixes JSON-able scalars (clock, counters, the sync RNG
    state dict) with numpy arrays (async flight columns) — arrays go to
    npz members, the rest into the header. ``sessions`` is the
    ``_RowStore`` meta describing the materialized session rows already
    appended to the sidecar (None for streaming telemetry, whose
    constant-size state rides in the head itself)."""
    header: Dict = {
        "format": _FORMAT,
        "version": SNAPSHOT_VERSION,
        "spec_hash": spec.content_hash(),
        "spec": spec.to_dict(),
        "mode": mode,
        "every": int(every),
        "round": int(round_idx),
        "stopper": {"smoothed": stop.smoothed, "hits": stop.hits,
                    "reached": stop.reached, "aborted": stop.aborted},
        "learner": learner.state(),
        "sessions": sessions,
    }
    arrays: Dict[str, np.ndarray] = {}
    scalars: Dict = {}
    for k, v in engine.items():
        if isinstance(v, np.ndarray):
            arrays[f"engine/{k}"] = v
        else:
            scalars[k] = v
    header["engine"] = scalars

    logh: Dict = {"rounds": log.rounds, "starved_rounds": log.starved_rounds,
                  "duration_s": log.duration_s,
                  "server_busy_s": log.server_busy_s,
                  "eval_history": log.eval_history}
    if hasattr(log, "stream_state"):
        logh["kind"] = "streaming"
        meta, arrs = log.stream_state()
        logh["stream"] = meta
        for k, a in arrs.items():
            arrays[f"stream/{k}"] = a
    else:
        logh["kind"] = "full"
    header["log"] = logh

    tmp = f"{path}.tmp"
    with open(tmp, "wb") as f:
        np.savez(f, header=np.asarray(json.dumps(header)), **arrays)
    os.replace(tmp, path)


def load_snapshot(path: str) -> "Snapshot":
    """Load and validate a checkpoint; raises ``ValueError`` naming the
    found and supported versions on a format/version mismatch (spec-hash
    validation happens in ``Experiment``, which knows the expected spec)."""
    with np.load(path, allow_pickle=False) as data:
        if "header" not in data.files:
            raise ValueError(f"{path!r} is not a {_FORMAT} file "
                             f"(no header member)")
        header = json.loads(str(data["header"][()]))
        arrays = {k: data[k] for k in data.files if k != "header"}
    if header.get("format") != _FORMAT:
        raise ValueError(
            f"{path!r} is not a {_FORMAT} file "
            f"(format tag {header.get('format')!r})")
    v = header.get("version")
    if v != SNAPSHOT_VERSION:
        raise ValueError(
            f"unsupported checkpoint version {v!r} in {path!r}; this "
            f"build reads snapshot version {SNAPSHOT_VERSION}")
    return Snapshot(header, arrays, path)


class Snapshot:
    """A loaded checkpoint: validated header dict + payload arrays."""

    def __init__(self, header: Dict, arrays: Dict[str, np.ndarray],
                 path: Optional[str] = None):
        self.header = header
        self.arrays = arrays
        self.path = path

    @property
    def spec_hash(self) -> str:
        return self.header["spec_hash"]

    @property
    def round_idx(self) -> int:
        return int(self.header["round"])

    @property
    def every(self) -> int:
        return int(self.header.get("every", 0))

    def spec(self):
        from repro.api.spec import ExperimentSpec   # lazy: avoid core->api
        return ExperimentSpec.from_dict(self.header["spec"])

    def engine_state(self) -> Dict:
        """Loop-local state: header scalars merged with ``engine/*``
        arrays (keys as the loop stored them)."""
        out = dict(self.header["engine"])
        for k, a in self.arrays.items():
            if k.startswith("engine/"):
                out[k[len("engine/"):]] = a
        return out

    def _sessions_batch(self, owner: str) -> Optional[SessionBatch]:
        """Rows of the given owner ("log" / "sink") read back from the
        rows sidecar, as one consolidated SessionBatch."""
        meta = self.header.get("sessions")
        if meta is None or meta["owner"] != owner or not meta["rows"]:
            return None
        rows_path = os.path.join(os.path.dirname(self.path or ""),
                                 meta["file"])
        return SessionBatch(
            device_names=tuple(meta["device_names"]),
            country_names=tuple(meta["country_names"]),
            **_RowStore.read(rows_path, meta))

    def sink_batch(self) -> Optional[SessionBatch]:
        """Pre-checkpoint rows of the async materialized window sink."""
        return self._sessions_batch("sink")

    # ------------------------------------------------------------- restore
    def restore_log(self, log: TaskLog) -> None:
        logh = self.header["log"]
        log.rounds = int(logh["rounds"])
        log.starved_rounds = int(logh["starved_rounds"])
        log.duration_s = float(logh["duration_s"])
        log.server_busy_s = float(logh["server_busy_s"])
        log.eval_history = [dict(e) for e in logh["eval_history"]]
        if logh["kind"] == "streaming":
            if not hasattr(log, "load_stream_state"):
                raise ValueError(
                    "checkpoint carries streaming telemetry state but the "
                    "resumed run built a materialized log (spec mismatch)")
            log.load_stream_state(
                logh["stream"],
                {k[len("stream/"):]: a for k, a in self.arrays.items()
                 if k.startswith("stream/")})
        else:
            batch = self._sessions_batch("log")
            if batch is not None:
                log.log_batch(batch)

    def restore_stopper(self, stop) -> None:
        sh = self.header["stopper"]
        stop.smoothed = sh["smoothed"]
        stop.hits = int(sh["hits"])
        stop.reached = bool(sh["reached"])
        stop.aborted = bool(sh["aborted"])

    def restore_learner(self, learner) -> None:
        if not hasattr(learner, "load_state"):
            raise ValueError(
                "engine snapshots require a learner with state()/"
                "load_state() (the surrogate); the real JAX learner is "
                "not resumable")
        learner.load_state(self.header["learner"])


# ---------------------------------------------------------------------------
# The loop-side hook
# ---------------------------------------------------------------------------

class SnapshotHook:
    """What the event loops see: ``tick(round_idx, build_state)`` saves a
    checkpoint every ``every`` rounds (then fires the crash injector, so a
    crash-at-checkpoint-round still leaves that checkpoint behind), and
    ``engine_state``/``sink_batch`` hand a resuming loop its saved state.

    ``build_state`` is a zero-arg callable returning ``(engine_dict,
    sink_accumulator_or_None)`` — state assembly is deferred so a hook
    with no checkpoint path (crash-injection only) costs nothing per
    round. The sink accumulator (async materialized window sink) and the
    log are mined with ``snapshot_rows`` so each save appends only the
    rows logged since the previous checkpoint to the rows sidecar.
    """

    def __init__(self, *, path: Optional[str] = None, every: int = 0,
                 spec=None, mode: str = "",
                 crash: Optional[_CrashInjector] = None,
                 resume: Optional[Snapshot] = None):
        self.path = path
        self.every = int(every)
        self.spec = spec
        self.mode = mode
        self.crash = crash
        self.resume = resume
        self.saves = 0          # checkpoints written by THIS run
        self.save_wall_s = 0.0  # wall seconds spent writing them
        # never re-save the state we just resumed from
        self._last_saved = resume.round_idx if resume is not None else -1
        self._rows: Optional[_RowStore] = None
        if path:
            meta = None
            if resume is not None and resume.path is not None \
                    and os.path.abspath(path) \
                    == os.path.abspath(resume.path):
                # continuing the resumed store: adopt its segment table
                # (a fresh path re-writes all rows as its first segment)
                meta = resume.header.get("sessions")
            self._rows = _RowStore(path + _ROWS_SUFFIX, meta)

    @property
    def engine_state(self) -> Optional[Dict]:
        return None if self.resume is None else self.resume.engine_state()

    def sink_batch(self) -> Optional[SessionBatch]:
        return None if self.resume is None else self.resume.sink_batch()

    def tick(self, round_idx: int,
             build_state: Callable[[], Tuple[Dict, Optional[object]]],
             log: TaskLog, learner, stop) -> None:
        if (self.path and self.every > 0 and round_idx > 0
                and round_idx % self.every == 0
                and round_idx != self._last_saved):
            t0 = time.perf_counter()
            engine, sink = build_state()
            sessions = None
            if not hasattr(log, "stream_state"):
                source = log if sink is None else sink
                owner = "log" if sink is None else "sink"
                dev, ctry, cols = source.snapshot_rows(self._rows.rows)
                self._rows.append(dev, ctry, cols)   # BEFORE the head
                sessions = self._rows.meta(owner)
            save_snapshot(self.path, spec=self.spec, mode=self.mode,
                          every=self.every, round_idx=round_idx,
                          engine=engine, log=log, learner=learner,
                          stop=stop, sessions=sessions)
            self._last_saved = round_idx
            self.saves += 1
            self.save_wall_s += time.perf_counter() - t0
        if self.crash is not None:
            self.crash.tick(round_idx)
