"""Pre-deployment carbon predictor (paper §5.3, Figures 8–9).

Empirical law: carbon ≈ a * (concurrency x rounds) + b for synchronous FL
and a * (concurrency x duration) + b for asynchronous FL. The coefficient a
depends on the task (model size, data, fleet, infrastructure); practitioners
fit it from a handful of measured runs, then forecast new configurations
before launch using simulated rounds-to-target.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class LinearFit:
    slope: float
    intercept: float
    r2: float

    def predict(self, x: float) -> float:
        return self.slope * x + self.intercept


def fit_linear(x: Sequence[float], y: Sequence[float]) -> LinearFit:
    x = np.asarray(x, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    assert x.shape == y.shape and x.size >= 2
    A = np.stack([x, np.ones_like(x)], axis=1)
    (slope, intercept), *_ = np.linalg.lstsq(A, y, rcond=None)
    pred = slope * x + intercept
    ss_res = float(np.sum((y - pred) ** 2))
    ss_tot = float(np.sum((y - np.mean(y)) ** 2))
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    return LinearFit(float(slope), float(intercept), r2)


@dataclass(frozen=True)
class CarbonPredictor:
    """carbon_kg ≈ slope * (concurrency x rounds_or_hours) + intercept."""

    fit: LinearFit
    mode: str                      # "sync" (x = concurrency*rounds)
    #                                "async" (x = concurrency*hours)

    @classmethod
    def from_measurements(cls, mode: str,
                          concurrency: Sequence[float],
                          rounds_or_hours: Sequence[float],
                          carbon_kg: Sequence[float]) -> "CarbonPredictor":
        x = np.asarray(concurrency, np.float64) * \
            np.asarray(rounds_or_hours, np.float64)
        return cls(fit=fit_linear(x, carbon_kg), mode=mode)

    def predict_kg(self, concurrency: float, rounds_or_hours: float) -> float:
        return self.fit.predict(concurrency * rounds_or_hours)
