"""Green-FL advisor (paper C4): pre-deployment configuration search.

Given constraints (deadline, target quality), simulate candidate configs
with the surrogate learner + carbon estimator, return the Pareto frontier
and the greenest feasible config. Encodes the paper's recipe as the default
candidate grid: LOW concurrency, local epochs 1-3, tuned FedAdam — and
exposes WHY each config wins (predicted rounds x concurrency).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence

from repro.configs.base import FederatedConfig, ModelConfig, RunConfig
from repro.federated.runtime import TaskResult, run_task
from repro.federated.surrogate import SurrogateLearner


@dataclass(frozen=True)
class Recommendation:
    fed: FederatedConfig
    carbon_kg: float
    duration_h: float
    reached_target: bool
    rounds: int

    def why(self) -> str:
        return (f"concurrency={self.fed.concurrency} x rounds={self.rounds} "
                f"-> {self.carbon_kg:.2f} kgCO2e in {self.duration_h:.1f} h "
                f"(E={self.fed.local_epochs}, lr_c={self.fed.client_lr}, "
                f"lr_s={self.fed.server_lr}, {self.fed.mode})")


DEFAULT_GRID = dict(
    mode=("sync", "async"),
    concurrency=(50, 100, 200, 400, 800),
    local_epochs=(1, 3),
    client_lr=(0.05, 0.1, 0.2),
    compression=("none", "int8"),
)


class GreenAdvisor:
    def __init__(self, model_cfg: ModelConfig, run: Optional[RunConfig] = None,
                 seq_len: int = 64):
        self.cfg = model_cfg
        self.run = run or RunConfig()
        self.seq_len = seq_len
        self._cache: Dict[FederatedConfig, Recommendation] = {}

    def evaluate(self, fed: FederatedConfig) -> Recommendation:
        if fed in self._cache:
            return self._cache[fed]
        learner = SurrogateLearner(self.cfg, fed, self.run)
        res = run_task(self.cfg, fed, self.run, learner,
                       seq_len=self.seq_len)
        rec = Recommendation(fed, res.carbon.total_kg, res.duration_h,
                             res.reached_target, res.rounds)
        self._cache[fed] = rec
        return rec

    def search(self, grid: Optional[Dict[str, Sequence]] = None,
               max_hours: Optional[float] = None) -> List[Recommendation]:
        grid = grid or DEFAULT_GRID
        recs = []
        keys = list(grid)
        for vals in itertools.product(*grid.values()):
            kw = dict(zip(keys, vals))
            kw.setdefault("aggregation_goal",
                          max(1, int(kw.get("concurrency", 100) * 0.8)))
            fed = FederatedConfig(**kw)
            recs.append(self.evaluate(fed))
        feasible = [r for r in recs if r.reached_target and
                    (max_hours is None or r.duration_h <= max_hours)]
        feasible.sort(key=lambda r: r.carbon_kg)
        return feasible or sorted(recs, key=lambda r: r.carbon_kg)

    def recommend(self, **kw) -> Recommendation:
        return self.search(**kw)[0]

    @staticmethod
    def pareto(recs: List[Recommendation]) -> List[Recommendation]:
        """(duration, carbon) Pareto frontier among target-reaching configs."""
        pts = sorted((r for r in recs if r.reached_target),
                     key=lambda r: (r.duration_h, r.carbon_kg))
        front, best = [], float("inf")
        for r in pts:
            if r.carbon_kg < best:
                front.append(r)
                best = r.carbon_kg
        return front
