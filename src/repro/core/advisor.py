"""Green-FL advisor (paper C4): pre-deployment configuration search.

Given constraints (deadline, target quality), simulate candidate configs
through `repro.api.Experiment` (surrogate learner + the advisor's
`Environment`), return the Pareto frontier and the greenest feasible
config. Encodes the paper's recipe as the default candidate grid: LOW
concurrency, local epochs 1-3, tuned FedAdam — and exposes WHY each config
wins (predicted rounds x concurrency). When nothing in the grid satisfies
the constraints, `search()` still returns the carbon-sorted candidates but
marks every one `feasible=False` instead of silently passing them off.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.api import Environment, Experiment, ExperimentSpec, ModelRef
from repro.configs.base import FederatedConfig, ModelConfig, RunConfig


@dataclass(frozen=True)
class Recommendation:
    fed: FederatedConfig
    carbon_kg: float
    duration_h: float
    reached_target: bool
    rounds: int
    feasible: bool = True    # satisfied the search() constraints it came from

    def why(self) -> str:
        flag = "" if self.feasible else " [INFEASIBLE]"
        return (f"concurrency={self.fed.concurrency} x rounds={self.rounds} "
                f"-> {self.carbon_kg:.2f} kgCO2e in {self.duration_h:.1f} h "
                f"(E={self.fed.local_epochs}, lr_c={self.fed.client_lr}, "
                f"lr_s={self.fed.server_lr}, {self.fed.mode}){flag}")


DEFAULT_GRID = dict(
    mode=("sync", "async"),
    concurrency=(50, 100, 200, 400, 800),
    local_epochs=(1, 3),
    client_lr=(0.05, 0.1, 0.2),
    compression=("none", "int8"),
)


class GreenAdvisor:
    def __init__(self, model_cfg: ModelConfig, run: Optional[RunConfig] = None,
                 seq_len: int = 64,
                 environment: Optional[Environment] = None):
        self.cfg = model_cfg
        self.run = run or RunConfig()
        self.seq_len = seq_len
        self.environment = environment or Environment()
        self._model_ref = ModelRef.from_config(model_cfg)
        self._cache: Dict[Tuple, Recommendation] = {}

    @staticmethod
    def _cache_key(fed: FederatedConfig) -> Tuple:
        """A canonical value key — field-order tuple of the frozen config —
        rather than trusting the config object itself to hash stably."""
        return dataclasses.astuple(fed)

    def evaluate(self, fed: FederatedConfig) -> Recommendation:
        key = self._cache_key(fed)
        if key in self._cache:
            return self._cache[key]
        spec = ExperimentSpec(model=self._model_ref, federated=fed,
                              run=self.run, environment=self.environment,
                              learner="surrogate", seq_len=self.seq_len)
        res = Experiment(spec).run()
        rec = Recommendation(fed, res.carbon.total_kg, res.duration_h,
                             res.reached_target, res.rounds)
        self._cache[key] = rec
        return rec

    def search(self, grid: Optional[Dict[str, Sequence]] = None,
               max_hours: Optional[float] = None) -> List[Recommendation]:
        grid = grid or DEFAULT_GRID
        recs = []
        keys = list(grid)
        for vals in itertools.product(*grid.values()):
            kw = dict(zip(keys, vals))
            kw.setdefault("aggregation_goal",
                          max(1, int(kw.get("concurrency", 100) * 0.8)))
            fed = FederatedConfig(**kw)
            recs.append(self.evaluate(fed))
        feasible = [r for r in recs if r.reached_target and
                    (max_hours is None or r.duration_h <= max_hours)]
        if feasible:
            feasible.sort(key=lambda r: r.carbon_kg)
            return feasible
        # nothing meets the constraints: return the least-bad candidates but
        # say so explicitly rather than passing them off as recommendations
        return [replace(r, feasible=False)
                for r in sorted(recs, key=lambda r: r.carbon_kg)]

    def recommend(self, **kw) -> Recommendation:
        return self.search(**kw)[0]

    @staticmethod
    def pareto(recs: List[Recommendation]) -> List[Recommendation]:
        """(duration, carbon) Pareto frontier among target-reaching configs."""
        pts = sorted((r for r in recs if r.reached_target),
                     key=lambda r: (r.duration_h, r.carbon_kg))
        front, best = [], float("inf")
        for r in pts:
            if r.carbon_kg < best:
                front.append(r)
                best = r.carbon_kg
        return front
