"""Representative Android device fleet (paper §4.1).

The paper extracts per-component currents from each manufacturer's
``power_profile.xml`` (cpu.active + cpu.cluster_power.cluster +
cpu.core_power.cluster at the big cluster's max frequency; wifi.active,
wifi.controller.rx/tx, wifi.controller.voltage) for the 210 most common
device models (~20% of participants), imputing the rest by SoC similarity.

We model that registry parametrically: a set of representative profiles
spanning the flagship→entry-level power/throughput range, each with a fleet
popularity weight and a country mix. Currents are in mA (power_profile.xml
units); phones are assumed to operate at 3.8 V (Deloitte 2015), as in the
paper. Training throughput is the *effective* CPU FLOP/s of the big cluster
on NN training workloads (PyTorch Mobile CPU path, fp32), which sets the
session compute duration the same way the paper's logger measures it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

VOLTAGE_V = 3.8  # Watt's law conversion voltage used by the paper


@dataclass(frozen=True)
class DeviceProfile:
    name: str
    soc: str
    # power_profile.xml fields (mA)
    cpu_active_ma: float          # cpu.active
    cpu_cluster_ma: float         # cpu.cluster_power.cluster (big)
    cpu_core_ma: float            # cpu.core_power.cluster @ max freq, per core
    big_cores: int
    wifi_active_ma: float         # wifi.active
    wifi_rx_ma: float             # wifi.controller.rx
    wifi_tx_ma: float             # wifi.controller.tx
    wifi_voltage_v: float         # wifi.controller.voltage
    # effective NN-training throughput of the big cluster (FLOP/s)
    train_gflops: float
    weight: float                 # fleet popularity weight

    @property
    def cpu_power_w(self) -> float:
        """FL training CPU power: big cluster at max frequency (paper §4.1:
        Perfetto traces show the task pinned to the big cluster at fmax)."""
        total_ma = (self.cpu_active_ma + self.cpu_cluster_ma
                    + self.big_cores * self.cpu_core_ma)
        return total_ma / 1000.0 * VOLTAGE_V

    @property
    def wifi_rx_power_w(self) -> float:
        return (self.wifi_active_ma + self.wifi_rx_ma) / 1000.0 * self.wifi_voltage_v

    @property
    def wifi_tx_power_w(self) -> float:
        return (self.wifi_active_ma + self.wifi_tx_ma) / 1000.0 * self.wifi_voltage_v


# Representative registry. Currents follow the shape of published
# power_profile.xml files (LineageOS / Pixel device trees); throughputs span
# flagship (~8 effective GFLOP/s) to entry-level (~0.8 GFLOP/s).
FLEET: Tuple[DeviceProfile, ...] = (
    DeviceProfile("pixel-7", "Tensor G2", 105, 320, 250, 4, 52, 110, 205, 3.85, 6.7, 0.06),
    DeviceProfile("pixel-3", "SDM845", 92, 285, 240, 4, 50, 100, 198, 3.85, 3.7, 0.05),
    DeviceProfile("galaxy-s21", "Exynos 2100", 110, 340, 265, 4, 55, 115, 210, 3.85, 5.9, 0.08),
    DeviceProfile("galaxy-a52", "SDM720G", 80, 210, 170, 2, 48, 95, 185, 3.80, 2.0, 0.13),
    DeviceProfile("redmi-note-11", "SDM680", 75, 195, 160, 4, 46, 92, 180, 3.80, 1.6, 0.15),
    DeviceProfile("galaxy-a13", "Exynos 850", 70, 165, 140, 4, 45, 90, 175, 3.80, 1.0, 0.14),
    DeviceProfile("moto-g-power", "SDM662", 72, 185, 150, 4, 46, 92, 178, 3.80, 1.3, 0.12),
    DeviceProfile("oneplus-9", "SD888", 108, 330, 260, 4, 54, 112, 208, 3.85, 6.3, 0.05),
    DeviceProfile("xiaomi-poco-x3", "SD732G", 82, 215, 175, 2, 48, 96, 188, 3.80, 2.2, 0.09),
    DeviceProfile("galaxy-j7", "Exynos 7870", 65, 150, 125, 4, 44, 88, 170, 3.80, 0.75, 0.07),
    DeviceProfile("pixel-6a", "Tensor G1", 100, 310, 245, 4, 51, 108, 200, 3.85, 5.6, 0.06),
)

assert abs(sum(p.weight for p in FLEET) - 1.0) < 1e-6

# country mix of FL participants (share of sessions); the paper weights
# energy by the carbon intensity of the connecting country.
COUNTRY_MIX: Dict[str, float] = {
    "US": 0.16, "IN": 0.14, "BR": 0.09, "ID": 0.07, "MX": 0.05,
    "DE": 0.05, "GB": 0.04, "FR": 0.04, "JP": 0.04, "PH": 0.04,
    "VN": 0.04, "TR": 0.03, "TH": 0.03, "EG": 0.03, "PK": 0.03,
    "NG": 0.02, "BD": 0.02, "IT": 0.02, "ES": 0.02, "PL": 0.02,
    "CA": 0.01, "AU": 0.01, "SE": 0.005, "NO": 0.005,
}
COUNTRY_MIX["OTHER"] = 0.02
_total = sum(COUNTRY_MIX.values())
COUNTRY_MIX = {k: v / _total for k, v in COUNTRY_MIX.items()}

# client uplink/downlink Wi-Fi goodput (bit/s) — residential broadband-ish
DOWNLOAD_BPS = 24e6
UPLOAD_BPS = 8e6
