"""Device + server energy models (paper §4.1–4.2).

Client session energy = CPU power x compute time + Wi-Fi rx power x download
time + Wi-Fi tx power x upload time (powers from power_profile.xml fields
via Watt's law at 3.8 V). Server energy = measured task power (45 W at the
conservatively assumed 1% utilization) x PUE x task duration, for each of
the two power-intensive components (Aggregator, Selector — the paper
conservatively assumes the Selector equals the Aggregator; the Coordinator
is negligible).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.core.carbon import PUE
from repro.core.profiles import DeviceProfile

SERVER_TASK_POWER_W = 45.0    # Aggregator @1% util (paper §4.2)
N_SERVER_COMPONENTS = 2       # Aggregator + Selector (equal, conservative)


@dataclass(frozen=True)
class SessionEnergy:
    compute_j: float
    upload_j: float      # device Wi-Fi tx only (network infra separate)
    download_j: float    # device Wi-Fi rx only

    @property
    def total_j(self) -> float:
        return self.compute_j + self.upload_j + self.download_j


def client_session_energy(profile: DeviceProfile, compute_s: float,
                          download_s: float, upload_s: float) -> SessionEnergy:
    return SessionEnergy(
        compute_j=profile.cpu_power_w * compute_s,
        upload_j=profile.wifi_tx_power_w * upload_s,
        download_j=profile.wifi_rx_power_w * download_s,
    )


def server_energy_j(task_duration_s: float, *, pue: float = PUE,
                    power_w: float = SERVER_TASK_POWER_W,
                    n_components: int = N_SERVER_COMPONENTS) -> float:
    return n_components * power_w * pue * task_duration_s


def compute_duration_s(flops: float, device_gflops: float) -> float:
    return flops / (device_gflops * 1e9)


def transfer_duration_s(num_bytes: float, bps: float) -> float:
    return 8.0 * num_bytes / bps
