"""TaskLog -> carbon footprint, per component (the paper's Figure 5 bars).

Components:
  client_compute  — CPU energy on phones, at the client country's intensity
  upload          — device Wi-Fi tx + uplink network-infrastructure path
  download        — device Wi-Fi rx + downlink network-infrastructure path
  server          — Aggregator+Selector x PUE, at the DC-weighted intensity

Network-infrastructure energy is attributed at the client country intensity
(the access/metro portion dominates the per-bit energy and sits near the
client). Dropped / timed-out sessions are charged for whatever they burned.

When the grid model carries diurnal schedules (time-varying intensity,
``IntensityModel.schedule``), every reduction path — vectorized
``batch_carbon``/``estimate``, the scalar ``session_carbon`` loop, and the
lane-pack ``lane_carbon`` — integrates energy x intensity(t) over each
session phase's time span instead of multiplying by one static value; flat
schedules keep the static fast path bit-for-bit. Server energy stays at
the (static) datacenter-weighted intensity — datacenters buy around-the-
clock supply, the paper's point being that *client* fleets cannot.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.carbon import IntensityModel
from repro.core.energy import SERVER_TASK_POWER_W, server_energy_j
from repro.core.network import DEFAULT_NETWORK, NetworkEnergyModel
from repro.core.profiles import FLEET, DeviceProfile
from repro.core.telemetry import (OUTCOME_CODE, ClientSession, SessionBatch,
                                  TaskLog)

_EXACT_CHUNK = 1 << 25


class ExactSum:
    """Error-free streaming float64 accumulator.

    Every float64 is an integer mantissa times a power of two, so a sum of
    floats is representable exactly as one (arbitrary-precision mantissa,
    binary exponent) pair. ``add`` folds an array in vectorized NumPy:
    ``frexp`` splits each value into a 53-bit integer mantissa and an
    exponent, the mantissa is split into 27-bit-high / 26-bit-low halves
    (so per-exponent-bin partial sums of <= 2^25 rows stay below 2^53 and
    ``np.bincount``'s float64 accumulation is exact), and the binned
    partials collapse into one big-int contribution. The running state is
    exact, so accumulation is associative and commutative: any chunking,
    lane segmentation, or merge order produces the **bit-identical**
    correctly-rounded ``value()``. This is what lets the streaming
    telemetry path reproduce the materialized reduction bit-for-bit.
    """

    __slots__ = ("_m", "_e")

    def __init__(self) -> None:
        self._m = 0  # arbitrary-precision mantissa; value = _m * 2**_e
        self._e = 0

    def add(self, x) -> "ExactSum":
        x = np.ascontiguousarray(x, dtype=np.float64).ravel()
        for lo in range(0, x.size, _EXACT_CHUNK):
            self._add_chunk(x[lo:lo + _EXACT_CHUNK])
        return self

    def _add_chunk(self, x: np.ndarray) -> None:
        x = x[x != 0.0]
        if not x.size:
            return
        if not np.isfinite(x).all():
            raise ValueError("ExactSum requires finite inputs")
        m, e = np.frexp(x)
        M = np.ldexp(m, 53).astype(np.int64)   # exact: |M| <= 2^53
        E = e.astype(np.int64) - 53
        hi = M >> 26                           # floor division (sign-safe)
        lo = M - (hi << 26)                    # in [0, 2^26)
        e0 = int(E.min())
        ebin = E - e0
        nb = int(ebin.max()) + 1
        sh = np.bincount(ebin, weights=hi.astype(np.float64), minlength=nb)
        sl = np.bincount(ebin, weights=lo.astype(np.float64), minlength=nb)
        tot = 0
        for b in np.flatnonzero((sh != 0.0) | (sl != 0.0)):
            tot += ((int(sh[b]) << 26) + int(sl[b])) << int(b)
        self._merge(tot, e0)

    def _merge(self, m2: int, e2: int) -> None:
        if m2 == 0:
            return
        if self._m == 0:
            self._m, self._e = m2, e2
        elif self._e <= e2:
            self._m += m2 << (e2 - self._e)
        else:
            self._m = (self._m << (self._e - e2)) + m2
            self._e = e2

    def merge(self, other: "ExactSum") -> "ExactSum":
        self._merge(other._m, other._e)
        return self

    def value(self) -> float:
        """Correctly-rounded float64 of the exact running sum."""
        if self._m == 0:
            return 0.0
        if self._e >= 0:
            return float(self._m << self._e)
        # CPython int/int true division is correctly rounded
        return self._m / (1 << -self._e)

    # ------------------------------------------------------------ snapshots
    _STATE_VERSION = 1

    def state(self) -> dict:
        """Version-tagged JSON-safe state. The mantissa is arbitrary
        precision, so it travels as a hex string; the round-trip through
        ``from_state`` is exact (same ``_m``/``_e``, hence the same
        correctly-rounded ``value()`` and the same future merges)."""
        return {"version": self._STATE_VERSION,
                "m": format(self._m, "x") if self._m >= 0
                else "-" + format(-self._m, "x"),
                "e": self._e}

    @classmethod
    def from_state(cls, state: Mapping) -> "ExactSum":
        v = state.get("version")
        if v != cls._STATE_VERSION:
            raise ValueError(
                f"unsupported ExactSum state version {v!r}; this build "
                f"reads version {cls._STATE_VERSION}")
        s = cls()
        s._m = int(state["m"], 16)
        s._e = int(state["e"])
        return s


def exact_sum(x) -> float:
    """One-shot correctly-rounded sum of a float64 array (see ExactSum)."""
    return ExactSum().add(x).value()


@dataclass(frozen=True)
class CarbonBreakdown:
    client_compute_kg: float
    upload_kg: float
    download_kg: float
    server_kg: float
    # contributed vs wasted split (the paper's over-commitment price):
    # contributed = completed sessions' client-side carbon + the server;
    # wasted = every non-completed session (dropped, timed out, cancelled,
    # failed, retried, interrupted) — work that burned carbon but never
    # aggregated. When populated, total_kg == contributed_kg + wasted_kg
    # by definition.
    contributed_kg: float = 0.0
    wasted_kg: float = 0.0
    # checkpoint/resume refinement of the waste: an interrupted session's
    # compute up to its last checkpoint is *salvaged* (a retry resumed
    # from it instead of redoing the work); everything else non-completed
    # is *lost*. wasted_kg == salvaged_kg + lost_kg exactly whenever a
    # checkpoint period was live (both are 0/waste otherwise).
    salvaged_kg: float = 0.0
    lost_kg: float = 0.0

    @property
    def total_kg(self) -> float:
        if self.contributed_kg or self.wasted_kg:
            return self.contributed_kg + self.wasted_kg
        return (self.client_compute_kg + self.upload_kg + self.download_kg
                + self.server_kg)

    def shares(self) -> Dict[str, float]:
        t = max(self.total_kg, 1e-18)
        return {
            "client_compute": self.client_compute_kg / t,
            "upload": self.upload_kg / t,
            "download": self.download_kg / t,
            "server": self.server_kg / t,
        }

    def as_dict(self) -> Dict[str, float]:
        return {
            "client_compute_kg": self.client_compute_kg,
            "upload_kg": self.upload_kg,
            "download_kg": self.download_kg,
            "server_kg": self.server_kg,
            "contributed_kg": self.contributed_kg,
            "wasted_kg": self.wasted_kg,
            "salvaged_kg": self.salvaged_kg,
            "lost_kg": self.lost_kg,
            "total_kg": self.total_kg,
        }


@dataclass
class CarbonEstimator:
    """TaskLog -> CarbonBreakdown under a fully explicit environment: the
    network energy model, device-profile registry, grid-intensity model and
    server power are all instance state — nothing on the estimation path
    reads module-level defaults (construct via ``repro.api.Environment`` to
    swap any of them)."""

    network: NetworkEnergyModel = field(default_factory=lambda: DEFAULT_NETWORK)
    profiles: Dict[str, DeviceProfile] = field(
        default_factory=lambda: {p.name: p for p in FLEET})
    intensity: IntensityModel = field(default_factory=IntensityModel)
    server_power_w: float = SERVER_TASK_POWER_W

    def session_carbon(self, s: ClientSession) -> Dict[str, float]:
        """Per-session component kg — ``_kg_rows`` batch-of-1, so the scalar
        path shares the per-phase span-mean intensity logic (download ->
        compute -> upload back to back from ``start_t``) with every
        vectorized reduction instead of re-implementing it."""
        b = SessionBatch.from_sessions([s])
        kg = _kg_rows(self, b.device_names, b.device_idx, b.country_names,
                      b.country_idx, b.compute_s, b.upload_s, b.download_s,
                      b.bytes_up, b.bytes_down, b.start_t)
        return {"client_compute_kg": float(kg[0, 0]),
                "upload_kg": float(kg[1, 0]),
                "download_kg": float(kg[2, 0])}

    def batch_carbon(self, b: SessionBatch,
                     checkpoint_period_s: float = 0.0) -> Dict[str, float]:
        """Fig. 5 component sums for a whole SessionBatch via group-by-
        device/country array reductions (no per-session loop). The three
        component energies land in one (3, n) matrix so the grid-intensity
        conversion is a single fused pass instead of three, and dropped/
        timed-out/cancelled rows need no masks — their truncated durations
        and prorated bytes already carry the burned-energy accounting.

        With ``checkpoint_period_s`` > 0 (availability churn + resume
        live), interrupted rows' compute waste splits at the last
        checkpoint into salvaged vs lost (``_salvage_kg``); otherwise
        salvaged is 0 and lost == waste bit-for-bit."""
        if not len(b):
            return {"client_compute_kg": 0.0, "upload_kg": 0.0,
                    "download_kg": 0.0, "ok_kg": 0.0, "waste_kg": 0.0,
                    "salvaged_kg": 0.0, "lost_kg": 0.0}
        kg = _kg_rows(self, b.device_names, b.device_idx, b.country_names,
                      b.country_idx, b.compute_s, b.upload_s, b.download_s,
                      b.bytes_up, b.bytes_down, b.start_t)
        # error-free sums: the result is the correctly-rounded true sum,
        # independent of row order or chunking — which is exactly what lets
        # the streaming telemetry fold reproduce this path bit-for-bit.
        # ok/waste split the same rows by completion (wasted work: dropped,
        # timed out, cancelled, failed, retried, interrupted) — same
        # exactness contract.
        okm = b.completed_mask
        out = {"client_compute_kg": exact_sum(kg[0]),
               "upload_kg": exact_sum(kg[1]),
               "download_kg": exact_sum(kg[2]),
               "ok_kg": exact_sum(kg[:, okm])}
        P = float(checkpoint_period_s)
        im = (b.outcome == OUTCOME_CODE["interrupted"]) if P > 0 else None
        if im is None or not im.any():
            w = exact_sum(kg[:, ~okm])
            out.update(waste_kg=w, salvaged_kg=0.0, lost_kg=w)
            return out
        iw = np.flatnonzero(im)
        salv_kg, tail_kg = _salvage_kg(
            self, b.device_names, b.device_idx[iw], b.country_names,
            b.country_idx[iw], b.compute_s[iw], b.download_s[iw],
            b.start_t[iw], P)
        ow = ~okm & ~im
        salv = exact_sum(salv_kg)
        lost = ExactSum().add(tail_kg).add(kg[1, iw]).add(kg[2, iw]) \
            .add(kg[:, ow]).value()
        # waste == salvaged + lost exactly (one well-defined float add)
        out.update(waste_kg=salv + lost, salvaged_kg=salv, lost_kg=lost)
        return out

    def _server_kg_s(self, duration_s: float) -> float:
        srv_j = server_energy_j(duration_s, pue=self.intensity.pue,
                                power_w=self.server_power_w)
        return self.intensity.co2e_kg(srv_j,
                                      self.intensity.datacenter_intensity())

    def _server_kg(self, log: TaskLog) -> float:
        return self._server_kg_s(log.duration_s)

    def estimate(self, log: TaskLog) -> CarbonBreakdown:
        # streaming logs carry exact running component sums — consult them
        # FIRST: their columns() view is a reservoir *sample*, so reducing
        # it here would silently undercount
        comp = getattr(log, "carbon_components", None)
        if comp is not None:
            d = comp(self)
        else:
            d = self.batch_carbon(
                log.columns() if hasattr(log, "columns")
                else SessionBatch.from_sessions(log.sessions),
                checkpoint_period_s=getattr(log, "checkpoint_period_s",
                                            0.0))
        srv = self._server_kg(log)
        return CarbonBreakdown(d["client_compute_kg"], d["upload_kg"],
                               d["download_kg"], srv,
                               contributed_kg=d.get("ok_kg", 0.0) + srv,
                               wasted_kg=d.get("waste_kg", 0.0),
                               salvaged_kg=d.get("salvaged_kg", 0.0),
                               lost_kg=d.get("lost_kg",
                                             d.get("waste_kg", 0.0)))

    def estimate_scalar(self, log: TaskLog) -> CarbonBreakdown:
        """Per-session reference loop — equivalence-test and benchmark twin
        of the vectorized ``estimate`` (including the checkpoint salvage
        split, via ``_salvage_kg`` batch-of-1)."""
        P = float(getattr(log, "checkpoint_period_s", 0.0))
        cc = up = dn = okk = salv = lost = 0.0
        for s in log.sessions:
            d = self.session_carbon(s)
            cc += d["client_compute_kg"]
            up += d["upload_kg"]
            dn += d["download_kg"]
            row = d["client_compute_kg"] + d["upload_kg"] + d["download_kg"]
            if s.completed:
                okk += row
            elif P > 0 and s.outcome == "interrupted":
                b = SessionBatch.from_sessions([s])
                sk, tk = _salvage_kg(self, b.device_names, b.device_idx,
                                     b.country_names, b.country_idx,
                                     b.compute_s, b.download_s, b.start_t,
                                     P)
                salv += float(sk[0])
                lost += float(tk[0]) + d["upload_kg"] + d["download_kg"]
            else:
                lost += row
        srv = self._server_kg(log)
        return CarbonBreakdown(cc, up, dn, srv, contributed_kg=okk + srv,
                               wasted_kg=salv + lost, salvaged_kg=salv,
                               lost_kg=lost)


def _kg_rows(est: CarbonEstimator, device_names, device_idx, country_names,
             country_idx, compute_s, upload_s, download_s, bytes_up,
             bytes_down, start_t, with_energy: bool = False) -> np.ndarray:
    """Per-row (3, n) kg matrix — rows: client_compute / upload / download.
    With ``with_energy=True`` also returns the (3, n) joules matrix (the
    streaming telemetry fold reuses it for grouped energy sums — one
    implementation of the per-phase span-mean logic, per the bit-for-bit
    contract).
    ``co2e_kg`` is plain arithmetic, so it broadcasts over the per-row
    energy/intensity columns — IntensityModel overrides stay honored.
    (Lane packs with differing network/intensity models are handled by
    calling this once per lane with that lane's estimator.)

    With a time-varying intensity schedule, each energy row is charged the
    mean intensity over its own phase span (sessions run download ->
    compute -> upload back to back from ``start_t``); the static path is
    untouched, so flat-schedule models stay bit-for-bit identical."""
    profs = [est.profiles[n] for n in device_names]
    cpu_w = np.asarray([p.cpu_power_w for p in profs])[device_idx]
    tx_w = np.asarray([p.wifi_tx_power_w for p in profs])[device_idx]
    rx_w = np.asarray([p.wifi_rx_power_w for p in profs])[device_idx]
    epb = est.network.energy_per_bit_j
    n = len(device_idx)
    e = np.empty((3, n))
    e[0] = cpu_w * compute_s
    e[1] = tx_w * upload_s + 8.0 * bytes_up * epb
    e[2] = rx_w * download_s + 8.0 * bytes_down * epb
    tab = est.intensity.vocab_schedule(tuple(country_names))
    if not tab.any_dynamic:
        ci = tab.static[country_idx]
        kg = est.intensity.co2e_kg(e, ci)
        return (kg, e) if with_energy else kg
    a1 = start_t + download_s
    a2 = a1 + compute_s
    ci3 = np.empty((3, n))
    ci3[0] = tab.mean(country_idx, a1, a2)
    ci3[1] = tab.mean(country_idx, a2, a2 + upload_s)
    ci3[2] = tab.mean(country_idx, start_t, a1)
    kg = est.intensity.co2e_kg(e, ci3)
    return (kg, e) if with_energy else kg


def _salvage_kg(est: CarbonEstimator, device_names, device_idx,
                country_names, country_idx, compute_s, download_s, start_t,
                period_s: float) -> Tuple[np.ndarray, np.ndarray]:
    """Checkpoint split of interrupted rows' burned compute carbon:
    ``floor(burned / P) * P`` seconds of compute survived to the last
    checkpoint (salvaged — a resume reused it), the remainder is lost.
    Under a diurnal grid each part is charged the mean intensity over its
    own sub-span of the compute phase, mirroring ``_kg_rows``'s phase
    integration — a row with zero salvage reproduces its ``_kg_rows``
    compute entry bit-for-bit (``c - 0.0 == c``, same span mean). Returns
    per-row ``(salvaged_kg, lost_kg)`` arrays; row-pure, so any blocking
    (streaming folds, lane segments, batch-of-1 scalar) agrees exactly."""
    profs = [est.profiles[n] for n in device_names]
    cpu_w = np.asarray([p.cpu_power_w for p in profs])[device_idx]
    salv_s = np.floor(compute_s / period_s) * period_s
    e_salv = cpu_w * salv_s
    e_tail = cpu_w * (compute_s - salv_s)
    tab = est.intensity.vocab_schedule(tuple(country_names))
    if not tab.any_dynamic:
        ci = tab.static[country_idx]
        return (est.intensity.co2e_kg(e_salv, ci),
                est.intensity.co2e_kg(e_tail, ci))
    a1 = start_t + download_s
    am = a1 + salv_s
    a2 = a1 + compute_s
    return (est.intensity.co2e_kg(e_salv, tab.mean(country_idx, a1, am)),
            est.intensity.co2e_kg(e_tail, tab.mean(country_idx, am, a2)))


def lane_carbon(cols: Dict[str, np.ndarray], lane: np.ndarray,
                estimators: Sequence[CarbonEstimator],
                device_names: Sequence[Tuple[str, ...]],
                country_names: Sequence[Tuple[str, ...]],
                durations_s: Sequence[float],
                checkpoint_period_s: Optional[Sequence[float]] = None
                ) -> List[CarbonBreakdown]:
    """Per-lane CarbonBreakdowns from one shared lane-columnar session
    store (the lane-batched sweep engine's ``LaneAccumulator``), as
    segment reductions over the lane-sorted columns instead of S
    independent estimator passes.

    One stable argsort groups the rows by lane; each lane's contiguous
    segment then goes through its own estimator's ``_kg_rows`` +
    ``exact_sum``. Exact summation is order-independent, so each lane's
    segment reduction matches the per-lane ``batch_carbon`` result
    bit-for-bit by construction — the lane-equivalence invariant
    (lane-batched == serial, seed for seed) needs no summation-order
    gymnastics. Per-lane estimators may differ in any Environment knob —
    profiles, intensity tables, network model, PUE, server power.
    ``checkpoint_period_s`` carries each lane's effective salvage period
    (0 disables the split — lost == waste, like ``batch_carbon``)."""
    order = np.argsort(lane, kind="stable")
    bounds = np.searchsorted(lane[order], np.arange(len(estimators) + 1))
    dev_s = cols["device_idx"][order]
    ctry_s = cols["country_idx"][order]
    comp_s = cols["compute_s"][order]
    up_s = cols["upload_s"][order]
    down_s = cols["download_s"][order]
    bu_s = cols["bytes_up"][order]
    bd_s = cols["bytes_down"][order]
    st_s = cols["start_t"][order]
    out_s = cols["outcome"][order]
    out: List[CarbonBreakdown] = []
    for i, est in enumerate(estimators):
        sl = slice(int(bounds[i]), int(bounds[i + 1]))
        srv = est._server_kg_s(durations_s[i])
        P = float(checkpoint_period_s[i]) if checkpoint_period_s else 0.0
        if sl.start == sl.stop:
            out.append(CarbonBreakdown(0.0, 0.0, 0.0, srv,
                                       contributed_kg=srv, wasted_kg=0.0))
            continue
        kg = _kg_rows(est, device_names[i], dev_s[sl], country_names[i],
                      ctry_s[sl], comp_s[sl], up_s[sl], down_s[sl],
                      bu_s[sl], bd_s[sl], st_s[sl])
        okm = out_s[sl] == 0  # OUTCOME_CODE["completed"]
        im = (out_s[sl] == OUTCOME_CODE["interrupted"]) if P > 0 else None
        if im is None or not im.any():
            w = exact_sum(kg[:, ~okm])
            out.append(CarbonBreakdown(
                exact_sum(kg[0]), exact_sum(kg[1]), exact_sum(kg[2]), srv,
                contributed_kg=exact_sum(kg[:, okm]) + srv,
                wasted_kg=w, lost_kg=w))
            continue
        iw = np.flatnonzero(im)
        salv_kg, tail_kg = _salvage_kg(
            est, device_names[i], dev_s[sl][iw], country_names[i],
            ctry_s[sl][iw], comp_s[sl][iw], down_s[sl][iw], st_s[sl][iw],
            P)
        ow = ~okm & ~im
        salv = exact_sum(salv_kg)
        lost = ExactSum().add(tail_kg).add(kg[1, iw]).add(kg[2, iw]) \
            .add(kg[:, ow]).value()
        out.append(CarbonBreakdown(
            exact_sum(kg[0]), exact_sum(kg[1]), exact_sum(kg[2]), srv,
            contributed_kg=exact_sum(kg[:, okm]) + srv,
            wasted_kg=salv + lost, salvaged_kg=salv, lost_kg=lost))
    return out
