"""TaskLog -> carbon footprint, per component (the paper's Figure 5 bars).

Components:
  client_compute  — CPU energy on phones, at the client country's intensity
  upload          — device Wi-Fi tx + uplink network-infrastructure path
  download        — device Wi-Fi rx + downlink network-infrastructure path
  server          — Aggregator+Selector x PUE, at the DC-weighted intensity

Network-infrastructure energy is attributed at the client country intensity
(the access/metro portion dominates the per-bit energy and sits near the
client). Dropped / timed-out sessions are charged for whatever they burned.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core.carbon import IntensityModel
from repro.core.energy import (SERVER_TASK_POWER_W, client_session_energy,
                               server_energy_j)
from repro.core.network import DEFAULT_NETWORK, NetworkEnergyModel
from repro.core.profiles import FLEET, DeviceProfile
from repro.core.telemetry import ClientSession, SessionBatch, TaskLog


@dataclass(frozen=True)
class CarbonBreakdown:
    client_compute_kg: float
    upload_kg: float
    download_kg: float
    server_kg: float

    @property
    def total_kg(self) -> float:
        return (self.client_compute_kg + self.upload_kg + self.download_kg
                + self.server_kg)

    def shares(self) -> Dict[str, float]:
        t = max(self.total_kg, 1e-18)
        return {
            "client_compute": self.client_compute_kg / t,
            "upload": self.upload_kg / t,
            "download": self.download_kg / t,
            "server": self.server_kg / t,
        }

    def as_dict(self) -> Dict[str, float]:
        return {
            "client_compute_kg": self.client_compute_kg,
            "upload_kg": self.upload_kg,
            "download_kg": self.download_kg,
            "server_kg": self.server_kg,
            "total_kg": self.total_kg,
        }


@dataclass
class CarbonEstimator:
    """TaskLog -> CarbonBreakdown under a fully explicit environment: the
    network energy model, device-profile registry, grid-intensity model and
    server power are all instance state — nothing on the estimation path
    reads module-level defaults (construct via ``repro.api.Environment`` to
    swap any of them)."""

    network: NetworkEnergyModel = field(default_factory=lambda: DEFAULT_NETWORK)
    profiles: Dict[str, DeviceProfile] = field(
        default_factory=lambda: {p.name: p for p in FLEET})
    intensity: IntensityModel = field(default_factory=IntensityModel)
    server_power_w: float = SERVER_TASK_POWER_W

    def session_carbon(self, s: ClientSession) -> Dict[str, float]:
        prof = self.profiles[s.device]
        e = client_session_energy(prof, s.compute_s, s.download_s, s.upload_s)
        ci = self.intensity.intensity(s.country)
        net_up_j = self.network.transfer_energy_j(s.bytes_up)
        net_down_j = self.network.transfer_energy_j(s.bytes_down)
        co2e = self.intensity.co2e_kg
        return {
            "client_compute_kg": co2e(e.compute_j, ci),
            "upload_kg": co2e(e.upload_j + net_up_j, ci),
            "download_kg": co2e(e.download_j + net_down_j, ci),
        }

    def batch_carbon(self, b: SessionBatch) -> Dict[str, float]:
        """Fig. 5 component sums for a whole SessionBatch via group-by-
        device/country array reductions (no per-session loop)."""
        if not len(b):
            return {"client_compute_kg": 0.0, "upload_kg": 0.0,
                    "download_kg": 0.0}
        profs = [self.profiles[n] for n in b.device_names]
        cpu_w = np.asarray([p.cpu_power_w for p in profs])[b.device_idx]
        tx_w = np.asarray([p.wifi_tx_power_w for p in profs])[b.device_idx]
        rx_w = np.asarray([p.wifi_rx_power_w for p in profs])[b.device_idx]
        ci = np.asarray([self.intensity.intensity(c)
                         for c in b.country_names])[b.country_idx]
        epb = self.network.energy_per_bit_j
        # co2e_kg is plain arithmetic, so it broadcasts over the per-row
        # energy/intensity columns — IntensityModel overrides stay honored
        co2e = self.intensity.co2e_kg
        return {
            "client_compute_kg": float(
                co2e(cpu_w * b.compute_s, ci).sum()),
            "upload_kg": float(
                co2e(tx_w * b.upload_s + 8.0 * b.bytes_up * epb, ci).sum()),
            "download_kg": float(
                co2e(rx_w * b.download_s + 8.0 * b.bytes_down * epb,
                     ci).sum()),
        }

    def _server_kg(self, log: TaskLog) -> float:
        srv_j = server_energy_j(log.duration_s, pue=self.intensity.pue,
                                power_w=self.server_power_w)
        return self.intensity.co2e_kg(srv_j,
                                      self.intensity.datacenter_intensity())

    def estimate(self, log: TaskLog) -> CarbonBreakdown:
        d = self.batch_carbon(log.columns() if hasattr(log, "columns")
                              else SessionBatch.from_sessions(log.sessions))
        return CarbonBreakdown(d["client_compute_kg"], d["upload_kg"],
                               d["download_kg"], self._server_kg(log))

    def estimate_scalar(self, log: TaskLog) -> CarbonBreakdown:
        """Per-session reference loop — equivalence-test and benchmark twin
        of the vectorized ``estimate``."""
        cc = up = dn = 0.0
        for s in log.sessions:
            d = self.session_carbon(s)
            cc += d["client_compute_kg"]
            up += d["upload_kg"]
            dn += d["download_kg"]
        return CarbonBreakdown(cc, up, dn, self._server_kg(log))
