"""Fault injection: time-varying failure hazards + correlated burst events.

The paper's production setting is millions of flaky phones: a device is
only eligible while idle, charging and on unmetered wifi, so sessions die
in *correlated* waves (morning unplug ramps, regional outages) rather
than i.i.d. — and every failed attempt still burned energy that the
estimator must charge. ``FaultModel`` describes that failure process for
an ``Environment``:

* **hazard** — per-country probability that a session fails mid-flight,
  optionally time-varying: ``hazard_schedule`` maps countries to
  piecewise-constant 24 h curves with ``hazard_phase_h`` UTC offsets,
  reusing the intensity-schedule machinery from ``repro.core.carbon``
  verbatim (same segment lookup, same constant-schedule collapse), so
  failure waves can anti-correlate with low-carbon hours.
* **bursts** — a deterministic jittered sequence of outage windows
  (``burst_rate_per_day`` per day, each ``burst_duration_s`` long) drawn
  from the model's own splitmix64 counter stream; any session whose span
  overlaps a window fails with ``burst_fail_prob`` at the moment the
  burst hits it.

Everything is a pure function of the model's fields — burst windows of
``seed``, per-session failure draws of the engine's ``(seed, client_id,
round)`` counters in ``federated.events`` — so the seed-for-seed oracle,
lane packing and streaming telemetry all survive bit-for-bit, and an
all-zero model is exactly today's fault-free engine.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Mapping, Sequence, Tuple

import numpy as np

from repro.core.carbon import SECONDS_PER_DAY, IntensityModel, _VocabSchedule

_M64 = (1 << 64) - 1
_U64 = np.uint64
# burst-window lane spacing — distinct from every stream constant in
# federated.events, so burst times never alias session/probe/retry draws
_BURST_MIX = 0x9FB21C651E98DF25

# Canonical morning-unplug hazard shape: multiplier on the base hazard per
# 3-hour segment starting at local midnight. Overnight (charging, idle)
# is quiet; the 06:00-12:00 unplug wave peaks; evening recovers.
HAZARD_SHAPE: Tuple[float, ...] = (0.3, 0.2, 1.6, 2.4, 1.4, 0.8, 0.6, 0.7)


def _splitmix64_arr(x: np.ndarray) -> np.ndarray:
    """Vectorized splitmix64 (bit-identical to ``federated.events``; kept
    local so core never imports the federated layer)."""
    x = x + _U64(0x9E3779B97F4A7C15)
    x = (x ^ (x >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> _U64(27))) * _U64(0x94D049BB133111EB)
    return x ^ (x >> _U64(31))


def wave_hazard_schedule(countries: Sequence[str], base: float = 0.05,
                         shape: Sequence[float] = HAZARD_SHAPE
                         ) -> Dict[str, Tuple[float, ...]]:
    """Default diurnal hazard curves: ``base`` swung through ``shape``
    per country (pair with ``carbon.UTC_OFFSET_H`` phases so the unplug
    wave lands at local morning)."""
    return {c: tuple(base * s for s in shape) for c in countries}


def _check_prob(name: str, v: float) -> None:
    if not 0.0 <= float(v) <= 1.0:
        raise ValueError(f"FaultModel.{name} must be a probability in "
                         f"[0, 1], got {v!r}")


@dataclass(frozen=True)
class FaultModel:
    """Per-country failure hazard (static table + optional diurnal
    schedules) plus correlated burst outages. All-zero (the default) is
    bit-for-bit the fault-free engine."""

    hazard: Mapping[str, float] = field(default_factory=dict)
    hazard_schedule: Mapping[str, Sequence[float]] = field(
        default_factory=dict)
    hazard_phase_h: Mapping[str, float] = field(default_factory=dict)
    burst_rate_per_day: float = 0.0
    burst_duration_s: float = 3600.0
    burst_fail_prob: float = 0.0
    seed: int = 0
    horizon_days: float = 60.0       # burst windows are materialized up to
    #                                  this task-clock horizon
    # private caches (hazard lookup tables, burst windows) — excluded from
    # equality so two equal models compare equal regardless of use
    _cache: Dict = field(default_factory=dict, init=False, repr=False,
                         compare=False)

    def __post_init__(self):
        for c, v in self.hazard.items():
            _check_prob(f"hazard[{c!r}]", v)
        for c, vals in self.hazard_schedule.items():
            if not len(vals):
                raise ValueError(
                    f"FaultModel.hazard_schedule[{c!r}] is empty")
            for v in vals:
                _check_prob(f"hazard_schedule[{c!r}]", v)
        _check_prob("burst_fail_prob", self.burst_fail_prob)
        if self.burst_rate_per_day < 0:
            raise ValueError("FaultModel.burst_rate_per_day must be >= 0, "
                             f"got {self.burst_rate_per_day!r}")
        if self.burst_duration_s < 0:
            raise ValueError("FaultModel.burst_duration_s must be >= 0, "
                             f"got {self.burst_duration_s!r}")
        if self.horizon_days <= 0:
            raise ValueError("FaultModel.horizon_days must be > 0, "
                             f"got {self.horizon_days!r}")

    # ----------------------------------------------------------- predicates
    @property
    def enabled(self) -> bool:
        """True iff the model can actually fail a session; disabled models
        take the engines' fault-free fast path untouched."""
        return (any(v > 0 for v in self.hazard.values())
                or any(any(x > 0 for x in vals)
                       for vals in self.hazard_schedule.values())
                or (self.burst_rate_per_day > 0
                    and self.burst_fail_prob > 0
                    and self.burst_duration_s > 0))

    # -------------------------------------------------------- hazard lookup
    def _hazard_model(self) -> IntensityModel:
        model = self._cache.get("model")
        if model is None:
            table = {str(k): float(v) for k, v in self.hazard.items()}
            table.setdefault("WORLD", 0.0)   # unlisted countries: no hazard
            model = IntensityModel(
                table=table, datacenter_locations={},
                schedule=dict(self.hazard_schedule),
                phase_h=dict(self.hazard_phase_h))
            self._cache["model"] = model
        return model

    def hazard_table(self, names: Sequence[str]) -> _VocabSchedule:
        """Compiled per-vocabulary hazard lookup — the same piecewise
        schedule machinery the intensity model uses (point lookups via
        ``at``, constant schedules collapsed to statics), cached per
        country vocabulary."""
        return self._hazard_model().vocab_schedule(tuple(names))

    # -------------------------------------------------------- burst windows
    def burst_windows(self) -> Tuple[np.ndarray, np.ndarray]:
        """(starts, ends) of every outage window up to ``horizon_days``:
        window k opens at ``(k + u_k) * mean_spacing`` with ``u_k`` the
        k-th draw of the model-seed splitmix stream — starts are strictly
        increasing, so a searchsorted finds the first overlap."""
        bw = self._cache.get("bursts")
        if bw is None:
            if (self.burst_rate_per_day <= 0 or self.burst_fail_prob <= 0
                    or self.burst_duration_s <= 0):
                z = np.zeros(0, np.float64)
                bw = (z, z)
            else:
                n = int(math.ceil(self.horizon_days
                                  * self.burst_rate_per_day))
                base = _U64(((self.seed & 0xFFFFFFFF) * 0x9E3779B9
                             + 0x7F4A7C15) & _M64)
                with np.errstate(over="ignore"):
                    h = _splitmix64_arr(
                        base + np.arange(n, dtype=np.uint64)
                        * _U64(_BURST_MIX))
                u = (h >> _U64(11)).astype(np.float64) / float(1 << 53)
                spacing = SECONDS_PER_DAY / self.burst_rate_per_day
                starts = (np.arange(n, dtype=np.float64) + u) * spacing
                bw = (starts, starts + self.burst_duration_s)
            self._cache["bursts"] = bw
        return bw

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        out: dict = {}
        if self.hazard:
            out["hazard"] = {k: float(v) for k, v in self.hazard.items()}
        if self.hazard_schedule:
            out["hazard_schedule"] = {
                k: [float(x) for x in v]
                for k, v in self.hazard_schedule.items()}
        if self.hazard_phase_h:
            out["hazard_phase_h"] = {k: float(v) for k, v
                                     in self.hazard_phase_h.items()}
        for f, default in (("burst_rate_per_day", 0.0),
                           ("burst_duration_s", 3600.0),
                           ("burst_fail_prob", 0.0),
                           ("seed", 0), ("horizon_days", 60.0)):
            v = getattr(self, f)
            if v != default:
                out[f] = v
        return out

    @classmethod
    def from_dict(cls, d) -> "FaultModel":
        if not d:
            return cls()
        d = dict(d)
        if "hazard_schedule" in d:
            d["hazard_schedule"] = {k: tuple(v) for k, v
                                    in d["hazard_schedule"].items()}
        return cls(**d)
