"""The client-runtime "logger" (paper §4.1) as a columnar data model.

Each FL session produces the vitals the paper's production logger captures:
device model, connecting country, download/compute/upload durations, bytes
moved, and the outcome (completed, dropped mid-round, timed out at 4
minutes, or cancelled because the task itself ended while the session was
in flight). Dropped/timed-out/cancelled clients still burned energy — the
estimator charges them (paper: "our methodology also accounts for the
clients that drop out or time out").

Storage is struct-of-arrays: strategies append one ``SessionBatch`` (a
bundle of NumPy columns plus small device/country vocabularies) per round
or per flush, and the estimator reduces whole columns at once. The
row-oriented ``ClientSession`` dataclass survives as a compatibility view —
``TaskLog.sessions`` lazily materialises it on demand — so telemetry
consumers that want objects still get them, while the hot path never
allocates per-session Python objects.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

# Append-only: codes are positional and live in persisted telemetry.
# "failed" = killed by the fault model (hazard or burst); "retried" = a
# failed attempt whose slot was re-dispatched (the retry is its own row);
# "interrupted" = the device exited availability-model eligibility (refused
# at admission, or churned mid-flight) — kept distinct from "failed" even
# when re-dispatched, because the checkpoint/resume salvage accounting
# needs to find these rows at estimate time.
OUTCOMES: Tuple[str, ...] = ("completed", "dropped", "timeout", "cancelled",
                             "failed", "retried", "interrupted")
OUTCOME_CODE: Dict[str, int] = {name: i for i, name in enumerate(OUTCOMES)}


@dataclass(frozen=True)
class ClientSession:
    """Row-oriented compatibility view of one session (see module doc)."""

    client_id: int
    round_idx: int               # sync round (async: server version at start)
    device: str                  # DeviceProfile.name
    country: str
    download_s: float
    compute_s: float
    upload_s: float
    bytes_down: float
    bytes_up: float
    start_t: float               # task clock, seconds
    end_t: float
    outcome: str                 # one of OUTCOMES
    staleness: int = 0           # async: server updates since model was sent

    @property
    def completed(self) -> bool:
        return self.outcome == "completed"


_FLOAT_COLS = ("download_s", "compute_s", "upload_s", "bytes_down",
               "bytes_up", "start_t", "end_t")


@dataclass(frozen=True)
class SessionBatch:
    """A cohort of sessions as columns. ``device_names``/``country_names``
    are per-batch vocabularies indexed by ``device_idx``/``country_idx``
    (strings stay out of the big arrays)."""

    device_names: Tuple[str, ...]
    country_names: Tuple[str, ...]
    client_id: np.ndarray        # int64
    round_idx: np.ndarray        # int64
    device_idx: np.ndarray       # int32 -> device_names
    country_idx: np.ndarray      # int32 -> country_names
    download_s: np.ndarray       # float64, seconds
    compute_s: np.ndarray
    upload_s: np.ndarray
    bytes_down: np.ndarray       # float64, bytes charged (prorated on drop)
    bytes_up: np.ndarray
    start_t: np.ndarray          # task clock, seconds
    end_t: np.ndarray
    outcome: np.ndarray          # int8 -> OUTCOMES
    staleness: np.ndarray        # int32

    def __len__(self) -> int:
        return int(self.client_id.shape[0])

    @property
    def completed_mask(self) -> np.ndarray:
        return self.outcome == OUTCOME_CODE["completed"]

    # ------------------------------------------------------------- builders
    @classmethod
    def empty(cls) -> "SessionBatch":
        z = np.zeros(0, np.float64)
        return cls((), (), np.zeros(0, np.int64), np.zeros(0, np.int64),
                   np.zeros(0, np.int32), np.zeros(0, np.int32),
                   z, z.copy(), z.copy(), z.copy(), z.copy(), z.copy(),
                   z.copy(), np.zeros(0, np.int8), np.zeros(0, np.int32))

    @classmethod
    def from_sessions(cls, sessions: Sequence[ClientSession]) -> "SessionBatch":
        if not sessions:
            return cls.empty()
        dev_vocab: Dict[str, int] = {}
        ctry_vocab: Dict[str, int] = {}
        dev_idx = np.fromiter(
            (dev_vocab.setdefault(s.device, len(dev_vocab)) for s in sessions),
            np.int32, len(sessions))
        ctry_idx = np.fromiter(
            (ctry_vocab.setdefault(s.country, len(ctry_vocab))
             for s in sessions), np.int32, len(sessions))
        cols = {c: np.asarray([getattr(s, c) for s in sessions], np.float64)
                for c in _FLOAT_COLS}
        return cls(
            tuple(dev_vocab), tuple(ctry_vocab),
            np.asarray([s.client_id for s in sessions], np.int64),
            np.asarray([s.round_idx for s in sessions], np.int64),
            dev_idx, ctry_idx,
            outcome=np.asarray([OUTCOME_CODE[s.outcome] for s in sessions],
                               np.int8),
            staleness=np.asarray([s.staleness for s in sessions], np.int32),
            **cols)

    @classmethod
    def concat(cls, batches: Sequence["SessionBatch"]) -> "SessionBatch":
        batches = [b for b in batches if len(b)]
        if not batches:
            return cls.empty()
        if len(batches) == 1:
            return batches[0]
        dev_vocab: Dict[str, int] = {}
        ctry_vocab: Dict[str, int] = {}
        dev_parts, ctry_parts = [], []
        for b in batches:
            dmap = np.asarray([dev_vocab.setdefault(n, len(dev_vocab))
                               for n in b.device_names], np.int32)
            cmap = np.asarray([ctry_vocab.setdefault(n, len(ctry_vocab))
                               for n in b.country_names], np.int32)
            dev_parts.append(dmap[b.device_idx] if len(dmap)
                             else b.device_idx)
            ctry_parts.append(cmap[b.country_idx] if len(cmap)
                              else b.country_idx)
        cat = np.concatenate
        return cls(
            tuple(dev_vocab), tuple(ctry_vocab),
            cat([b.client_id for b in batches]),
            cat([b.round_idx for b in batches]),
            cat(dev_parts), cat(ctry_parts),
            outcome=cat([b.outcome for b in batches]),
            staleness=cat([b.staleness for b in batches]),
            **{c: cat([getattr(b, c) for b in batches])
               for c in _FLOAT_COLS})

    # ----------------------------------------------------------------- view
    def to_sessions(self) -> List[ClientSession]:
        dn, cn = self.device_names, self.country_names
        return [ClientSession(
            client_id=int(self.client_id[i]),
            round_idx=int(self.round_idx[i]),
            device=dn[self.device_idx[i]],
            country=cn[self.country_idx[i]],
            download_s=float(self.download_s[i]),
            compute_s=float(self.compute_s[i]),
            upload_s=float(self.upload_s[i]),
            bytes_down=float(self.bytes_down[i]),
            bytes_up=float(self.bytes_up[i]),
            start_t=float(self.start_t[i]),
            end_t=float(self.end_t[i]),
            outcome=OUTCOMES[self.outcome[i]],
            staleness=int(self.staleness[i])) for i in range(len(self))]


_ACC_DTYPES = {"client_id": np.int64, "round_idx": np.int64,
               "device_idx": np.int32, "country_idx": np.int32,
               "download_s": np.float64, "compute_s": np.float64,
               "upload_s": np.float64, "bytes_down": np.float64,
               "bytes_up": np.float64, "start_t": np.float64,
               "end_t": np.float64, "outcome": np.int8,
               "staleness": np.int32}


class BatchAccumulator:
    """Arrival-ordered columnar batch assembly for strategies that log in
    windows: ``append`` is O(1) (it keeps block references), and
    consolidation lazily writes every block exactly once into
    amortized-doubling preallocated buffers sized exactly on first use —
    so repeated ``to_batch`` calls re-copy only the blocks appended since
    the last one, instead of re-concatenating every column from scratch
    each time (the old list+``np.concatenate`` scheme made window-per-
    window accumulation with periodic snapshots quadratic). Snapshots are
    copy-on-write: handing out buffer views freezes the store, and the
    next consolidation reallocates rather than mutating what a caller
    holds. Appended blocks are adopted — callers must not mutate them
    afterwards (both engines hand over freshly built arrays)."""

    # subclasses may ride extra columns in the same buffers (LaneAccumulator)
    _EXTRA_DTYPES: Dict[str, type] = {}

    def __init__(self, device_names: Tuple[str, ...],
                 country_names: Tuple[str, ...]):
        self.device_names = device_names
        self.country_names = country_names
        self._dtypes = {**_ACC_DTYPES, **self._EXTRA_DTYPES}
        self._cols: Dict[str, np.ndarray] = {}
        self._pending: List[Dict[str, np.ndarray]] = []
        self._cap = 0
        self._n = 0         # rows appended (incl. pending blocks)
        self._n_buf = 0     # rows already consolidated into the buffers
        self._frozen = False

    def __len__(self) -> int:
        return self._n

    def append(self, **cols: np.ndarray) -> None:
        """Append one block; ``cols`` must cover every SessionBatch column
        except the vocabularies (fixed at construction). Values may be
        scalars (broadcast) as long as ``client_id`` is an array."""
        assert cols.keys() == self._dtypes.keys(), sorted(cols)
        self._pending.append(cols)
        self._n += len(cols["client_id"])

    def _consolidate(self) -> None:
        """Write pending blocks into the buffers (dtype-casting like
        ``np.asarray``); grows by doubling, but the first allocation is
        exact-size so the accumulate-once/consolidate-once pattern copies
        each value exactly once."""
        if not self._pending:
            return              # nothing new; existing views stay valid
        if self._n > self._cap or self._frozen:
            # grow only when out of space (exact on first allocation, then
            # doubling); a freeze-triggered copy-on-write keeps capacity
            if self._n <= self._cap:
                cap = self._cap
            else:
                cap = self._n if self._cap == 0 \
                    else max(self._n, 2 * self._cap)
            for f, dt in self._dtypes.items():
                buf = np.empty(cap, dt)
                if self._n_buf:
                    buf[:self._n_buf] = self._cols[f][:self._n_buf]
                self._cols[f] = buf
            self._cap = cap
            self._frozen = False
        pos = self._n_buf
        for block in self._pending:
            n = len(block["client_id"])
            for f, arr in block.items():
                self._cols[f][pos:pos + n] = arr
            pos += n
        self._pending = []
        self._n_buf = pos

    def to_batch(self) -> SessionBatch:
        """Consolidated views of every appended row (the store freezes; a
        later append copies on write, so the snapshot stays immutable)."""
        if not self._n:
            return SessionBatch.empty()
        self._consolidate()
        self._frozen = True
        return SessionBatch(
            device_names=self.device_names,
            country_names=self.country_names,
            **{f: self._cols[f][:self._n] for f in _ACC_DTYPES})

    def snapshot_rows(self, start: int
                      ) -> Tuple[Tuple[str, ...], Tuple[str, ...],
                                 Dict[str, np.ndarray]]:
        """Row slices ``[start:n)`` of every SessionBatch column, read
        off the consolidated buffers AND the still-pending blocks without
        consolidating or freezing — O(new rows), and the store's own
        exact-size consolidate-once pattern stays intact (a periodic
        snapshot neither triggers copy-on-write nor forces growth by
        doubling). Values are views or broadcast-casts valid until the
        next ``append``: consume them immediately (the snapshot writer
        serializes them on the spot)."""
        cols = {f: np.empty(max(self._n - start, 0), dt)
                for f, dt in _ACC_DTYPES.items()}
        out = 0
        if start < self._n_buf:
            for f in cols:
                cols[f][:self._n_buf - start] = \
                    self._cols[f][start:self._n_buf]
            out = self._n_buf - start
        pos = self._n_buf
        for block in self._pending:
            nb = len(block["client_id"])
            if pos + nb > start:
                lo = max(0, start - pos)
                for f in cols:
                    v = block[f]     # slice assignment broadcasts scalars
                    cols[f][out:out + nb - lo] = \
                        v[lo:] if isinstance(v, np.ndarray) else v
                out += nb - lo
            pos += nb
        return self.device_names, self.country_names, cols


class LaneAccumulator(BatchAccumulator):
    """``BatchAccumulator`` with a per-row ``lane`` column: one shared
    struct-of-arrays store for a whole lane pack (the lane-batched sweep
    engine). ``split`` slices each lane's ``SessionBatch`` back out — rows
    keep append order within a lane, which is exactly that lane's serial
    log order, and each lane gets its own device/country vocabularies
    (indices in the store are lane-local)."""

    _EXTRA_DTYPES = {"lane": np.int32}

    def __init__(self, device_names_per_lane: Sequence[Tuple[str, ...]],
                 country_names_per_lane: Sequence[Tuple[str, ...]]):
        super().__init__((), ())
        self._dev_names = list(device_names_per_lane)
        self._ctry_names = list(country_names_per_lane)

    @property
    def n_lanes(self) -> int:
        return len(self._dev_names)

    def raw(self) -> Dict[str, np.ndarray]:
        """Trimmed views of every column (lane included) — for segment
        reductions over the whole pack (``estimator.lane_carbon``) with
        no per-lane copying. Freezes like ``to_batch``."""
        if not self._n:
            return {f: np.zeros(0, dt) for f, dt in self._dtypes.items()}
        self._consolidate()
        self._frozen = True
        return {f: self._cols[f][:self._n] for f in self._dtypes}

    def split(self) -> List[SessionBatch]:
        if not self._n:
            return [SessionBatch.empty() for _ in self._dev_names]
        self._consolidate()
        lane = self._cols["lane"][:self._n]
        out = []
        for i in range(self.n_lanes):
            idx = np.flatnonzero(lane == i)
            out.append(SessionBatch(
                device_names=self._dev_names[i],
                country_names=self._ctry_names[i],
                **{f: self._cols[f][:self._n][idx] for f in _ACC_DTYPES}))
        return out


class TaskLog:
    """Accumulates everything the carbon estimator needs for one FL task.

    Sessions arrive as ``SessionBatch`` chunks (``log_batch``) on the fast
    path, or as individual ``ClientSession`` objects (``log_session``) from
    legacy callers; both land in the same columnar store. ``columns()``
    consolidates all chunks into one batch (cached until the next append);
    ``sessions`` is the lazy row-oriented compatibility view."""

    def __init__(self):
        self._batches: List[SessionBatch] = []
        self._pending: List[ClientSession] = []
        self._n: int = 0
        self._columns: Optional[SessionBatch] = None
        self._sessions: Optional[Tuple[ClientSession, ...]] = None
        self.rounds: int = 0                  # server model updates so far
        self.starved_rounds: int = 0          # sync rounds closed under quorum
        self.duration_s: float = 0.0          # task wall-clock so far
        self.server_busy_s: float = 0.0       # == duration (servers stay up)
        self.eval_history: List[Dict] = []

    # ------------------------------------------------------------ appenders
    def log_batch(self, batch: SessionBatch) -> None:
        if self._pending:
            self._batches.append(SessionBatch.from_sessions(self._pending))
            self._pending = []
        self._batches.append(batch)
        self._n += len(batch)
        self._columns = self._sessions = None

    def log_session(self, s: ClientSession) -> None:
        self._pending.append(s)
        self._n += 1
        self._columns = self._sessions = None

    def log_round(self, t: float, starved: bool = False) -> None:
        self.rounds += 1
        if starved:
            self.starved_rounds += 1
        self.duration_s = max(self.duration_s, t)

    def log_eval(self, t: float, round_idx: int, perplexity: float,
                 smoothed: float) -> None:
        self.eval_history.append(dict(t=t, round=round_idx,
                                      perplexity=perplexity, smoothed=smoothed))

    # ---------------------------------------------------------------- views
    @property
    def n_sessions(self) -> int:
        return self._n

    def columns(self) -> SessionBatch:
        """All sessions consolidated into one SessionBatch (cached)."""
        if self._columns is None:
            parts = list(self._batches)
            if self._pending:
                parts.append(SessionBatch.from_sessions(self._pending))
            self._columns = SessionBatch.concat(parts)
        return self._columns

    def snapshot_rows(self, start: int
                      ) -> Tuple[Tuple[str, ...], Tuple[str, ...],
                                 Dict[str, np.ndarray]]:
        """Copies of rows ``[start:n)`` of every column, walked off the
        chunk list without consolidating — O(new rows), so periodic
        engine snapshots don't pay O(all rows) per checkpoint."""
        if self._pending:   # fold row-appends into the chunk list first
            self._batches.append(SessionBatch.from_sessions(self._pending))
            self._pending = []
        dev: Tuple[str, ...] = ()
        ctry: Tuple[str, ...] = ()
        parts: Dict[str, List[np.ndarray]] = {f: [] for f in _ACC_DTYPES}
        pos = 0
        for b in self._batches:
            if b.device_names:
                dev, ctry = b.device_names, b.country_names
            nb = len(b)
            if pos + nb > start:
                lo = max(0, start - pos)
                for f in parts:
                    parts[f].append(getattr(b, f)[lo:])
            pos += nb
        cols = {f: (np.concatenate(v) if v
                    else np.zeros(0, _ACC_DTYPES[f]))
                for f, v in parts.items()}
        return dev, ctry, cols

    @property
    def sessions(self) -> Tuple[ClientSession, ...]:
        """Row-oriented compatibility view (materialised lazily). A tuple,
        not a list: appending to the view cannot reach the columnar store,
        so it fails loudly instead of silently dropping sessions — append
        through ``log_session``/``log_batch``."""
        if self._sessions is None:
            self._sessions = tuple(self.columns().to_sessions())
        return self._sessions

    # ------------------------------------------------------------ summaries
    def completed_sessions(self) -> int:
        return int(np.count_nonzero(self.columns().completed_mask))

    def participation(self) -> Dict[str, int]:
        counts = np.bincount(self.columns().outcome, minlength=len(OUTCOMES))
        return {OUTCOMES[i]: int(n) for i, n in enumerate(counts) if n}

    def total_bytes(self) -> Dict[str, float]:
        b = self.columns()
        return {"up": float(b.bytes_up.sum()),
                "down": float(b.bytes_down.sum())}

    def mean_staleness(self) -> float:
        b = self.columns()
        ok = b.completed_mask
        return float(b.staleness[ok].mean()) if ok.any() else 0.0
