"""The client-runtime "logger" (paper §4.1) as a data model.

Each FL session produces a ``ClientSession`` record with exactly the vitals
the paper's production logger captures: device model, connecting country,
download/compute/upload durations, bytes moved, and the outcome (completed,
dropped mid-round, or timed out at 4 minutes). Dropped/timed-out clients
still burned energy — the estimator charges them (paper: "our methodology
also accounts for the clients that drop out or time out").
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass(frozen=True)
class ClientSession:
    client_id: int
    round_idx: int               # sync round (async: server version at start)
    device: str                  # DeviceProfile.name
    country: str
    download_s: float
    compute_s: float
    upload_s: float
    bytes_down: float
    bytes_up: float
    start_t: float               # task clock, seconds
    end_t: float
    outcome: str                 # "completed" | "dropped" | "timeout"
    staleness: int = 0           # async: server updates since model was sent

    @property
    def completed(self) -> bool:
        return self.outcome == "completed"


@dataclass
class TaskLog:
    """Accumulates everything the carbon estimator needs for one FL task."""

    sessions: List[ClientSession] = field(default_factory=list)
    rounds: int = 0                       # server model updates so far
    duration_s: float = 0.0               # task wall-clock so far
    server_busy_s: float = 0.0            # == duration (servers stay up)
    eval_history: List[Dict] = field(default_factory=list)

    def log_session(self, s: ClientSession) -> None:
        self.sessions.append(s)

    def log_round(self, t: float) -> None:
        self.rounds += 1
        self.duration_s = max(self.duration_s, t)

    def log_eval(self, t: float, round_idx: int, perplexity: float,
                 smoothed: float) -> None:
        self.eval_history.append(dict(t=t, round=round_idx,
                                      perplexity=perplexity, smoothed=smoothed))

    # ------------------------------------------------------------ summaries
    def completed_sessions(self) -> int:
        return sum(1 for s in self.sessions if s.completed)

    def participation(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for s in self.sessions:
            out[s.outcome] = out.get(s.outcome, 0) + 1
        return out

    def total_bytes(self) -> Dict[str, float]:
        return {
            "up": float(sum(s.bytes_up for s in self.sessions)),
            "down": float(sum(s.bytes_down for s in self.sessions)),
        }

    def mean_staleness(self) -> float:
        ss = [s.staleness for s in self.sessions if s.completed]
        return float(np.mean(ss)) if ss else 0.0
