"""Networking-infrastructure energy (paper §4.3): energy-per-bit model.

    P_network = (E_a + E_as + E_bng + n_e*E_e + n_c*E_c + E_ds) * B

over the path  client -> Wi-Fi AP -> edge Ethernet switch -> BNG ->
edge routers -> core routers -> edge routers -> DC Ethernet switch -> DC.
Constants follow Vishwanath et al. (2015) / Baliga et al. (2011) /
Jalali et al. (2014) per-bit energies.
"""
from __future__ import annotations

from dataclasses import dataclass

NJ = 1e-9  # nanojoule


@dataclass(frozen=True)
class NetworkEnergyModel:
    e_access_nj: float = 52.6      # Wi-Fi access point, per bit
    e_edge_switch_nj: float = 11.2  # edge Ethernet switch
    e_bng_nj: float = 30.7         # broadband network gateway
    e_edge_router_nj: float = 16.9  # per edge router
    n_edge_routers: int = 4
    e_core_router_nj: float = 2.85  # per core router
    n_core_routers: int = 8
    e_dc_switch_nj: float = 11.2   # datacenter Ethernet switch

    @property
    def energy_per_bit_j(self) -> float:
        return NJ * (self.e_access_nj + self.e_edge_switch_nj + self.e_bng_nj
                     + self.n_edge_routers * self.e_edge_router_nj
                     + self.n_core_routers * self.e_core_router_nj
                     + self.e_dc_switch_nj)

    def transfer_energy_j(self, num_bytes: float) -> float:
        return 8.0 * num_bytes * self.energy_per_bit_j


DEFAULT_NETWORK = NetworkEnergyModel()
