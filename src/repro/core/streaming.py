"""Streaming telemetry — constant-memory population-scale tasks.

The paper measures FL on *millions of phones*; materializing every
simulated session as telemetry columns makes a 10^8-session task
memory-bound long before it is compute-bound. This module keeps the
engine's telemetry surface while storing O(groups + sample) instead of
O(sessions):

**Exact running reductions.** ``StreamingAccumulator`` folds each
resolved window's columns through ``estimator._kg_rows`` — the single
implementation of the per-phase ``intensity(country, t)`` span-mean
logic — into error-free ``ExactSum`` accumulators for the three
``CarbonBreakdown`` components, total bytes, and integer counters
(participation per outcome, completed-session staleness sum). Exact
summation is associative and commutative, so the folded totals equal the
materialized ``batch_carbon`` reduction **bit-for-bit** on every
schedule, regardless of window chunking or lane packing.

**Grouped breakdown table.** Per ``(country, intensity-schedule-segment,
outcome)`` group the fold also accumulates CO2e / energy / bytes /
duration / count via ``np.bincount`` into small running float64 arrays
(the per-region running-total shape of Savazzi et al.'s analysis).
Memory model: the component totals and counters are *exact*; the grouped
table is plain float64 accumulation (per-append bincount partials), i.e.
accurate to normal float rounding, not bit-pinned.

**Reservoir sample.** A deterministic bottom-k reservoir keeps
``sample`` full session rows for the fig scripts: session ``i`` (global
engine-order index) is retained iff ``events.reservoir_keys(seed, i)``
is among the k smallest keys seen. The retained *set* is a pure function
of ``(seed, index)`` — identical across chunk sizes, serial vs
lane-batched execution, and worker counts — and ``columns()`` returns it
in engine order as a well-formed ``SessionBatch``.

``StreamedLog`` packages the accumulator behind the ``TaskLog`` surface
(``n_sessions``, ``participation``, ``mean_staleness``, ``columns``,
rounds/evals), so strategies, ``Result`` and the estimator consume it
unchanged; ``CarbonEstimator.estimate`` spots ``carbon_components`` and
reads the exact sums instead of reducing the sampled columns.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.carbon import SECONDS_PER_DAY
from repro.core.telemetry import (OUTCOME_CODE, OUTCOMES, SessionBatch,
                                  TaskLog, _ACC_DTYPES)

_MEASURES = ("co2e_kg", "energy_j", "bytes", "duration_s", "count")


class StreamingAccumulator:
    """Constant-memory fold of session columns (see module doc).

    ``append(**cols)`` is ``BatchAccumulator``-compatible: one block of
    engine-order rows per call, column indices relative to the fixed
    ``device_names``/``country_names`` vocabularies fixed at construction
    (both engines emit the sampler's full vocab). The estimator is bound
    at construction because the fold charges carbon as rows arrive."""

    def __init__(self, estimator, device_names: Tuple[str, ...],
                 country_names: Tuple[str, ...], *, seed: int,
                 sample: int, checkpoint_period_s: float = 0.0):
        from repro.core.estimator import ExactSum
        self.estimator = estimator
        self.device_names = tuple(device_names)
        self.country_names = tuple(country_names)
        self.seed = int(seed)
        self.sample = int(sample)
        self.checkpoint_period_s = float(checkpoint_period_s)
        assert self.sample > 0
        self._n = 0
        # exact component sums (bit-for-bit vs materialized batch_carbon)
        self._kg = [ExactSum(), ExactSum(), ExactSum()]
        # exact contributed/wasted split over the same rows: completed vs
        # everything else (dropped/timeout/cancelled/failed/retried/
        # interrupted). With a live checkpoint period the waste further
        # splits into salvaged (interrupted compute up to the last
        # checkpoint, reused by a resume) vs lost — exact sums are
        # associative, so the fold matches batch_carbon's split
        # bit-for-bit regardless of block boundaries.
        self._kg_ok = ExactSum()
        self._kg_salv = ExactSum()
        self._kg_lost = ExactSum()
        self._bytes_up = ExactSum()
        self._bytes_down = ExactSum()
        # exact integer counters
        self._outcome_counts = np.zeros(len(OUTCOMES), np.int64)
        self._stale_sum = 0              # over completed sessions
        # grouped running table: (country, schedule-segment, outcome)
        tab = estimator.intensity.vocab_schedule(self.country_names)
        self._tab = tab
        self._nseg = int(tab.nseg.max()) if len(self.country_names) else 1
        ngroups = max(len(self.country_names), 1) * self._nseg * len(OUTCOMES)
        self._groups = {m: np.zeros(ngroups, np.float64) for m in _MEASURES}
        # bottom-k reservoir (engine-order rows; global-index keyed)
        self._res_idx = np.zeros(0, np.int64)
        self._res_keys = np.zeros(0, np.uint64)
        self._res_cols: Dict[str, np.ndarray] = {
            f: np.zeros(0, dt) for f, dt in _ACC_DTYPES.items()}
        # device/country remap caches for foreign-vocab batches
        self._dev_pos = {n: i for i, n in enumerate(self.device_names)}
        self._ctry_pos = {n: i for i, n in enumerate(self.country_names)}
        self._remap_cache: Dict[Tuple[Tuple[str, ...], Tuple[str, ...]],
                                Tuple[np.ndarray, np.ndarray]] = {}

    def __len__(self) -> int:
        return self._n

    # -------------------------------------------------------------- folding
    def append(self, **cols: np.ndarray) -> None:
        n = len(cols["client_id"])
        if not n:
            return
        block = {}
        for f, dt in _ACC_DTYPES.items():
            a = np.asarray(cols[f], dt)
            block[f] = np.broadcast_to(a, (n,)) if a.ndim == 0 else a
        from repro.core.estimator import _kg_rows
        kg, e = _kg_rows(self.estimator, self.device_names,
                         block["device_idx"], self.country_names,
                         block["country_idx"], block["compute_s"],
                         block["upload_s"], block["download_s"],
                         block["bytes_up"], block["bytes_down"],
                         block["start_t"], with_energy=True)
        for i in range(3):
            self._kg[i].add(kg[i])
        self._bytes_up.add(block["bytes_up"])
        self._bytes_down.add(block["bytes_down"])
        out = block["outcome"]
        self._outcome_counts += np.bincount(out, minlength=len(OUTCOMES))
        ok = out == 0  # OUTCOME_CODE["completed"]
        self._kg_ok.add(kg[:, ok])
        P = self.checkpoint_period_s
        im = (out == OUTCOME_CODE["interrupted"]) if P > 0 else None
        if im is None or not im.any():
            self._kg_lost.add(kg[:, ~ok])
        else:
            from repro.core.estimator import _salvage_kg
            iw = np.flatnonzero(im)
            salv_kg, tail_kg = _salvage_kg(
                self.estimator, self.device_names, block["device_idx"][iw],
                self.country_names, block["country_idx"][iw],
                block["compute_s"][iw], block["download_s"][iw],
                block["start_t"][iw], P)
            self._kg_salv.add(salv_kg)
            self._kg_lost.add(tail_kg).add(kg[1, iw]).add(kg[2, iw]) \
                .add(kg[:, ~ok & ~im])
        self._stale_sum += int(block["staleness"][ok].sum(dtype=np.int64))
        self._fold_groups(block, kg, e, out)
        self._fold_reservoir(block, n)
        self._n += n

    def append_batch(self, b: SessionBatch) -> None:
        """Fold a ``SessionBatch``, remapping its per-batch vocabularies
        onto the accumulator's fixed ones (identity for engine batches,
        which carry the sampler's full vocab). Unknown names fail loudly —
        the fixed vocab is what keys the grouped table."""
        if not len(b):
            return
        key = (b.device_names, b.country_names)
        maps = self._remap_cache.get(key)
        if maps is None:
            try:
                dmap = np.asarray([self._dev_pos[x] for x in b.device_names],
                                  np.int32)
                cmap = np.asarray([self._ctry_pos[x] for x in b.country_names],
                                  np.int32)
            except KeyError as exc:
                raise ValueError(
                    f"session batch names {exc} not in the streaming "
                    f"accumulator's fixed vocabulary") from None
            maps = self._remap_cache[key] = (dmap, cmap)
        dmap, cmap = maps
        self.append(
            client_id=b.client_id, round_idx=b.round_idx,
            device_idx=dmap[b.device_idx] if len(dmap) else b.device_idx,
            country_idx=cmap[b.country_idx] if len(cmap) else b.country_idx,
            download_s=b.download_s, compute_s=b.compute_s,
            upload_s=b.upload_s, bytes_down=b.bytes_down,
            bytes_up=b.bytes_up, start_t=b.start_t, end_t=b.end_t,
            outcome=b.outcome, staleness=b.staleness)

    def _fold_groups(self, block, kg, e, out) -> None:
        ctry = block["country_idx"].astype(np.int64)
        tab = self._tab
        r = np.mod(block["start_t"] + tab.phase_s[ctry], SECONDS_PER_DAY)
        seg = tab._segment(ctry, r)
        g = (ctry * self._nseg + seg) * len(OUTCOMES) + out
        nb = self._groups["count"].shape[0]
        self._groups["co2e_kg"] += np.bincount(
            g, weights=kg[0] + kg[1] + kg[2], minlength=nb)
        self._groups["energy_j"] += np.bincount(
            g, weights=e[0] + e[1] + e[2], minlength=nb)
        self._groups["bytes"] += np.bincount(
            g, weights=block["bytes_up"] + block["bytes_down"], minlength=nb)
        self._groups["duration_s"] += np.bincount(
            g, weights=block["end_t"] - block["start_t"], minlength=nb)
        self._groups["count"] += np.bincount(g, minlength=nb)

    def _fold_reservoir(self, block, n: int) -> None:
        from repro.federated.events import reservoir_keys
        gidx = np.arange(self._n, self._n + n, dtype=np.int64)
        keys = reservoir_keys(self.seed, gidx)
        if n > self.sample:
            # pre-trim big blocks so the merge sorts O(sample) rows
            part = np.argpartition(keys, self.sample - 1)[:self.sample]
            keys, gidx = keys[part], gidx[part]
            block = {f: a[part] for f, a in block.items()}
        idx = np.concatenate([self._res_idx, gidx])
        allk = np.concatenate([self._res_keys, keys])
        if idx.shape[0] > self.sample:
            order = np.lexsort((idx, allk))[:self.sample]
        else:
            order = np.arange(idx.shape[0])
        self._res_idx = idx[order]
        self._res_keys = allk[order]
        for f in _ACC_DTYPES:
            merged = np.concatenate([self._res_cols[f], block[f]])
            self._res_cols[f] = merged[order]

    # ---------------------------------------------------------------- views
    def carbon_components(self) -> Dict[str, float]:
        salv = self._kg_salv.value()
        lost = self._kg_lost.value()
        # waste == salvaged + lost exactly (one float add, matching
        # batch_carbon); with no live checkpoint period salv is 0.0 and
        # 0.0 + lost == lost bitwise, so the key stays back-compatible
        return {"client_compute_kg": self._kg[0].value(),
                "upload_kg": self._kg[1].value(),
                "download_kg": self._kg[2].value(),
                "ok_kg": self._kg_ok.value(),
                "waste_kg": salv + lost,
                "salvaged_kg": salv,
                "lost_kg": lost}

    def total_bytes(self) -> Dict[str, float]:
        return {"up": self._bytes_up.value(),
                "down": self._bytes_down.value()}

    def participation(self) -> Dict[str, int]:
        return {OUTCOMES[i]: int(c)
                for i, c in enumerate(self._outcome_counts) if c}

    def completed(self) -> int:
        return int(self._outcome_counts[0])

    def mean_staleness(self) -> float:
        c = self.completed()
        return self._stale_sum / c if c else 0.0

    def breakdown_table(self) -> List[Dict]:
        """Non-empty groups as rows: country, schedule segment, outcome,
        plus the five accumulated measures. Float64 running sums (see
        module doc for the exact-vs-rounded memory model)."""
        rows = []
        nz = np.flatnonzero(self._groups["count"])
        for g in nz:
            out = int(g % len(OUTCOMES))
            seg = int((g // len(OUTCOMES)) % self._nseg)
            ctry = int(g // (len(OUTCOMES) * self._nseg))
            rows.append({
                "country": self.country_names[ctry],
                "segment": seg,
                "outcome": OUTCOMES[out],
                **{m: float(self._groups[m][g]) for m in _MEASURES}})
        return rows

    def sample_columns(self) -> SessionBatch:
        """Retained reservoir rows, in engine (global-index) order."""
        order = np.argsort(self._res_idx, kind="stable")
        return SessionBatch(
            device_names=self.device_names,
            country_names=self.country_names,
            **{f: self._res_cols[f][order] for f in _ACC_DTYPES})

    def sample_indices(self) -> np.ndarray:
        """Global engine-order indices of the retained rows, sorted."""
        return np.sort(self._res_idx)

    # ------------------------------------------------------------ snapshots
    _SUM_NAMES = ("kg0", "kg1", "kg2", "kg_ok", "kg_salv", "kg_lost",
                  "bytes_up", "bytes_down")

    def _sums(self):
        return dict(zip(self._SUM_NAMES,
                        (*self._kg, self._kg_ok, self._kg_salv,
                         self._kg_lost, self._bytes_up, self._bytes_down)))

    def state(self) -> Tuple[dict, Dict[str, np.ndarray]]:
        """Full fold state as ``(json_meta, arrays)``. ``load_state`` on a
        same-config accumulator restores it exactly: the ExactSum states
        round-trip bit-for-bit, counters are integers, and the grouped
        table / reservoir come back as the identical float64/uint64
        arrays — so a resumed fold continues as if never interrupted."""
        meta = {"n": self._n, "stale_sum": self._stale_sum,
                "sums": {k: s.state() for k, s in self._sums().items()}}
        arrays = {"outcome_counts": self._outcome_counts,
                  "res_idx": self._res_idx, "res_keys": self._res_keys,
                  **{f"groups_{m}": self._groups[m] for m in _MEASURES},
                  **{f"res_{f}": self._res_cols[f] for f in _ACC_DTYPES}}
        return meta, arrays

    def load_state(self, meta: dict, arrays: Dict[str, np.ndarray]) -> None:
        from repro.core.estimator import ExactSum
        self._n = int(meta["n"])
        self._stale_sum = int(meta["stale_sum"])
        sums = {k: ExactSum.from_state(s) for k, s in meta["sums"].items()}
        self._kg = [sums["kg0"], sums["kg1"], sums["kg2"]]
        self._kg_ok = sums["kg_ok"]
        self._kg_salv = sums["kg_salv"]
        self._kg_lost = sums["kg_lost"]
        self._bytes_up = sums["bytes_up"]
        self._bytes_down = sums["bytes_down"]
        self._outcome_counts = np.asarray(arrays["outcome_counts"],
                                          np.int64).copy()
        for m in _MEASURES:
            self._groups[m] = np.asarray(arrays[f"groups_{m}"],
                                         np.float64).copy()
        self._res_idx = np.asarray(arrays["res_idx"], np.int64).copy()
        self._res_keys = np.asarray(arrays["res_keys"], np.uint64).copy()
        self._res_cols = {f: np.asarray(arrays[f"res_{f}"], dt).copy()
                          for f, dt in _ACC_DTYPES.items()}

class StreamedLog(TaskLog):
    """``TaskLog`` whose session store is a ``StreamingAccumulator``:
    appends fold instead of materialize, summaries read the exact running
    reductions, and ``columns()``/``sessions`` expose the deterministic
    reservoir *sample* (``sampled`` says whether rows were dropped).
    Satisfies everything ``Result.summary()``/``to_dict()`` and
    ``CarbonEstimator.estimate`` consume."""

    def __init__(self, estimator, device_names: Tuple[str, ...],
                 country_names: Tuple[str, ...], *, seed: int,
                 sample: int = 4096, mode: str = "",
                 checkpoint_period_s: float = 0.0):
        super().__init__()
        self.mode = mode
        self.checkpoint_period_s = float(checkpoint_period_s)
        self._acc = StreamingAccumulator(
            estimator, device_names, country_names, seed=seed,
            sample=sample, checkpoint_period_s=checkpoint_period_s)

    def __len__(self) -> int:
        return self._acc._n

    # ------------------------------------------------------------ appenders
    def log_batch(self, batch: SessionBatch) -> None:
        self._acc.append_batch(batch)
        self._n = self._acc._n
        self._columns = self._sessions = None

    def log_session(self, s) -> None:
        self._acc.append_batch(SessionBatch.from_sessions([s]))
        self._n = self._acc._n
        self._columns = self._sessions = None

    def append(self, **cols: np.ndarray) -> None:
        """``BatchAccumulator``-compatible sink surface — the async engine
        folds window pops straight into the log, no staging store."""
        self._acc.append(**cols)
        self._n = self._acc._n
        self._columns = self._sessions = None

    # ------------------------------------------------------------ snapshots
    def stream_state(self) -> Tuple[dict, "Dict[str, np.ndarray]"]:
        """Accumulator fold state (see ``StreamingAccumulator.state``)."""
        return self._acc.state()

    def load_stream_state(self, meta: dict, arrays) -> None:
        self._acc.load_state(meta, arrays)
        self._n = self._acc._n
        self._columns = self._sessions = None

    # ---------------------------------------------------------------- views
    @property
    def sampled(self) -> bool:
        """True when ``columns()`` is a strict sample of the population."""
        return self._acc._n > self._acc._res_idx.shape[0]

    def columns(self) -> SessionBatch:
        if self._columns is None:
            self._columns = self._acc.sample_columns()
        return self._columns

    # ------------------------------------------------------------ summaries
    def carbon_components(self, estimator) -> Dict[str, float]:
        est = self._acc.estimator
        if estimator is not est:
            try:
                same = bool(estimator == est)
            except Exception:
                same = False
            if not same:
                raise ValueError(
                    "StreamedLog was folded under a different estimator; "
                    "its exact sums cannot be re-estimated — re-run with "
                    "telemetry='full' to change the environment post hoc")
        return self._acc.carbon_components()

    def breakdown_table(self) -> List[Dict]:
        return self._acc.breakdown_table()

    def completed_sessions(self) -> int:
        return self._acc.completed()

    def participation(self) -> Dict[str, int]:
        return self._acc.participation()

    def total_bytes(self) -> Dict[str, float]:
        return self._acc.total_bytes()

    def mean_staleness(self) -> float:
        return self._acc.mean_staleness()
