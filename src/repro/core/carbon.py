"""Carbon intensity model (paper §4.1 "Accounting for geography" + §4.2).

Country-level carbon intensities (gCO2e/kWh, Our World in Data, 2020-2021
reported years) map session energy to CO2e by the client's connecting
country. Server energy uses the weighted average intensity of datacenter
locations (weights = number of datacenters per country), times PUE 1.09.

Grid intensity is also a function of *when* a session runs — the paper's
core thesis is that cross-device FL cannot "reliably tap into renewables",
so time/geo shifting is the headline Green-FL lever (CAFE-style carbon-aware
scheduling). ``IntensityModel`` therefore carries optional per-country
**diurnal schedules**: piecewise-constant gCO2e/kWh over a repeating 24 h
cycle (equal-length segments) plus a per-country phase offset in hours
(the country's UTC offset, so "midday" lands at local midday on the shared
task clock). A static table entry is exactly the degenerate one-segment
schedule; a schedule whose segments are all equal collapses back to a
static value at lookup-table build time, which keeps flat-schedule runs
bit-for-bit identical to the static model. ``intensity_at`` is the
vectorized point lookup; ``_VocabSchedule.mean`` integrates over a time
span (what the estimator charges a session phase with).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping, Sequence, Tuple, Union

import numpy as np

# gCO2e per kWh (OWID "carbon intensity of electricity", most recent year)
CARBON_INTENSITY: Dict[str, float] = {
    "WORLD": 475.0,
    "US": 379.0, "IN": 708.0, "BR": 102.0, "ID": 717.0, "MX": 431.0,
    "DE": 385.0, "GB": 257.0, "FR": 68.0, "JP": 479.0, "PH": 610.0,
    "VN": 542.0, "TR": 464.0, "TH": 501.0, "EG": 469.0, "PK": 344.0,
    "NG": 404.0, "BD": 574.0, "IT": 372.0, "ES": 193.0, "PL": 751.0,
    "CA": 125.0, "AU": 531.0, "SE": 45.0, "NO": 26.0, "IE": 348.0,
    "DK": 181.0, "SG": 489.0, "OTHER": 475.0,
}

PUE = 1.09  # paper §4.2 (Meta datacenters)

# datacenter fleet: country -> number of datacenters (weights for the
# weighted-average intensity model of §4.2)
DATACENTER_LOCATIONS: Dict[str, int] = {
    "US": 14, "IE": 1, "DK": 1, "SE": 1, "SG": 1,
}

SECONDS_PER_DAY = 86400.0

# Canonical diurnal shape: fractional deviation from the daily mean per
# 3-hour segment starting at local midnight — overnight fossil baseload
# sits above the mean, the midday solar belly well below, and the evening
# ramp peaks as solar falls off while demand holds. Deviations sum to 0,
# so the cycle average equals the static table value.
DIURNAL_SHAPE: Tuple[float, ...] = (0.10, 0.16, 0.00, -0.20, -0.26, -0.10,
                                    0.12, 0.18)

# Approximate UTC offsets (hours) of the participation-mix countries: the
# per-country phase that aligns the shared task clock with local solar
# time. Half-hour offsets (IN) are kept; multi-zone countries use their
# population-weighted zone.
UTC_OFFSET_H: Dict[str, float] = {
    "US": -6.0, "IN": 5.5, "BR": -3.0, "ID": 7.0, "MX": -6.0, "DE": 1.0,
    "GB": 0.0, "FR": 1.0, "JP": 9.0, "PH": 8.0, "VN": 7.0, "TR": 3.0,
    "TH": 7.0, "EG": 2.0, "PK": 5.0, "NG": 1.0, "BD": 6.0, "IT": 1.0,
    "ES": 1.0, "PL": 1.0, "CA": -5.0, "AU": 10.0, "SE": 1.0, "NO": 1.0,
    "IE": 0.0, "DK": 1.0, "SG": 8.0, "WORLD": 0.0, "OTHER": 0.0,
}


def diurnal_schedule(table: Mapping[str, float] = CARBON_INTENSITY,
                     amplitude: float = 1.0,
                     shape: Sequence[float] = DIURNAL_SHAPE
                     ) -> Dict[str, Tuple[float, ...]]:
    """Default diurnal schedules: every country's static intensity swung
    through ``shape`` (scaled by ``amplitude``), cycle mean preserved."""
    return {c: tuple(ci * (1.0 + amplitude * s) for s in shape)
            for c, ci in table.items()}


class _VocabSchedule:
    """Per-vocabulary compiled intensity lookup: for a fixed tuple of
    country names, static values, dynamic-schedule masks and the padded
    segment/prefix tables that make ``at`` (point lookup) and ``mean``
    (time-span integral) a few array ops. Built once per vocabulary and
    cached on the ``IntensityModel``."""

    def __init__(self, model: "IntensityModel", names: Sequence[str]):
        self.names = tuple(names)
        scheds = [model._dynamic_schedule(n) for n in self.names]
        self.static = np.asarray([model.intensity(n) for n in self.names],
                                 np.float64)
        self.dynamic = np.asarray([s is not None for s in scheds], bool)
        self.any_dynamic = bool(self.dynamic.any())
        v = len(self.names)
        kmax = max((len(s) for s in scheds if s), default=1)
        # static rows degrade to a one-segment schedule of their own value,
        # so every formula below is total (np.where still picks `static`)
        self.vals = np.tile(self.static[:, None], (1, kmax))
        self.nseg = np.ones(v, np.int64)
        self.phase_s = np.zeros(v, np.float64)
        for i, s in enumerate(scheds):
            if s is None:
                continue
            self.vals[i, :len(s)] = s
            self.nseg[i] = len(s)
            self.phase_s[i] = (model.phase_h.get(self.names[i], 0.0)
                               % 24.0) * 3600.0
        self.seg_s = SECONDS_PER_DAY / self.nseg
        self.prefix = np.concatenate(
            [np.zeros((v, 1)), np.cumsum(self.vals, axis=1)],
            axis=1) * self.seg_s[:, None]
        self.cycle = self.prefix[np.arange(v), self.nseg]
        # compiled screening tables, built lazily on first use:
        #   _seg_cache  -> (breaks, vals_seg) global segment grid
        #   _mask_cache -> k -> (S, V) "value <= k-th smallest" bool masks
        #   _exit_cache -> binary-lifting min table for exit_times
        self._seg_cache = None
        self._mask_cache: Dict[int, np.ndarray] = {}
        self._exit_cache = None

    def _segment(self, idx: np.ndarray, r: np.ndarray) -> np.ndarray:
        """Segment index for cycle-local seconds r in [0, 86400)."""
        return np.minimum((r / self.seg_s[idx]).astype(np.int64),
                          self.nseg[idx] - 1)

    def at(self, idx, t) -> np.ndarray:
        """Point intensity for vocab rows ``idx`` at task-clock ``t``
        seconds (broadcasts; static rows return their static value)."""
        idx = np.asarray(idx, np.intp)
        t = np.asarray(t, np.float64)
        r = np.mod(t + self.phase_s[idx], SECONDS_PER_DAY)
        j = self._segment(idx, r)
        return np.where(self.dynamic[idx],
                        self.vals[idx, j], self.static[idx])

    # ------------------------------------------------ compiled segment grid
    def segment_table(self) -> Tuple[np.ndarray, np.ndarray]:
        """Global breakpoint grid over the 24 h cycle: ``(breaks,
        vals_seg)`` where ``breaks`` is the sorted (S,) array of
        cycle-local task-clock seconds at which ANY row's schedule
        changes value (all rows' segment boundaries with their phase
        offsets folded in, always including 0.0) and ``vals_seg`` is the
        (S, V) matrix of every row's value on ``[breaks[s],
        breaks[s+1])``. ``vals_seg`` is evaluated with ``at`` itself at
        the breakpoints, so the table agrees with the per-row lookup by
        construction; within a segment no row changes value, which is
        what makes a single searchsorted a faithful stand-in for the
        per-row mod/floor attribution."""
        tab = self._seg_cache
        if tab is None:
            if self.any_dynamic:
                pts = [np.mod(np.arange(int(self.nseg[i])) * self.seg_s[i]
                              - self.phase_s[i], SECONDS_PER_DAY)
                       for i in np.nonzero(self.dynamic)[0]]
                breaks = np.unique(np.concatenate([[0.0], *pts]))
            else:
                breaks = np.zeros(1)
            idx = np.arange(len(self.names), dtype=np.intp)
            vals_seg = self.at(idx[None, :], breaks[:, None])
            tab = self._seg_cache = (breaks, vals_seg)
        return tab

    def segment_at(self, t) -> np.ndarray:
        """Global segment index for task-clock times ``t`` — one
        searchsorted into the compiled breakpoint grid (O(log S) per
        row) instead of per-row-per-country mod/floor work."""
        breaks, _ = self.segment_table()
        tl = np.mod(np.asarray(t, np.float64), SECONDS_PER_DAY)
        # breaks[0] == 0.0 and tl >= 0, so the result is always >= 0
        return np.searchsorted(breaks, tl, side="right") - 1

    def allowed_masks(self, k: int) -> np.ndarray:
        """(S, V) bool table: per global segment, which rows sit at or
        below the segment's k-th smallest value. The threshold is the
        VALUE ``partition(vals_seg[s], k-1)[k-1]`` — not an argpartition
        rank — so tied values are all allowed, exactly like the direct
        per-row ``intensity_at`` + partition screen; gathering a
        precomputed row therefore reproduces the recomputed mask
        bit-for-bit. Cached per k (the vocabulary is fixed per table,
        and tables are cached per names tuple on the model)."""
        m = self._mask_cache.get(k)
        if m is None:
            _, vals_seg = self.segment_table()
            tau = np.partition(vals_seg, k - 1, axis=1)[:, k - 1:k]
            m = self._mask_cache[k] = vals_seg <= tau
        return m

    def exit_table(self):
        """Binary-lifting minimum table over the doubled per-row segment
        values: ``(dv, st, M)`` where ``dv`` is (V, 2*kmax) with each
        row's cycle written twice (pad +inf), ``st[m][i, p]`` is the min
        of ``dv[i, p:p+2**m]`` and ``M = bit_length(max nseg)``. Lets
        ``exit_times`` find each row's first boundary whose value dips
        to its draw in O(log nseg) vectorized gathers instead of a
        Python loop over every segment of the cycle."""
        lut = self._exit_cache
        if lut is None:
            w = 2 * int(self.nseg.max())
            v = len(self.names)
            dv = np.full((v, w), np.inf)
            for i in range(v):
                ns = int(self.nseg[i])
                dv[i, :ns] = self.vals[i, :ns]
                dv[i, ns:2 * ns] = self.vals[i, :ns]
            m_levels = int(self.nseg.max()).bit_length()
            st = [dv]
            h = 1
            for _ in range(1, m_levels):
                prev = st[-1]
                cur = prev.copy()
                cur[:, :w - h] = np.minimum(prev[:, :w - h], prev[:, h:])
                st.append(cur)
                h *= 2
            lut = self._exit_cache = (dv, st, m_levels)
        return lut

    def _cumulative(self, idx: np.ndarray, t: np.ndarray) -> np.ndarray:
        """∫_0^t intensity dt' for vocab rows idx (t in task-clock s)."""
        ts = t + self.phase_s[idx]
        cycles = np.floor(ts / SECONDS_PER_DAY)
        r = ts - cycles * SECONDS_PER_DAY
        j = self._segment(idx, r)
        within = np.maximum(r - j * self.seg_s[idx], 0.0)
        return (cycles * self.cycle[idx] + self.prefix[idx, j]
                + self.vals[idx, j] * within)

    def mean(self, idx, a, b) -> np.ndarray:
        """Mean intensity over [a, b] per row; zero-length spans (and
        static rows) fall back to the point value at ``a``."""
        idx = np.asarray(idx, np.intp)
        a = np.asarray(a, np.float64)
        b = np.asarray(b, np.float64)
        point = self.at(idx, a)
        dur = b - a
        live = self.dynamic[idx] & (dur > 0)
        if not live.any():
            return point
        integral = self._cumulative(idx, b) - self._cumulative(idx, a)
        return np.where(live,
                        np.divide(integral, dur, out=np.zeros_like(point),
                                  where=dur > 0),
                        point)


@dataclass(frozen=True)
class IntensityModel:
    """A swappable grid-carbon model: country intensity table, datacenter
    fleet weights, PUE, and optional per-country diurnal ``schedule``s
    (piecewise-constant gCO2e/kWh over a 24 h cycle, equal segments, with
    ``phase_h`` UTC offsets — see the module docstring). Instances are what
    `repro.api.Environment` threads through the estimator; the module-level
    functions below keep delegating to `DEFAULT_INTENSITY` for legacy
    callers."""

    table: Mapping[str, float] = field(
        default_factory=lambda: dict(CARBON_INTENSITY))
    datacenter_locations: Mapping[str, int] = field(
        default_factory=lambda: dict(DATACENTER_LOCATIONS))
    pue: float = PUE
    fallback: str = "WORLD"
    schedule: Mapping[str, Sequence[float]] = field(default_factory=dict)
    phase_h: Mapping[str, float] = field(default_factory=dict)
    # per-vocabulary compiled lookup tables (built lazily, keyed by the
    # country-name tuple); excluded from equality so the cache is invisible
    _vocab_cache: Dict[Tuple[str, ...], _VocabSchedule] = field(
        default_factory=dict, init=False, repr=False, compare=False)

    def _dynamic_schedule(self, country: str) -> Union[Tuple[float, ...],
                                                       None]:
        """The country's schedule as a tuple IF it is genuinely
        time-varying; constant schedules (incl. the one-segment case)
        collapse to a static override so flat-schedule runs stay
        bit-for-bit identical to the static model."""
        vals = self.schedule.get(country)
        if not vals:
            return None
        vals = tuple(float(x) for x in vals)
        if all(x == vals[0] for x in vals):
            return None
        return vals

    def intensity(self, country: str) -> float:
        """Static / time-averaged intensity. Constant schedules override
        the table exactly; a time-varying schedule contributes its cycle
        mean (segments are equal-length, so the plain average)."""
        vals = self.schedule.get(country)
        if vals:
            vals = tuple(float(x) for x in vals)
            if all(x == vals[0] for x in vals):
                return vals[0]
            return sum(vals) / len(vals)
        # partial custom tables (Environment overrides) fall back to their
        # own fallback entry, then to the global world average
        return self.table.get(
            country,
            self.table.get(self.fallback, CARBON_INTENSITY["WORLD"]))

    # ------------------------------------------------------ time-resolved
    def vocab_schedule(self, names: Sequence[str]) -> _VocabSchedule:
        """Compiled lookup tables for a country vocabulary (cached)."""
        key = tuple(names)
        tab = self._vocab_cache.get(key)
        if tab is None:
            tab = self._vocab_cache[key] = _VocabSchedule(self, key)
        return tab

    def is_dynamic(self, names: Union[Sequence[str], None] = None) -> bool:
        """True iff any (given) country has a time-varying schedule."""
        if names is None:
            names = self.schedule.keys()
        return any(self._dynamic_schedule(n) is not None for n in names)

    def intensity_at(self, countries: Sequence[str], t) -> np.ndarray:
        """Vectorized point lookup: intensity of each named country at
        task-clock ``t`` seconds. ``t`` broadcasts against the country
        axis — a scalar gives shape (V,), an (n, 1) column gives (n, V)
        (every country's intensity at each row's clock)."""
        tab = self.vocab_schedule(countries)
        return tab.at(np.arange(len(tab.names), dtype=np.intp), t)

    def mean_intensity(self, country: str, a: float, b: float) -> float:
        """Scalar mean intensity of one country over task-clock [a, b]."""
        return float(self.vocab_schedule((country,)).mean([0], [a], [b])[0])

    def datacenter_intensity(self) -> float:
        total = sum(self.datacenter_locations.values())
        if total <= 0:
            # no (or zero-weighted) datacenter fleet: fall back to the
            # model's fallback intensity instead of dividing by zero
            return self.intensity(self.fallback)
        return sum(self.intensity(c) * n
                   for c, n in self.datacenter_locations.items()) / total

    def co2e_kg(self, energy_j: float, intensity_g_per_kwh: float) -> float:
        """Joules -> kg CO2e at the given intensity."""
        kwh = energy_j / 3.6e6
        return kwh * intensity_g_per_kwh / 1000.0

    def mix_intensity(self, country_mix: Mapping[str, float]) -> float:
        return sum(self.intensity(c) * w for c, w in country_mix.items()) / \
            max(sum(country_mix.values()), 1e-12)


DEFAULT_INTENSITY = IntensityModel()


def intensity(country: str) -> float:
    return DEFAULT_INTENSITY.intensity(country)


def datacenter_intensity() -> float:
    return DEFAULT_INTENSITY.datacenter_intensity()


def co2e_kg(energy_j: float, intensity_g_per_kwh: float) -> float:
    """Joules -> kg CO2e at the given intensity."""
    return DEFAULT_INTENSITY.co2e_kg(energy_j, intensity_g_per_kwh)


def mix_intensity(country_mix: Mapping[str, float]) -> float:
    return DEFAULT_INTENSITY.mix_intensity(country_mix)
