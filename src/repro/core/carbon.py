"""Carbon intensity model (paper §4.1 "Accounting for geography" + §4.2).

Country-level carbon intensities (gCO2e/kWh, Our World in Data, 2020-2021
reported years) map session energy to CO2e by the client's connecting
country. Server energy uses the weighted average intensity of datacenter
locations (weights = number of datacenters per country), times PUE 1.09.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Mapping

# gCO2e per kWh (OWID "carbon intensity of electricity", most recent year)
CARBON_INTENSITY: Dict[str, float] = {
    "WORLD": 475.0,
    "US": 379.0, "IN": 708.0, "BR": 102.0, "ID": 717.0, "MX": 431.0,
    "DE": 385.0, "GB": 257.0, "FR": 68.0, "JP": 479.0, "PH": 610.0,
    "VN": 542.0, "TR": 464.0, "TH": 501.0, "EG": 469.0, "PK": 344.0,
    "NG": 404.0, "BD": 574.0, "IT": 372.0, "ES": 193.0, "PL": 751.0,
    "CA": 125.0, "AU": 531.0, "SE": 45.0, "NO": 26.0, "IE": 348.0,
    "DK": 181.0, "SG": 489.0, "OTHER": 475.0,
}

PUE = 1.09  # paper §4.2 (Meta datacenters)

# datacenter fleet: country -> number of datacenters (weights for the
# weighted-average intensity model of §4.2)
DATACENTER_LOCATIONS: Dict[str, int] = {
    "US": 14, "IE": 1, "DK": 1, "SE": 1, "SG": 1,
}


@dataclass(frozen=True)
class IntensityModel:
    """A swappable grid-carbon model: country intensity table, datacenter
    fleet weights, and PUE. Instances are what `repro.api.Environment`
    threads through the estimator; the module-level functions below keep
    delegating to `DEFAULT_INTENSITY` for legacy callers."""

    table: Mapping[str, float] = field(
        default_factory=lambda: dict(CARBON_INTENSITY))
    datacenter_locations: Mapping[str, int] = field(
        default_factory=lambda: dict(DATACENTER_LOCATIONS))
    pue: float = PUE
    fallback: str = "WORLD"

    def intensity(self, country: str) -> float:
        # partial custom tables (Environment overrides) fall back to their
        # own fallback entry, then to the global world average
        return self.table.get(
            country,
            self.table.get(self.fallback, CARBON_INTENSITY["WORLD"]))

    def datacenter_intensity(self) -> float:
        total = sum(self.datacenter_locations.values())
        return sum(self.intensity(c) * n
                   for c, n in self.datacenter_locations.items()) / total

    def co2e_kg(self, energy_j: float, intensity_g_per_kwh: float) -> float:
        """Joules -> kg CO2e at the given intensity."""
        kwh = energy_j / 3.6e6
        return kwh * intensity_g_per_kwh / 1000.0

    def mix_intensity(self, country_mix: Mapping[str, float]) -> float:
        return sum(self.intensity(c) * w for c, w in country_mix.items()) / \
            max(sum(country_mix.values()), 1e-12)


DEFAULT_INTENSITY = IntensityModel()


def intensity(country: str) -> float:
    return DEFAULT_INTENSITY.intensity(country)


def datacenter_intensity() -> float:
    return DEFAULT_INTENSITY.datacenter_intensity()


def co2e_kg(energy_j: float, intensity_g_per_kwh: float) -> float:
    """Joules -> kg CO2e at the given intensity."""
    return DEFAULT_INTENSITY.co2e_kg(energy_j, intensity_g_per_kwh)


def mix_intensity(country_mix: Mapping[str, float]) -> float:
    return DEFAULT_INTENSITY.mix_intensity(country_mix)
