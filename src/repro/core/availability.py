"""Device availability: diurnal eligibility curves + mid-session churn.

The paper's production constraint is that a phone only trains while idle,
charging and on unmetered wifi — so the *eligible* fleet is itself a
per-country 24 h curve (evening/overnight charging peak, midday dip),
anti-correlated with the solar-driven low-intensity hours that
carbon-aware scheduling wants to exploit — and a device routinely exits
eligibility mid-session (unplugged, off wifi), interrupting work the
fault model cannot express. ``AvailabilityModel`` describes both effects
for an ``Environment``:

* **admission** — per-country probability that a candidate device is
  eligible at dispatch time: a static table plus optional
  ``eligibility_schedule`` piecewise-constant 24 h curves with
  ``eligibility_phase_h`` UTC offsets, reusing the intensity-schedule
  machinery from ``repro.core.carbon`` verbatim (same segment lookup,
  same constant-schedule collapse). The engine draws one admission
  uniform per session on a dedicated counter stream; an inadmissible
  device is logged ``interrupted`` at zero cost and its slot retried.
* **churn** — the *same* uniform, read against the eligibility curve
  over the session's span: the device stays eligible exactly while
  ``u < eligibility(t)``, so an admitted session is interrupted at the
  first schedule-segment boundary where the curve falls to or below its
  draw (``exit_times``). Static curves never cross an admitted draw, so
  a schedule-free model degrades to admission-only gating.

Everything is a pure function of the engine's ``(seed, client_id,
round)`` counters, so the seed-for-seed oracle, lane packing and
streaming telemetry all survive bit-for-bit — and an all-available model
(the default) is exactly today's availability-blind engine.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Mapping, Sequence, Tuple

import numpy as np

from repro.core.carbon import (SECONDS_PER_DAY, UTC_OFFSET_H, IntensityModel,
                               _VocabSchedule)

# Canonical eligibility shape: absolute per-country eligibility probability
# per 3-hour segment starting at local midnight. Overnight/evening (on the
# charger, idle, home wifi) is the peak; the working-day midday trough is
# exactly where DIURNAL_SHAPE's solar belly sits — the anti-correlation the
# paper's availability analysis turns on.
AVAIL_SHAPE: Tuple[float, ...] = (0.95, 0.90, 0.55, 0.35, 0.30, 0.45,
                                  0.75, 0.90)


def diurnal_availability(countries: Sequence[str],
                         shape: Sequence[float] = AVAIL_SHAPE,
                         phase_h: Mapping[str, float] = UTC_OFFSET_H
                         ) -> "AvailabilityModel":
    """Default diurnal availability: every country rides ``shape`` with
    its UTC offset as phase, so the charging peak lands at local evening
    (pairs with ``carbon.UTC_OFFSET_H`` the same way the intensity
    schedules do)."""
    return AvailabilityModel(
        eligibility_schedule={c: tuple(float(x) for x in shape)
                              for c in countries},
        eligibility_phase_h={c: float(phase_h.get(c, 0.0))
                             for c in countries})


def exit_times(tab: _VocabSchedule, idx, u, start) -> np.ndarray:
    """First task-clock time ``> start`` at which each row's eligibility
    curve falls to or below its admission draw ``u`` — the moment the
    device exits eligibility. Crossings only happen at schedule segment
    boundaries (curves are piecewise constant), so the search space is
    one cycle of boundaries ahead of ``start``; rows whose curve never
    dips to ``u`` (static rows with an admitted draw, or periodic curves
    that stay above it) return ``+inf``. The scan is a binary-lifting
    descent over the table's compiled doubled-cycle min structure
    (``exit_table``): from the current segment, greedily jump the widest
    power-of-two span whose minimum stays above ``u`` — O(log nseg)
    vectorized gathers instead of a Python loop over every segment. The
    crossing *comparison* reads the stored segment values themselves, so
    which boundary is hit is exactly the sequential scan's answer; each
    row's result depends only on its own ``(idx, u, start)``, and the
    scalar oracle calls this batch-of-1, so serial, lane and oracle
    share the exact float sequence."""
    idx = np.asarray(idx, np.intp)
    u = np.asarray(u, np.float64)
    start = np.asarray(start, np.float64)
    n = idx.shape[0]
    out = np.full(n, np.inf)
    if not tab.any_dynamic:
        return out
    r = np.mod(start + tab.phase_s[idx], SECONDS_PER_DAY)
    j0 = tab._segment(idx, r)
    seg = tab.seg_s[idx]
    nseg = tab.nseg[idx]
    dv, st, m_levels = tab.exit_table()
    w = dv.shape[1]
    # pos = last boundary offset known crossing-free; search range is
    # (j0, j0 + nseg] in doubled-cycle coordinates (k = nseg re-checks
    # the starting segment one full day later)
    end_pos = j0 + nseg
    pos = j0.copy()
    for m in range(m_levels - 1, -1, -1):
        step = 1 << m
        fits = pos + step <= end_pos
        wmin = st[m][idx, np.minimum(pos + 1, w - 1)]
        pos += np.where(fits & (wmin > u), step, 0)
    k = pos + 1 - j0
    hit = (k <= nseg) & (dv[idx, np.minimum(pos + 1, w - 1)] <= u)
    out[hit] = (start + ((j0 + k) * seg - r))[hit]
    return out


def _check_frac(name: str, v: float) -> None:
    if not 0.0 <= float(v) <= 1.0:
        raise ValueError(f"AvailabilityModel.{name} must be an eligibility "
                         f"probability in [0, 1], got {v!r}")


@dataclass(frozen=True)
class AvailabilityModel:
    """Per-country device eligibility (static table + optional diurnal
    schedules). All-available (the default) is bit-for-bit the
    availability-blind engine."""

    eligibility: Mapping[str, float] = field(default_factory=dict)
    eligibility_schedule: Mapping[str, Sequence[float]] = field(
        default_factory=dict)
    eligibility_phase_h: Mapping[str, float] = field(default_factory=dict)
    # private caches (eligibility lookup tables) — excluded from equality
    # so two equal models compare equal regardless of use
    _cache: Dict = field(default_factory=dict, init=False, repr=False,
                         compare=False)

    def __post_init__(self):
        for c, v in self.eligibility.items():
            _check_frac(f"eligibility[{c!r}]", v)
        for c, vals in self.eligibility_schedule.items():
            if not len(vals):
                raise ValueError(
                    f"AvailabilityModel.eligibility_schedule[{c!r}] is "
                    f"empty")
            for v in vals:
                _check_frac(f"eligibility_schedule[{c!r}]", v)
        for c, v in self.eligibility_phase_h.items():
            if not math.isfinite(float(v)):
                raise ValueError(
                    f"AvailabilityModel.eligibility_phase_h[{c!r}] must be "
                    f"finite, got {v!r}")

    # ----------------------------------------------------------- predicates
    @property
    def enabled(self) -> bool:
        """True iff the model can actually exclude a device; disabled
        models take the engines' availability-free fast path untouched."""
        return (any(float(v) < 1.0 for v in self.eligibility.values())
                or any(any(float(x) < 1.0 for x in vals)
                       for vals in self.eligibility_schedule.values()))

    # --------------------------------------------------- eligibility lookup
    def _eligibility_model(self) -> IntensityModel:
        model = self._cache.get("model")
        if model is None:
            table = {str(k): float(v) for k, v in self.eligibility.items()}
            table.setdefault("WORLD", 1.0)  # unlisted: always eligible
            model = IntensityModel(
                table=table, datacenter_locations={},
                schedule=dict(self.eligibility_schedule),
                phase_h=dict(self.eligibility_phase_h))
            self._cache["model"] = model
        return model

    def eligibility_table(self, names: Sequence[str]) -> _VocabSchedule:
        """Compiled per-vocabulary eligibility lookup — the same piecewise
        schedule machinery the intensity model uses (point lookups via
        ``at``, constant schedules collapsed to statics), cached per
        country vocabulary."""
        return self._eligibility_model().vocab_schedule(tuple(names))

    # ------------------------------------------------------- serialization
    def to_dict(self) -> dict:
        out: dict = {}
        if self.eligibility:
            out["eligibility"] = {k: float(v)
                                  for k, v in self.eligibility.items()}
        if self.eligibility_schedule:
            out["eligibility_schedule"] = {
                k: [float(x) for x in v]
                for k, v in self.eligibility_schedule.items()}
        if self.eligibility_phase_h:
            out["eligibility_phase_h"] = {
                k: float(v) for k, v in self.eligibility_phase_h.items()}
        return out

    @classmethod
    def from_dict(cls, d) -> "AvailabilityModel":
        if not d:
            return cls()
        d = dict(d)
        if "eligibility_schedule" in d:
            d["eligibility_schedule"] = {
                k: tuple(v) for k, v in d["eligibility_schedule"].items()}
        return cls(**d)
