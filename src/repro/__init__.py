"""repro: Green Federated Learning (Yousefpour et al., 2023) in JAX."""
__version__ = "1.0.0"
