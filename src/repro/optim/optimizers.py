"""From-scratch optimizers (no optax): client SGD + FedAdam server optimizer.

The paper (§3.3): clients run plain SGD (no momentum — no extra on-device
state, little data per client); the server runs Adam on the aggregated
model delta ("FedAdam", Reddi et al. 2021). Optimizer state lives in the
same flat-dict format as params, so sharding rules apply unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, jnp.ndarray]
State = Dict[str, Params]


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], State]
    update: Callable[[Params, State, Params], Tuple[Params, State]]
    # update(grads, state, params) -> (new_params, new_state)


def sgd(lr: float) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        new = {k: params[k] - lr * grads[k].astype(params[k].dtype)
               for k in params}
        return new, {"step": state["step"] + 1}

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9) -> Optimizer:
    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": {k: jnp.zeros_like(v) for k, v in params.items()}}

    def update(grads, state, params):
        m = {k: beta * state["m"][k] + grads[k].astype(state["m"][k].dtype)
             for k in params}
        new = {k: params[k] - lr * m[k].astype(params[k].dtype) for k in params}
        return new, {"step": state["step"] + 1, "m": m}

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8) -> Optimizer:
    """Adam with f32 moments regardless of param dtype."""

    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": {k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()},
            "v": {k: jnp.zeros(v.shape, jnp.float32) for k, v in params.items()},
        }

    def update(grads, state, params):
        t = state["step"] + 1
        tf = t.astype(jnp.float32)
        c1 = 1.0 - b1 ** tf
        c2 = 1.0 - b2 ** tf
        m, v, new = {}, {}, {}
        for k in params:
            g = grads[k].astype(jnp.float32)
            m[k] = b1 * state["m"][k] + (1 - b1) * g
            v[k] = b2 * state["v"][k] + (1 - b2) * jnp.square(g)
            upd = (m[k] / c1) / (jnp.sqrt(v[k] / c2) + eps)
            new[k] = (params[k].astype(jnp.float32) - lr * upd).astype(params[k].dtype)
        return new, {"step": t, "m": m, "v": v}

    return Optimizer(init, update)


def server_optimizer(name: str, lr: float, *, b1: float = 0.9,
                     b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    if name == "adam":
        return adam(lr, b1, b2, eps)
    if name == "sgd":
        return sgd(lr)
    if name == "momentum":
        return momentum(lr, b1)
    raise ValueError(name)


def opt_state_axes(state_template: State, param_axes) -> Dict:
    """Logical axes for optimizer state (moments share param axes)."""
    out = {}
    for k, v in state_template.items():
        if k == "step":
            out[k] = ()
        else:
            out[k] = dict(param_axes)
    return out
