from repro.optim.optimizers import (Optimizer, adam, momentum, sgd,
                                    server_optimizer)
