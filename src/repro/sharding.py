"""Logical-axis -> mesh sharding rules (GSPMD via NamedSharding).

Every model param/cache leaf carries a tuple of logical axis names (one per
dim). ``spec_for`` maps those to a PartitionSpec given the mesh, with
per-dim divisibility checks so illegal shardings silently fall back to
replication (e.g. smollm's 9 heads on a 16-way model axis).

Default production rules (single pod, mesh ("data", "model")):
  heads/kv_heads/ffn/experts/vocab/rnn -> "model"   (tensor / expert parallel)
  embed                                -> "data"    (FSDP: params+opt sharded)
  batch                                -> ("pod","data")  [+ "pod" when present]
  layers / head_dim / cache / None     -> replicated

MoE expert-parallel note: experts shard over "model" when divisible
(granite 32e/16); otherwise the FFN dim carries the model axis (mixtral 8e).
Both are expressed by listing "experts" BEFORE "ffn" in the rule table and
letting divisibility resolve the winner per arch.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

# logical axis -> preferred mesh axes, in priority order
DEFAULT_RULES: Dict[str, Sequence[MeshAxes]] = {
    "batch": (("pod", "data"), "data"),
    "experts": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "ffn_out": (),
    "vocab": ("model",),
    "rnn": ("model",),
    "embed": (("pod", "data"), "data"),     # FSDP
    "embed_out": (),
    "cache": (),
    "layers": (),
    "head_dim": (),
    "head_dim2": (),
}

# rules for replicated-parameter (pure data-parallel / vmap-client) mode
DP_RULES: Dict[str, Sequence[MeshAxes]] = {
    **{k: () for k in DEFAULT_RULES},
    "batch": (("pod", "data"), "data"),
    "clients": (("pod", "data"), "data"),
}

# cross-device simulation (vmap-client) rules: params TP over "model" but NO
# FSDP — each data-axis slice carries whole per-client param deltas, the
# faithful small-model cross-device regime (smollm / charlm).
XDEVICE_RULES: Dict[str, Sequence[MeshAxes]] = {
    **DEFAULT_RULES,
    "embed": (),
    "clients": (("pod", "data"), "data"),
}

# Serving (decode) rules: weights stay RESIDENT — 2D-sharded over
# ("model","data") where divisible so a 141B MoE fits 256 chips without
# per-step FSDP all-gathers; activations (tiny at decode: B x d) move
# instead. KV caches shard over batch; FSDP ("embed") is disabled.
SERVE_RULES: Dict[str, Sequence[MeshAxes]] = {
    "batch": (("pod", "data"), "data"),
    "experts": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": (("model", "data"), "model", "data"),
    "ffn_out": (),
    "vocab": (("model", "data"), "model", "data"),
    "rnn": ("model", "data"),
    "embed": (),
    "embed_out": (),
    "head_dim": ("data",),
    "cache": (),
    "layers": (),
    "head_dim2": (),
}


def _axis_size(mesh: Mesh, axes: MeshAxes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def _mesh_has(mesh: Mesh, axes: MeshAxes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    return all(a in mesh.shape for a in axes)


def spec_for(logical: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Mesh, rules: Optional[Dict[str, Sequence[MeshAxes]]] = None
             ) -> P:
    """Resolve one leaf's PartitionSpec. Replicates any dim whose preferred
    mesh axes are absent, already used, or don't divide the dim size."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    out = []
    for name, dim in zip(logical, shape):
        placed = None
        for cand in (rules.get(name, ()) if name else ()):
            if cand is None:
                continue
            cand_t = (cand,) if isinstance(cand, str) else tuple(cand)
            if not _mesh_has(mesh, cand_t):
                continue
            if any(a in used for a in cand_t):
                continue
            if dim % _axis_size(mesh, cand_t) != 0:
                continue
            placed = cand_t if len(cand_t) > 1 else cand_t[0]
            used.update(cand_t)
            break
        out.append(placed)
    # drop trailing Nones for tidiness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_specs(axes_tree: Dict[str, Tuple[Optional[str], ...]],
               shapes: Dict[str, jax.ShapeDtypeStruct], mesh: Mesh,
               rules=None) -> Dict[str, P]:
    return {k: spec_for(axes_tree[k], shapes[k].shape, mesh, rules)
            for k in axes_tree}


def tree_shardings(axes_tree, shapes, mesh, rules=None):
    return {k: NamedSharding(mesh, s)
            for k, s in tree_specs(axes_tree, shapes, mesh, rules).items()}


def batch_spec(mesh: Mesh, ndim: int, *, batch_dim: int = 0,
               shape: Optional[Sequence[int]] = None) -> P:
    """Shard the batch dim over ("pod","data") where divisible."""
    axes: MeshAxes = ("pod", "data") if "pod" in mesh.shape else ("data",)
    if shape is not None and shape[batch_dim] % _axis_size(mesh, axes) != 0:
        # try data only
        axes = ("data",)
        if shape[batch_dim] % _axis_size(mesh, axes) != 0:
            axes = None
    spec = [None] * ndim
    if axes:
        # keep the tuple form even for a single axis so specs compare
        # consistently (PartitionSpec('data') != PartitionSpec(('data',)))
        spec[batch_dim] = tuple(axes)
    return P(*spec)


def current_mesh() -> Optional[Mesh]:
    """The mesh installed by ``with mesh:`` (empty -> None)."""
    try:
        from jax._src.mesh import thread_resources
        m = thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def constrain_batch(x, dim: int = 0):
    """with_sharding_constraint pinning the batch dim over ("pod","data").

    GSPMD sometimes resolves the FSDP-weight x batch-sharded-activation
    contraction by all-gathering ACTIVATIONS (replicating the whole forward
    on every data shard). Pinning activations after each block keeps the
    batch distributed. No-op outside a mesh context or when indivisible.
    """
    m = current_mesh()
    if m is None or x.ndim <= dim:
        return x
    axes = tuple(a for a in ("pod", "data") if a in m.shape)
    if not axes:
        return x
    size = 1
    for a in axes:
        size *= m.shape[a]
    if x.shape[dim] % size != 0:
        axes = ("data",) if "data" in m.shape else ()
        if not axes or x.shape[dim] % m.shape["data"] != 0:
            return x
    spec = [None] * x.ndim
    spec[dim] = axes if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*spec)))


def constrain_replicated(x):
    """Pin a (small) activation to full replication — decode-time FFN inputs
    are (B, d) ~ 1 MB; replicating them lets 2D-sharded resident weights
    matmul locally with partial-sum all-reduces instead of weight gathers."""
    m = current_mesh()
    if m is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*([None] * x.ndim))))


def count_bytes(tree) -> int:
    leaves = jax.tree.leaves(tree)
    return int(sum(np.prod(l.shape) * l.dtype.itemsize for l in leaves))
