"""Minimal stand-in for the `hypothesis` API used by this test suite.

The CI image does not ship hypothesis; rather than skip the property
tests we run each one against a deterministic pseudo-random sample of the
declared strategy space. Only the subset the suite uses is implemented:
``given``, ``settings(max_examples=, deadline=)`` and the ``integers``,
``floats``, ``lists``, ``booleans``, ``sampled_from``, ``composite`` and
interactive ``data`` strategies. conftest.py registers this module as
``hypothesis`` in sys.modules only when the real package is missing, so
installing hypothesis transparently upgrades the suite back to real
property testing.
"""
from __future__ import annotations

import inspect
import random
import sys
import types
import zlib

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rnd: random.Random):
        return self._draw(rnd)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> _Strategy:
    def draw(r: random.Random) -> float:
        # hit the boundaries occasionally, like hypothesis does
        u = r.random()
        if u < 0.05:
            return min_value
        if u > 0.95:
            return max_value
        return r.uniform(min_value, max_value)
    return _Strategy(draw)


def lists(elements: _Strategy, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    return _Strategy(
        lambda r: [elements.draw(r)
                   for _ in range(r.randint(min_size, max_size))])


def booleans() -> _Strategy:
    return _Strategy(lambda r: r.random() < 0.5)


def sampled_from(elements) -> _Strategy:
    pool = list(elements)
    return _Strategy(lambda r: r.choice(pool))


def composite(fn):
    """hypothesis' @composite: fn(draw, *args) -> value becomes a
    strategy factory; ``draw`` pulls from other strategies inline."""
    def factory(*args, **kwargs):
        return _Strategy(
            lambda r: fn(lambda s: s.draw(r), *args, **kwargs))
    factory.__name__ = fn.__name__
    return factory


class _Data:
    """Interactive draw object produced by st.data()."""

    def __init__(self, rnd: random.Random):
        self._rnd = rnd

    def draw(self, strategy: _Strategy, label=None):
        return strategy.draw(self._rnd)


def data() -> _Strategy:
    return _Strategy(lambda r: _Data(r))


def given(*strategies: _Strategy):
    def decorate(fn):
        params = list(inspect.signature(fn).parameters)
        # like hypothesis: positional strategies fill the TRAILING params;
        # any leading params remain pytest fixtures
        strat_names = params[len(params) - len(strategies):]
        fixture_names = params[:len(params) - len(strategies)]

        # NOT functools.wraps: copying __wrapped__/the signature would make
        # pytest treat the strategy-filled parameters as fixtures
        def wrapper(**fixture_kwargs):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rnd = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for i in range(n):
                drawn = {name: s.draw(rnd)
                         for name, s in zip(strat_names, strategies)}
                try:
                    fn(**fixture_kwargs, **drawn)
                except Exception as e:  # pragma: no cover - failure path
                    raise AssertionError(
                        f"falsifying example (#{i}): {drawn!r}") from e
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper.__signature__ = inspect.Signature(
            [inspect.Parameter(n_, inspect.Parameter.POSITIONAL_OR_KEYWORD)
             for n_ in fixture_names])
        wrapper._max_examples = getattr(fn, "_max_examples",
                                        _DEFAULT_MAX_EXAMPLES)
        return wrapper
    return decorate


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def decorate(fn):
        fn._max_examples = max_examples
        return fn
    return decorate


def install() -> None:
    """Register this stub as `hypothesis` (+ `hypothesis.strategies`)."""
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers = integers
    st.floats = floats
    st.lists = lists
    st.booleans = booleans
    st.sampled_from = sampled_from
    st.composite = composite
    st.data = data
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
