"""FL runtime (event loops) + surrogate learner: the paper's qualitative
findings must hold in simulation."""
import numpy as np
import pytest

from repro.configs import FederatedConfig, RunConfig, get_config
from repro.core.predictor import fit_linear
from repro.federated import SurrogateLearner, run_task

CFG = get_config("paper-charlm")
RUN = RunConfig(target_perplexity=175.0, max_hours=48.0)


def _run(mode="sync", conc=100, goal=None, **kw):
    fed = FederatedConfig(mode=mode, concurrency=conc,
                          aggregation_goal=goal or max(1, int(conc * 0.8)),
                          **kw)
    return run_task(CFG, fed, RUN, SurrogateLearner(CFG, fed, RUN))


def test_deterministic():
    a = _run(conc=50)
    b = _run(conc=50)
    assert a.rounds == b.rounds
    assert a.carbon.total_kg == pytest.approx(b.carbon.total_kg)


def test_reaches_target_with_good_hparams():
    res = _run(conc=200)
    assert res.reached_target
    assert res.final_perplexity <= 175.0 * 1.1


def test_bad_lr_fails_or_is_much_slower():
    good = _run(conc=200, client_lr=0.1)
    bad = _run(conc=200, client_lr=1e-4)
    assert (not bad.reached_target) or bad.rounds > 3 * good.rounds


def test_async_faster_but_dirtier():
    """Paper Fig.5: tuned async reaches target sooner in wall-clock but
    emits more carbon than sync."""
    sync = _run(mode="sync", conc=400, goal=400)
    asyn = _run(mode="async", conc=400, goal=400)
    assert asyn.duration_h < sync.duration_h
    assert asyn.carbon.total_kg > 0.9 * sync.carbon.total_kg


def test_concurrency_diminishing_returns():
    """Paper Fig.7: more concurrency -> more carbon, sublinear speedup."""
    lo = _run(conc=50)
    hi = _run(conc=800)
    assert hi.carbon.total_kg > 3 * lo.carbon.total_kg
    assert hi.duration_h < lo.duration_h          # still faster
    speedup = lo.duration_h / hi.duration_h
    assert speedup < 16                            # way below linear (16x)


def test_component_shares_match_paper_at_headline_setting():
    """Paper §5.1 at concurrency=1000: client compute ~46-50%, upload
    ~27-29%, download ~22-24%, server ~1-2%. Allow simulator slack."""
    res = _run(conc=1000, goal=1000)
    sh = res.carbon.shares()
    assert 0.40 <= sh["client_compute"] <= 0.56
    assert 0.20 <= sh["upload"] <= 0.33
    assert 0.16 <= sh["download"] <= 0.28
    assert sh["server"] <= 0.08


def test_carbon_linear_in_concurrency_x_rounds():
    """Paper Fig.8: carbon ~ a*(concurrency x rounds), high R^2."""
    xs, ys = [], []
    for conc in (50, 100, 200, 400):
        r = _run(conc=conc)
        xs.append(conc * r.rounds)
        ys.append(r.carbon.total_kg)
    fit = fit_linear(xs, ys)
    assert fit.r2 > 0.9


def test_compression_reduces_carbon():
    """Paper §6: int8 compression cuts comm carbon ~4x =>
    total reduction toward 1/(cc + comm/4)."""
    base = _run(conc=200)
    comp = _run(conc=200, compression="int8")
    assert comp.carbon.total_kg < 0.75 * base.carbon.total_kg
    assert comp.reached_target


def test_sessions_logged_with_outcomes():
    res = _run(conc=100)
    parts = res.log.participation()
    assert parts.get("completed", 0) > 0
    assert sum(parts.values()) == len(res.log.sessions)
    # telemetry carries device + country for every session
    s = res.log.sessions[0]
    assert s.device and s.country
