"""repro.api: spec JSON round-trips, strategy registry, streaming
callbacks, Environment overrides, and the GreenAdvisor edge cases."""
import dataclasses

import pytest

from repro.api import (Environment, Experiment, ExperimentSpec, ModelRef,
                       STRATEGIES, Strategy, get_strategy, register_strategy)
from repro.configs import FederatedConfig, RunConfig, get_config
from repro.core.network import NetworkEnergyModel
from repro.core.profiles import FLEET


def _spec(mode="sync", conc=50, max_rounds=60, **fed_kw):
    return ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(mode=mode, concurrency=conc,
                                  aggregation_goal=max(1, int(conc * 0.8)),
                                  **fed_kw),
        run=RunConfig(target_perplexity=175.0, max_rounds=max_rounds),
        learner="surrogate")


# ------------------------------------------------------------ spec JSON
def test_spec_json_roundtrip_equality():
    spec = _spec(mode="async", compression="int8")
    assert ExperimentSpec.from_json(spec.to_json()) == spec


def test_spec_json_reproduces_summary(tmp_path):
    spec = _spec(conc=30)
    path = str(tmp_path / "spec.json")
    spec.save(path)
    first = Experiment(spec).run().summary()
    again = Experiment(ExperimentSpec.load(path)).run().summary()
    assert first == again


def test_model_ref_inline_config_roundtrip():
    cfg = get_config("paper-charlm")
    ref = ModelRef.from_config(cfg)
    ref2 = ModelRef.from_dict(ref.to_dict())
    assert ref2.resolve() == cfg


def test_model_ref_reduced_overrides():
    ref = ModelRef("paper-charlm", reduced=True,
                   reduced_kw=dict(layers=1, d_model=64, d_ff=64, vocab=256),
                   overrides=dict(lstm_hidden=64, max_context=16))
    cfg = ref.resolve()
    assert cfg.num_layers == 1 and cfg.lstm_hidden == 64
    # survives a JSON hop (tuple fields come back as tuples)
    spec = ExperimentSpec(model=ref)
    cfg2 = ExperimentSpec.from_json(spec.to_json()).model.resolve()
    assert cfg2 == cfg


def test_spec_rejects_unknown_learner():
    with pytest.raises(AssertionError):
        ExperimentSpec(learner="quantum")


# ------------------------------------------------------ strategy registry
def test_registry_has_seeded_strategies():
    assert {"sync", "async"} <= set(STRATEGIES)
    assert get_strategy("sync").mode == "sync"


def test_unknown_strategy_raises():
    with pytest.raises(ValueError, match="unknown strategy"):
        get_strategy("carbon-aware-nope")


def test_register_strategy_decorator():
    @register_strategy("test-dummy")
    class Dummy(Strategy):
        pass
    try:
        assert isinstance(get_strategy("test-dummy"), Dummy)
        assert Dummy.mode == "test-dummy"
    finally:
        del STRATEGIES["test-dummy"]


def test_run_task_shim_warns_and_matches_api():
    from repro.federated import SurrogateLearner, run_task
    spec = _spec(conc=30)
    cfg = spec.model.resolve()
    with pytest.warns(DeprecationWarning):
        tr = run_task(cfg, spec.federated, spec.run,
                      SurrogateLearner(cfg, spec.federated, spec.run))
    assert tr.summary() == Experiment(spec).run().summary()


@pytest.mark.parametrize("shim_name,mode", [("run_sync", "sync"),
                                            ("run_async", "async")])
def test_run_sync_async_shims_warn_and_match_api(shim_name, mode):
    """The pre-`repro.api` free functions survive only as deprecated
    shims: they must warn and reproduce the Experiment result exactly."""
    import repro.federated as fed_pkg
    from repro.federated import SurrogateLearner
    spec = _spec(mode=mode, conc=30, max_rounds=40)
    cfg = spec.model.resolve()
    shim = getattr(fed_pkg, shim_name)
    with pytest.warns(DeprecationWarning, match=shim_name):
        tr = shim(cfg, spec.federated, spec.run,
                  SurrogateLearner(cfg, spec.federated, spec.run))
    assert tr.summary() == Experiment(spec).run().summary()


# ------------------------------------------------------------- callbacks
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_callback_ordering(mode):
    spec = _spec(mode=mode, conc=20, max_rounds=15)
    calls = []
    res = Experiment(spec).run(
        on_start=lambda s: calls.append(("start", s)),
        on_round=lambda ev: calls.append(("round", ev)),
        on_complete=lambda r: calls.append(("complete", r)))
    kinds = [k for k, _ in calls]
    assert kinds[0] == "start" and kinds[-1] == "complete"
    assert kinds.count("start") == kinds.count("complete") == 1
    events = [ev for k, ev in calls if k == "round"]
    assert len(events) == res.rounds > 0
    assert all(ev.mode == mode for ev in events)
    # rounds strictly increase, task clock and session count never decrease
    for a, b in zip(events, events[1:]):
        assert b.round_idx == a.round_idx + 1
        assert b.t_s >= a.t_s
        assert b.n_sessions >= a.n_sessions
    assert calls[0][1] is spec
    assert calls[-1][1].summary() == res.summary()


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_round_event_fields(mode):
    """RoundEvent is the streaming contract: every field must be populated
    and internally consistent on both strategies."""
    from repro.federated import RoundEvent
    spec = _spec(mode=mode, conc=25, max_rounds=20)
    events = []
    res = Experiment(spec).run(on_round=events.append)
    assert events and all(isinstance(ev, RoundEvent) for ev in events)
    for ev in events:
        assert ev.mode == mode
        assert ev.t_s > 0.0
        assert ev.perplexity > 0.0
        assert ev.smoothed_perplexity > 0.0
        assert 0 < ev.n_sessions <= res.log.n_sessions
    # the last event matches the final result (modulo the cancelled
    # sessions flushed after the final update)
    last = events[-1]
    assert last.round_idx == res.rounds
    assert last.t_s == pytest.approx(res.duration_h * 3600.0)
    assert last.smoothed_perplexity == pytest.approx(
        res.smoothed_perplexity)
    # smoothing is an EWMA of the raw stream: first event's smoothed value
    # equals its raw perplexity
    assert events[0].smoothed_perplexity == pytest.approx(
        events[0].perplexity)


# ------------------------------------------------------------ environment
def test_environment_roundtrip():
    env = Environment(network=NetworkEnergyModel(e_access_nj=99.0),
                      fleet=FLEET[:3], pue=1.5)
    env2 = Environment.from_dict(env.to_dict())
    assert env2.network.e_access_nj == 99.0
    assert env2.pue == 1.5
    assert env2.fleet == tuple(FLEET[:3])


def test_network_override_changes_breakdown():
    spec = _spec(conc=30)
    base = Experiment(spec).run().carbon
    hot = Experiment(spec.replace(environment=Environment(
        network=NetworkEnergyModel(e_access_nj=526.0)))).run().carbon
    assert hot.upload_kg > base.upload_kg
    assert hot.download_kg > base.download_kg
    assert hot.client_compute_kg == pytest.approx(base.client_compute_kg)


def test_intensity_override_scales_carbon():
    spec = _spec(conc=30)
    base = Experiment(spec).run().carbon
    env = Environment(carbon_intensity={
        k: 10.0 * v for k, v in Environment().carbon_intensity.items()})
    scaled = Experiment(spec.replace(environment=env)).run().carbon
    assert scaled.client_compute_kg == pytest.approx(
        10.0 * base.client_compute_kg)
    assert scaled.total_kg == pytest.approx(10.0 * base.total_kg)


def test_partial_intensity_table_falls_back():
    # a partial custom table must not crash runs whose sampled countries
    # (or datacenter countries) are missing from it
    spec = _spec(conc=20, max_rounds=5)
    env = Environment(carbon_intensity={"US": 380.0})
    res = Experiment(spec.replace(environment=env)).run()
    assert res.carbon.total_kg > 0


def test_inline_config_spec_json_equality():
    spec = ExperimentSpec(
        model=ModelRef.from_config(get_config("paper-charlm")))
    assert ExperimentSpec.from_json(spec.to_json()) == spec


def test_prebuilt_learner_is_used_then_rebuilt():
    spec = _spec(conc=20, max_rounds=5)
    exp = Experiment(spec)
    pre = exp.build_learner()
    res1 = exp.run()
    assert exp.learner is pre            # run consumed the pre-built learner
    res2 = exp.run()                     # second run rebuilds -> reproducible
    assert exp.learner is not pre
    assert res1.summary() == res2.summary()


def test_fleet_override_reaches_telemetry():
    spec = _spec(conc=20, max_rounds=5)
    one_phone = dataclasses.replace(FLEET[0], weight=1.0)
    res = Experiment(spec.replace(
        environment=Environment(fleet=(one_phone,)))).run()
    assert {s.device for s in res.log.sessions} == {one_phone.name}


# -------------------------------------------------------------- advisor
def test_advisor_cache_hits_on_equal_config():
    from repro.core.advisor import GreenAdvisor
    adv = GreenAdvisor(get_config("paper-charlm"),
                       RunConfig(target_perplexity=175.0, max_rounds=60))
    fed = FederatedConfig(concurrency=30, aggregation_goal=24)
    r1 = adv.evaluate(fed)
    # a distinct-but-equal config must hit the same cache entry
    r2 = adv.evaluate(FederatedConfig(concurrency=30, aggregation_goal=24))
    assert r1 is r2


def test_advisor_flags_infeasible():
    from repro.core.advisor import GreenAdvisor
    adv = GreenAdvisor(get_config("paper-charlm"),
                       RunConfig(target_perplexity=175.0))
    grid = dict(mode=("sync",), concurrency=(50,), local_epochs=(1,))
    ok = adv.search(grid=grid)
    assert ok and all(r.feasible for r in ok)
    bad = adv.search(grid=grid, max_hours=1e-4)
    assert bad and all(not r.feasible for r in bad)
    assert "[INFEASIBLE]" in bad[0].why()
