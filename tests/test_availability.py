"""Availability is first-class (PR 8): diurnal device eligibility,
mid-session churn with checkpoint/resume salvage, and sync
over-selection.

The contract under test:

* an all-available ``AvailabilityModel`` (the default — even with the
  checkpoint/retry knobs armed) is **bit-for-bit** today's
  availability-blind engine, on static AND diurnal intensity schedules;
* with availability gating + churn live, the columnar engines, lane
  packs and the scalar oracle agree seed for seed — including the
  checkpoint/resume salvage arithmetic and sync over-selection;
* the waste split is exact: ``wasted_kg == salvaged_kg + lost_kg`` and
  ``contributed_kg + wasted_kg == total_kg`` (plain ``==``, not approx)
  in materialized AND streaming telemetry, and the two paths agree
  bit-for-bit;
* sync over-selection dispatches ``ceil((1+f)*goal)``, closes on the
  goal-th completer and cancels (and charges) the surplus;
* the carbon-aware CO2e win over async survives the anti-correlated
  default availability model (the PR's acceptance criterion);
* every new construction-time knob validates with a ``ValueError``, and
  the whole model JSON round-trips through ``ExperimentSpec``.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (Environment, Experiment, ExperimentSpec, ModelRef,
                       sweep)
from repro.configs import FederatedConfig, RunConfig, get_config
from repro.core.availability import (AVAIL_SHAPE, AvailabilityModel,
                                     diurnal_availability)
from repro.core.carbon import DIURNAL_SHAPE
from repro.core.faults import FaultModel
from repro.core.streaming import StreamedLog
from repro.core.telemetry import OUTCOMES
from repro.federated.reference import run_scalar
from repro.federated.runtime import get_strategy
from repro.federated.surrogate import SurrogateLearner

CFG = get_config("paper-charlm")

_COLS = ("client_id", "round_idx", "device_idx", "country_idx",
         "download_s", "compute_s", "upload_s", "bytes_down", "bytes_up",
         "start_t", "end_t", "outcome", "staleness")

_MIX = tuple(Environment().country_mix)

# canonical anti-correlated evening-charging-peak model (3 h segments:
# admission gating dominates, churn needs the task clock to cross hours)
_DIURNAL_AV = diurnal_availability(_MIX)
# fine-grained churny model: 288 alternating 5-minute segments, so an
# admitted draw in the (0.45, 0.95) band exits eligibility at the next
# boundary — mid-session churn within minutes-long sessions
_CHURNY_AV = AvailabilityModel(
    eligibility_schedule={c: (0.95, 0.45) * 144 for c in _MIX})

_AVAILS = (_DIURNAL_AV, _CHURNY_AV)


def _spec(mode, conc, goal_frac, seed, max_rounds, avail=_CHURNY_AV,
          env_kw=None, telemetry="full", dropout=0.05,
          **fed_kw) -> ExperimentSpec:
    return ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(
            mode=mode, concurrency=conc,
            aggregation_goal=max(1, int(conc * goal_frac)),
            seed=seed, dropout_rate=dropout, **fed_kw),
        run=RunConfig(target_perplexity=175.0, max_rounds=max_rounds,
                      telemetry=telemetry, telemetry_sample=64),
        environment=Environment(availability=avail, **(env_kw or {})),
        learner="surrogate")


def _assert_same(res_a, res_b, cols=True) -> None:
    sa, sb = res_a.summary(), res_b.summary()
    assert sa == sb, {k: (sa[k], sb[k]) for k in sa if sa[k] != sb[k]}
    assert res_a.log.participation() == res_b.log.participation()
    if cols:
        ca, cb = res_a.log.columns(), res_b.log.columns()
        for f in _COLS:
            assert np.array_equal(getattr(ca, f), getattr(cb, f)), f


def _assert_split_exact(c) -> None:
    """The PR's accounting identity, as plain float equality."""
    assert c.wasted_kg == c.salvaged_kg + c.lost_kg
    assert c.contributed_kg + c.wasted_kg == c.total_kg


# ------------------------------------------------------ all-available identity
@pytest.mark.parametrize("mode", ["sync", "async", "carbon-aware"])
@pytest.mark.parametrize("diurnal", [False, True])
def test_all_available_model_is_bit_identical(mode, diurnal):
    """The default AvailabilityModel — even with checkpoint_period_s and
    retry_limit armed — takes the availability-free fast path untouched:
    summaries AND session columns are bit-for-bit the availability-blind
    run, on static and diurnal intensity schedules."""
    env_kw = {"intensity_schedule": Environment.preset("diurnal")
              .intensity_schedule} if diurnal else {}
    armed = _spec(mode, 24, 0.8, 11, 8, avail=AvailabilityModel(),
                  env_kw=env_kw, retry_limit=3, retry_backoff_s=60.0,
                  checkpoint_period_s=300.0)
    plain = _spec(mode, 24, 0.8, 11, 8, avail=AvailabilityModel(),
                  env_kw=env_kw)
    assert not AvailabilityModel().enabled
    ra, rb = Experiment(armed).run(), Experiment(plain).run()
    _assert_same(ra, rb)
    assert ra.log.participation().get("interrupted", 0) == 0
    # the waste split degenerates cleanly: nothing salvaged, lost == waste
    assert ra.carbon.salvaged_kg == 0.0
    assert ra.carbon.lost_kg == ra.carbon.wasted_kg


# --------------------------------------------------- serial == lane == oracle
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_churny_lane_pack_matches_serial_property(seed0):
    """Randomized heterogeneous packs (all three modes, both availability
    models, faults riding along, mixed checkpoint / retry / over-selection
    knobs) are bit-for-bit equal to per-spec serial runs — summary scalars
    AND session columns. Lanes with DIFFERENT AvailabilityModels pack
    together, including availability-free lanes."""
    rng = np.random.default_rng(seed0)
    specs = []
    for j in range(int(rng.integers(3, 6))):
        mode = ("sync", "async", "carbon-aware")[int(rng.integers(3))]
        avail = (_DIURNAL_AV, _CHURNY_AV,
                 AvailabilityModel())[int(rng.integers(3))]
        fault = (FaultModel(),
                 FaultModel(hazard={"WORLD": 0.08},
                            seed=3))[int(rng.integers(2))]
        specs.append(_spec(
            mode=mode, conc=int(rng.integers(10, 40)),
            goal_frac=float(rng.uniform(0.4, 1.0)),
            seed=int(rng.integers(0, 2 ** 31)),
            max_rounds=int(rng.integers(4, 12)),
            avail=avail, env_kw={"fault": fault},
            retry_limit=int(rng.integers(0, 4)),
            retry_backoff_s=float(rng.choice([0.0, 20.0])),
            checkpoint_period_s=float(rng.choice([0.0, 60.0, 150.0])),
            over_select_fraction=(float(rng.choice([0.0, 0.25]))
                                  if mode == "sync" else 0.0)))
    serial = [Experiment(s).run() for s in specs]
    lane = sweep(specs, workers=1, vectorize=True)
    saw_interrupted = False
    for rl, rs in zip(lane, serial):
        _assert_same(rl, rs)
        _assert_split_exact(rl.carbon)
        if rl.log.participation().get("interrupted"):
            saw_interrupted = True
    assert saw_interrupted


@pytest.mark.parametrize("mode", ["sync", "async", "carbon-aware"])
def test_churny_engine_matches_scalar_oracle(mode):
    """With availability churn + faults + checkpoint/resume retries live,
    the columnar engine replays the scalar oracle seed for seed:
    identical outcomes/participation, carbon split (salvaged and lost
    included) to the scalar-vs-vector libm tolerance."""
    env = Environment(availability=_CHURNY_AV,
                      fault=FaultModel(hazard={"WORLD": 0.06}, seed=3))
    fed = FederatedConfig(mode=mode, concurrency=28, aggregation_goal=20,
                          seed=5, retry_limit=2, retry_backoff_s=20.0,
                          checkpoint_period_s=60.0)
    run = RunConfig(target_perplexity=175.0, max_rounds=12)
    vec = get_strategy(mode).run(CFG, fed, run,
                                 SurrogateLearner(CFG, fed, run),
                                 sampler=env.sampler(CFG, fed, 64),
                                 estimator=env.estimator())
    ref = run_scalar(CFG, fed, run, SurrogateLearner(CFG, fed, run),
                     sampler=env.sampler(CFG, fed, 64),
                     estimator=env.estimator())
    assert vec.rounds == ref.rounds
    assert vec.log.participation() == ref.log.participation()
    assert vec.log.participation().get("interrupted", 0) > 0
    assert vec.carbon.salvaged_kg > 0
    for k, v in vec.carbon.as_dict().items():
        assert v == pytest.approx(ref.carbon.as_dict()[k], rel=1e-9), k
    bv, br = vec.log.columns(), ref.log.columns()
    dmap = np.asarray([bv.device_names.index(x) for x in br.device_names])
    cmap = np.asarray([bv.country_names.index(x) for x in br.country_names])
    assert np.array_equal(bv.client_id, br.client_id)
    assert np.array_equal(bv.round_idx, br.round_idx)
    assert np.array_equal(bv.outcome, br.outcome)
    assert np.array_equal(bv.device_idx, dmap[br.device_idx])
    assert np.array_equal(bv.country_idx, cmap[br.country_idx])
    for f in ("download_s", "compute_s", "upload_s", "start_t", "end_t"):
        np.testing.assert_allclose(getattr(bv, f), getattr(br, f),
                                   rtol=1e-9, atol=1e-12, err_msg=f)


# ----------------------------------------------------- exact salvage split
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_salvaged_plus_lost_sums_exactly_to_wasted(mode):
    """Checkpointed churn splits the waste: ``wasted == salvaged + lost``
    and ``contributed + wasted == total`` hold as plain float equality in
    materialized AND streaming telemetry, the two agree bit-for-bit, both
    parts are strictly positive, and the scalar estimator twin agrees."""
    spec = _spec(mode, 24, 0.75, 5, 10, retry_limit=2,
                 retry_backoff_s=20.0, checkpoint_period_s=60.0)
    full = Experiment(spec).run()
    stream = Experiment(spec.replace(run=dataclasses.replace(
        spec.run, telemetry="streaming"))).run()
    for res in (full, stream):
        c = res.carbon
        _assert_split_exact(c)
        assert c.salvaged_kg > 0 and c.lost_kg > 0
        assert res.log.participation().get("interrupted", 0) > 0
    assert isinstance(stream.log, StreamedLog)
    assert full.summary() == stream.summary()                # bit-for-bit
    assert full.carbon.salvaged_kg == stream.carbon.salvaged_kg
    assert full.carbon.lost_kg == stream.carbon.lost_kg
    scalar = spec.environment.estimator().estimate_scalar(full.log)
    assert full.carbon.salvaged_kg == pytest.approx(scalar.salvaged_kg,
                                                    rel=1e-9)
    assert full.carbon.lost_kg == pytest.approx(scalar.lost_kg, rel=1e-9)
    # the split keys surface in the serialized breakdown
    d = full.carbon.as_dict()
    assert d["salvaged_kg"] == full.carbon.salvaged_kg
    assert d["lost_kg"] == full.carbon.lost_kg


def test_checkpoint_salvage_requires_resume():
    """Salvage is only real when a retry actually resumes: with
    ``retry_limit=0`` (no resume) or ``checkpoint_period_s=0`` nothing is
    salvaged and ``lost == wasted`` exactly; with both armed the salvage
    shows up, the resume arithmetic redoes only the remainder, and the
    engine trajectory actually diverges from the redo-everything twin."""
    from repro.federated.runtime import _retry_rem
    from repro.core.telemetry import OUTCOME_CODE
    base = dict(mode="async", conc=24, goal_frac=0.75, seed=5,
                max_rounds=10, retry_backoff_s=20.0)
    for kw in ({"retry_limit": 0, "checkpoint_period_s": 60.0},
               {"retry_limit": 2, "checkpoint_period_s": 0.0}):
        res = Experiment(_spec(**base, **kw)).run()
        assert res.carbon.salvaged_kg == 0.0
        assert res.carbon.lost_kg == res.carbon.wasted_kg
        _assert_split_exact(res.carbon)
    ckpt = Experiment(_spec(**base, retry_limit=2,
                            checkpoint_period_s=60.0)).run()
    redo = Experiment(_spec(**base, retry_limit=2,
                            checkpoint_period_s=0.0)).run()
    assert ckpt.carbon.salvaged_kg > 0 and redo.carbon.salvaged_kg == 0.0
    # the resumed children really run shorter sessions: the engine
    # trajectories diverge row-for-row (same seeds, same draws)
    assert not np.array_equal(ckpt.log.columns().compute_s,
                              redo.log.columns().compute_s)
    # the remainder arithmetic itself: an interruption 130 s into a 200 s
    # plan with 60 s checkpoints salvages 120 s -> the resume redoes 0.4
    # of the original; a second interruption before the next checkpoint
    # salvages nothing more; failed rows always redo their full remainder
    I, F = OUTCOME_CODE["interrupted"], OUTCOME_CODE["failed"]
    r1 = _retry_rem(np.asarray([I], np.int8), np.asarray([200.0]),
                    np.asarray([130.0]), np.asarray([1.0]), 60.0)
    assert r1[0] == pytest.approx(0.4)
    r2 = _retry_rem(np.asarray([I], np.int8), np.asarray([80.0]),
                    np.asarray([30.0]), r1, 60.0)
    assert r2[0] == r1[0]
    assert _retry_rem(np.asarray([F], np.int8), np.asarray([200.0]),
                      np.asarray([130.0]), np.asarray([0.4]), 60.0)[0] \
        == 0.4
    # interrupted keeps its label even when a resume went out — churn
    # stays separable from crash-retries in the outcome taxonomy
    assert ckpt.log.participation().get("interrupted", 0) > 0
    assert OUTCOMES[-1] == "interrupted"


# -------------------------------------------------------- sync over-selection
def test_sync_over_selection_cancels_surplus():
    """over_select_fraction dispatches ceil((1+f)*goal) per round, the
    round closes on the goal-th completer, and the surplus is relabeled
    ``cancelled`` (and charged as waste) — identically in serial, lane
    and oracle runs."""
    spec = _spec("sync", 40, 0.5, 9, 8, avail=AvailabilityModel(),
                 dropout=0.0, over_select_fraction=0.3)
    res = Experiment(spec).run()
    goal, f = 20, 0.3
    ndisp = int(np.ceil((1 + f) * goal))                     # 26
    assert res.log.n_sessions == ndisp * res.rounds
    p = res.log.participation()
    assert p.get("cancelled", 0) > 0
    assert p["completed"] == goal * res.rounds               # goal-th closes
    assert res.carbon.wasted_kg > 0
    _assert_same(sweep([spec], workers=1, vectorize=True)[0], res)
    oracle = run_scalar(CFG, spec.federated, spec.run,
                        SurrogateLearner(CFG, spec.federated, spec.run),
                        sampler=spec.environment.sampler(
                            CFG, spec.federated, spec.seq_len),
                        estimator=spec.environment.estimator())
    assert oracle.log.participation() == p
    # f == 0 keeps the legacy dispatch width (full concurrency)
    plain = Experiment(_spec("sync", 40, 0.5, 9, 8,
                             avail=AvailabilityModel(), dropout=0.0)).run()
    assert plain.log.n_sessions == 40 * plain.rounds
    assert plain.log.participation().get("cancelled", 0) == 0


# ---------------------------------------------- carbon-aware x availability
def test_carbon_aware_win_survives_availability():
    """Acceptance: with the anti-correlated default availability model ON
    TOP of the diurnal grid, carbon-aware still reports strictly lower
    total CO2e than async at equal aggregation goal — and its probe
    screening (top-k mask intersected with the availability mask at the
    dispatch clock) wastes far fewer dispatches on ineligible devices."""
    env = Environment.preset("diurnal", availability=_DIURNAL_AV)
    run = RunConfig(target_perplexity=175.0, max_rounds=60)
    out = {}
    for mode in ("async", "carbon-aware"):
        fed = FederatedConfig(mode=mode, concurrency=100,
                              aggregation_goal=80)
        out[mode] = get_strategy(mode).run(
            CFG, fed, run, SurrogateLearner(CFG, fed, run),
            sampler=env.sampler(CFG, fed, 64), estimator=env.estimator())
    ca, asy = out["carbon-aware"], out["async"]
    assert ca.rounds == asy.rounds                   # same update budget
    assert ca.carbon.total_kg < 0.8 * asy.carbon.total_kg
    assert ca.final_perplexity == pytest.approx(asy.final_perplexity,
                                                rel=0.05)
    # the availability intersection is doing work: async burns thousands
    # of dispatches on ineligible devices, carbon-aware screens them out
    ia = ca.log.participation().get("interrupted", 0)
    ib = asy.log.participation().get("interrupted", 0)
    assert ib > 0 and ia < 0.25 * ib


def test_default_shape_is_anticorrelated_with_intensity():
    """The canonical availability shape peaks where the diurnal intensity
    shape peaks (evening charging vs evening fossil peak) and dips over
    the midday solar belly — the tension the PR's scheduling result turns
    on is structural, not tuned."""
    av = np.asarray(AVAIL_SHAPE)
    ci = np.asarray(DIURNAL_SHAPE)
    assert len(av) == len(ci) == 8
    assert av.min() >= 0 and av.max() <= 1
    # availability trough sits inside the low-intensity (solar) half
    assert ci[int(np.argmin(av))] < 0
    # positive correlation: cheap-carbon hours are scarce-device hours
    assert float(np.corrcoef(av, ci)[0, 1]) > 0.5


# ------------------------------------------------------- validation + wiring
def test_construction_time_validation():
    """Satellite: every new knob fails loudly at construction."""
    with pytest.raises(ValueError, match="eligibility"):
        AvailabilityModel(eligibility={"US": 1.5})
    with pytest.raises(ValueError, match="eligibility"):
        AvailabilityModel(eligibility={"US": -0.1})
    with pytest.raises(ValueError, match="eligibility_schedule"):
        AvailabilityModel(eligibility_schedule={"US": ()})
    with pytest.raises(ValueError, match="eligibility_schedule"):
        AvailabilityModel(eligibility_schedule={"US": (0.5, 2.0)})
    with pytest.raises(ValueError, match="eligibility_phase_h"):
        AvailabilityModel(eligibility_schedule={"US": (0.5,)},
                          eligibility_phase_h={"US": float("nan")})
    with pytest.raises(ValueError, match="checkpoint_period_s"):
        FederatedConfig(checkpoint_period_s=-1.0)
    with pytest.raises(ValueError, match="checkpoint_period_s"):
        FederatedConfig(checkpoint_period_s=float("inf"))
    with pytest.raises(ValueError, match="over_select_fraction"):
        FederatedConfig(over_select_fraction=-0.1)
    with pytest.raises(ValueError, match="over_select_fraction"):
        FederatedConfig(over_select_fraction=float("nan"))


def test_availability_json_round_trip():
    """AvailabilityModel (and the whole churny Environment + the new
    FederatedConfig knobs) survives the spec JSON round trip — and the
    round-tripped spec reruns bit-for-bit."""
    spec = _spec("async", 16, 0.8, 6, 6, avail=_DIURNAL_AV,
                 retry_limit=2, checkpoint_period_s=120.0)
    back = ExperimentSpec.from_json(spec.to_json())
    assert back.environment.availability == _DIURNAL_AV
    assert back.federated.checkpoint_period_s == 120.0
    assert AvailabilityModel.from_dict(
        AvailabilityModel().to_dict()) == AvailabilityModel()
    assert AvailabilityModel.from_dict(
        _CHURNY_AV.to_dict()) == _CHURNY_AV
    # the all-available default stays implicit in the JSON
    assert "availability" not in Environment().to_dict()
    _assert_same(Experiment(back).run(), Experiment(spec).run())
