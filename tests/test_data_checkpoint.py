"""Synthetic federated dataset statistics + checkpoint round-trip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.data import FederatedDataset, client_num_samples


def test_power_law_sample_counts():
    ns = np.asarray([client_num_samples(i) for i in range(4000)])
    assert 20 < ns.mean() < 60          # paper: mean ~34
    assert ns.min() >= 2
    assert (ns > 200).sum() > 5         # heavy tail exists


def test_determinism_and_client_disjointness():
    ds = FederatedDataset(vocab_size=1000, seq_len=16)
    a1 = ds.client_tokens(5)
    a2 = ds.client_tokens(5)
    np.testing.assert_array_equal(a1, a2)
    b = ds.client_tokens(6)
    assert a1.shape[1] == 16
    assert not (a1[: min(len(a1), len(b))] == b[: min(len(a1), len(b))]).all()


def test_non_iid_dialects():
    """Clients' unigram histograms must differ far beyond sampling noise."""
    ds = FederatedDataset(vocab_size=512, seq_len=64)
    h = []
    for c in (1, 2):
        t = ds.client_tokens(c, n_samples=64).reshape(-1)
        h.append(np.bincount(t, minlength=512) / t.size)
    l1 = np.abs(h[0] - h[1]).sum()
    assert l1 > 0.3


def test_chars_deterministic_and_padded():
    ds = FederatedDataset(vocab_size=100, seq_len=4, char_vocab=64,
                          max_word_len=12)
    w = np.asarray([[1, 50, 99]])
    c1, c2 = ds.word_chars(w), ds.word_chars(w)
    np.testing.assert_array_equal(c1, c2)
    assert c1.shape == (1, 3, 12)
    assert (c1 >= 0).all() and (c1 < 64).all()
    # frequent (low-id) words are shorter
    len1 = (ds.word_chars(np.asarray([1])) > 0).sum()
    len99 = (ds.word_chars(np.asarray([99])) > 0).sum()
    assert len1 <= len99


def test_client_batches_padding_mask():
    ds = FederatedDataset(vocab_size=100, seq_len=8)
    bs = ds.client_batches(3, batch_size=16, local_epochs=2)
    assert len(bs) >= 2
    for b in bs:
        assert b["tokens"].shape == (16, 8)
        assert b["mask"].shape == (16, 7)


def test_checkpoint_roundtrip(tmp_path):
    tree = {
        "params": {"a/b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "c": jnp.asarray([1, 2], jnp.int32)},
        "step": jnp.asarray(7, jnp.int32),
    }
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, tree, meta={"round": 7})
    loaded, meta = load_checkpoint(path)
    assert meta["round"] == 7
    np.testing.assert_array_equal(loaded["params"]["a/b"],
                                  np.asarray(tree["params"]["a/b"]))
    assert loaded["params"]["a/b"].dtype == np.float32
    assert int(loaded["step"]) == 7


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_checkpoint_roundtrip_property(tmp_path_factory, seed):
    rng = np.random.default_rng(seed)
    tree = {"x": rng.normal(size=(rng.integers(1, 20),)).astype(np.float32)}
    path = str(tmp_path_factory.mktemp("ck") / "c")
    save_checkpoint(path, tree)
    loaded, _ = load_checkpoint(path)
    np.testing.assert_array_equal(loaded["x"], tree["x"])
