"""Time-varying grid intensity + the carbon-aware strategy (PR 5).

The carbon axis is now intensity(country, t): per-country piecewise-
constant diurnal schedules flow Environment -> IntensityModel ->
estimator (all three reduction paths) and drive the "carbon-aware"
FedBuff strategy's cohort selection. Invariants under test:

* flat/constant schedules are bit-for-bit identical to the static model
  across sync, async, carbon-aware and lane-pack paths (hypothesis
  property test);
* the vectorized schedule lookup (point + span mean) matches hand math,
  including phase offsets and cycle wrap-around;
* the carbon-aware columnar engine == its scalar heap oracle seed for
  seed, static AND diurnal;
* carbon-aware beats plain async on total CO2e at equal aggregation goal
  on the default diurnal Environment (the PR's acceptance criterion);
* Environment presets ("diurnal", "flagship-only", "entry-heavy") and
  the intensity_schedule JSON round-trip.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (Environment, Experiment, ExperimentSpec, ModelRef,
                       sweep)
from repro.configs import FederatedConfig, RunConfig, get_config
from repro.core.carbon import (CARBON_INTENSITY, DIURNAL_SHAPE, UTC_OFFSET_H,
                               IntensityModel, diurnal_schedule)
from repro.core.estimator import CarbonEstimator
from repro.core.profiles import FLEET
from repro.core.telemetry import ClientSession, TaskLog
from repro.federated.reference import run_scalar
from repro.federated.runtime import get_strategy
from repro.federated.surrogate import SurrogateLearner

CFG = get_config("paper-charlm")
H = 3600.0


def _spec(mode, conc, goal, env, seed=0, max_rounds=15, **fed_kw):
    return ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(mode=mode, concurrency=conc,
                                  aggregation_goal=goal, seed=seed,
                                  **fed_kw),
        run=RunConfig(target_perplexity=175.0, max_rounds=max_rounds),
        environment=env, learner="surrogate")


# ------------------------------------------------------------ model lookup
def test_intensity_at_point_phase_and_wrap():
    m = IntensityModel(schedule={"US": (100.0, 300.0)},
                       phase_h={"US": 0.0})
    # 2 equal segments: [0, 12h) -> 100, [12h, 24h) -> 300, repeating
    assert m.intensity_at(("US",), 0.0)[0] == 100.0
    assert m.intensity_at(("US",), 11.99 * H)[0] == 100.0
    assert m.intensity_at(("US",), 12.0 * H)[0] == 300.0
    assert m.intensity_at(("US",), 36.0 * H)[0] == 300.0     # next day
    # static countries ignore t entirely
    assert m.intensity_at(("FR",), 5.0 * H)[0] == CARBON_INTENSITY["FR"]
    # phase shifts the cycle: +12h swaps the halves
    m2 = IntensityModel(schedule={"US": (100.0, 300.0)},
                        phase_h={"US": 12.0})
    assert m2.intensity_at(("US",), 1.0 * H)[0] == 300.0
    # negative offsets normalize mod 24
    m3 = IntensityModel(schedule={"US": (100.0, 300.0)},
                        phase_h={"US": -12.0})
    assert m3.intensity_at(("US",), 1.0 * H)[0] == 300.0
    # (n, V) broadcast: per-row clock x country vocab
    t = np.asarray([[0.0], [13.0 * H]])
    ci = m.intensity_at(("US", "FR"), t)
    assert ci.shape == (2, 2)
    assert ci[0, 0] == 100.0 and ci[1, 0] == 300.0
    assert ci[0, 1] == ci[1, 1] == CARBON_INTENSITY["FR"]


def test_mean_intensity_integrates_across_segments_and_days():
    m = IntensityModel(schedule={"US": (100.0, 300.0)})
    assert m.mean_intensity("US", 0.0, 24 * H) == pytest.approx(200.0)
    assert m.mean_intensity("US", 0.0, 12 * H) == pytest.approx(100.0)
    assert m.mean_intensity("US", 6 * H, 18 * H) == pytest.approx(200.0)
    # 3/4 of the span in the first segment
    assert m.mean_intensity("US", 9 * H, 13 * H) == pytest.approx(150.0)
    # wraps across the cycle boundary
    assert m.mean_intensity("US", 18 * H, 30 * H) == pytest.approx(200.0)
    # multi-day span converges to the cycle mean
    assert m.mean_intensity("US", 0.0, 10 * 24 * H) == pytest.approx(200.0)
    # zero-length span falls back to the point value
    assert m.mean_intensity("US", 13 * H, 13 * H) == 300.0


def test_constant_schedule_collapses_to_static():
    m = IntensityModel(schedule={"US": (222.0, 222.0, 222.0)})
    assert not m.is_dynamic()
    assert m.intensity("US") == 222.0            # exact, not 3*222/3
    assert m.intensity_at(("US",), 12345.678)[0] == 222.0
    # one-segment schedules are the same degenerate case
    m1 = IntensityModel(schedule={"FR": (50.0,)})
    assert not m1.is_dynamic(("FR",))
    assert m1.intensity("FR") == 50.0
    # a genuinely varying schedule is dynamic; cycle mean is the average
    md = IntensityModel(schedule={"US": (100.0, 300.0)})
    assert md.is_dynamic() and md.is_dynamic(("US", "FR"))
    assert not md.is_dynamic(("FR",))
    assert md.intensity("US") == pytest.approx(200.0)


def test_diurnal_schedule_preserves_cycle_mean():
    sched = diurnal_schedule()
    assert set(sched) == set(CARBON_INTENSITY)
    assert sum(DIURNAL_SHAPE) == pytest.approx(0.0)
    for c, vals in sched.items():
        assert len(vals) == len(DIURNAL_SHAPE)
        assert sum(vals) / len(vals) == pytest.approx(CARBON_INTENSITY[c])
        assert min(vals) > 0
    m = IntensityModel(schedule=sched, phase_h=UTC_OFFSET_H)
    # phases differ, so country minima land at different task-clock hours
    us = [m.intensity_at(("US",), h * H)[0] for h in range(24)]
    jp = [m.intensity_at(("JP",), h * H)[0] for h in range(24)]
    assert int(np.argmin(us)) != int(np.argmin(jp))


# --------------------------------------------------------------- estimator
def _session(country, start_t, dn, cp, up, device="pixel-3"):
    return ClientSession(
        client_id=1, round_idx=0, device=device, country=country,
        download_s=dn, compute_s=cp, upload_s=up, bytes_down=64e6,
        bytes_up=64e6, start_t=start_t, end_t=start_t + dn + cp + up,
        outcome="completed")


def test_estimator_charges_each_phase_at_its_span_mean():
    sched = {"US": (100.0, 300.0)}
    est_d = CarbonEstimator(intensity=IntensityModel(schedule=sched))
    # session: download sits fully in the 100-segment, compute straddles
    # the 12h edge half-half (mean 200), upload fully in the 300-segment
    s = _session("US", 10 * H, dn=1 * H, cp=2 * H, up=1 * H)
    d = est_d.session_carbon(s)
    est_100 = CarbonEstimator(
        intensity=IntensityModel(table={**CARBON_INTENSITY, "US": 100.0}))
    est_200 = CarbonEstimator(
        intensity=IntensityModel(table={**CARBON_INTENSITY, "US": 200.0}))
    est_300 = CarbonEstimator(
        intensity=IntensityModel(table={**CARBON_INTENSITY, "US": 300.0}))
    assert d["download_kg"] == pytest.approx(
        est_100.session_carbon(s)["download_kg"])
    assert d["client_compute_kg"] == pytest.approx(
        est_200.session_carbon(s)["client_compute_kg"])
    assert d["upload_kg"] == pytest.approx(
        est_300.session_carbon(s)["upload_kg"])
    # and the batch path agrees with the scalar loop on a mixed log
    log = TaskLog()
    for i, c in enumerate(("US", "FR", "US", "IN")):
        log.log_session(_session(c, i * 7 * H, dn=0.5 * H, cp=5 * H,
                                 up=0.25 * H, device=FLEET[i].name))
    log.duration_s = 40 * H
    vec, ref = est_d.estimate(log), est_d.estimate_scalar(log)
    for k, v in vec.as_dict().items():
        assert v == pytest.approx(ref.as_dict()[k], rel=1e-9), k
    # a diurnal grid prices this log differently from the static table
    static = CarbonEstimator().estimate(log)
    assert vec.total_kg != static.total_kg


# ----------------------------------------------- flat-schedule degeneracy
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=1, max_value=6),
       st.floats(min_value=15.0, max_value=900.0),
       st.floats(min_value=-12.0, max_value=14.0),
       st.integers(min_value=0, max_value=10_000))
def test_flat_schedule_bit_identical_property(n_seg, value, phase, seed):
    """Satellite: a constant intensity_schedule reproduces the static
    model bit-for-bit — same summary scalars — across sync, async and
    carbon-aware, serial AND lane-packed."""
    consts = {"US": value, "IN": round(value * 1.7, 3), "FR": 42.0}
    env_static = Environment(
        carbon_intensity={**CARBON_INTENSITY, **consts})
    env_sched = Environment(
        intensity_schedule={c: [v] * n_seg for c, v in consts.items()},
        intensity_phase_h={"US": phase})
    for mode in ("sync", "async", "carbon-aware"):
        mk = lambda env: _spec(mode, 24, 18, env,       # noqa: B023,E731
                               seed=seed, max_rounds=8)
        base = Experiment(mk(env_static)).run().summary()
        flat = Experiment(mk(env_sched)).run().summary()
        assert base == flat, (mode, {k: (base[k], flat[k])
                                     for k in base if base[k] != flat[k]})
        lane = sweep([mk(env_sched)], workers=1, vectorize=True)
        assert lane[0].summary() == base, mode


# ------------------------------------------- carbon-aware strategy engine
def test_country_draw_pins_plan_batch_country_column():
    """The carbon-aware screen is only correct because ``country_draw``
    reproduces the planner's country draw exactly — pin the coupling so a
    future re-keying of the fused planner uniforms cannot silently desync
    the filter (every equivalence test would stay green if it did)."""
    env = Environment(country_mix={"US": 0.3, "FR": 0.3, "IN": 0.4})
    for fed in (FederatedConfig(seed=5),
                FederatedConfig(seed=91, compression="int8")):
        for sampler in (env.sampler(CFG, fed, 64),
                        Environment().sampler(CFG, fed, 64)):
            ids = np.random.default_rng(fed.seed).integers(
                0, 5_000_000, 300).astype(np.int64)
            for r in (0, 7, 1_000_003):
                assert np.array_equal(
                    sampler.country_draw(ids, r),
                    sampler.plan_batch(ids, r).country_idx), (fed.seed, r)



@pytest.mark.parametrize("env", [Environment(),
                                 Environment.preset("diurnal")],
                         ids=["static", "diurnal"])
@pytest.mark.parametrize("conc,goal", [(100, 80), (37, 30)])
def test_carbon_aware_matches_scalar_oracle(env, conc, goal):
    """The columnar carbon-aware engine (window-batched merge + probed
    replacement ids) reproduces the scalar heap oracle seed for seed."""
    fed = FederatedConfig(mode="carbon-aware", concurrency=conc,
                          aggregation_goal=goal)
    run = RunConfig(target_perplexity=175.0, max_rounds=40)
    vec = get_strategy("carbon-aware").run(
        CFG, fed, run, SurrogateLearner(CFG, fed, run),
        sampler=env.sampler(CFG, fed, 64), estimator=env.estimator())
    ref = run_scalar(CFG, fed, run, SurrogateLearner(CFG, fed, run),
                     sampler=env.sampler(CFG, fed, 64),
                     estimator=env.estimator())
    assert vec.rounds == ref.rounds
    assert vec.log.n_sessions == ref.log.n_sessions
    assert vec.log.participation() == ref.log.participation()
    assert vec.duration_h == pytest.approx(ref.duration_h, rel=1e-9)
    for k, v in vec.carbon.as_dict().items():
        assert v == pytest.approx(ref.carbon.as_dict()[k], rel=1e-9), k
    assert vec.log.mean_staleness() == pytest.approx(
        ref.log.mean_staleness(), rel=1e-9)


def test_carbon_aware_beats_async_on_diurnal_environment():
    """Acceptance: at equal aggregation goal on the default diurnal
    Environment, carbon-aware reports lower total CO2e than async, with
    comparable convergence (same update count, similar perplexity)."""
    env = Environment.preset("diurnal")
    run = RunConfig(target_perplexity=175.0, max_rounds=60)
    out = {}
    for mode in ("async", "carbon-aware"):
        fed = FederatedConfig(mode=mode, concurrency=100,
                              aggregation_goal=80)
        out[mode] = get_strategy(mode).run(
            CFG, fed, run, SurrogateLearner(CFG, fed, run),
            sampler=env.sampler(CFG, fed, 64), estimator=env.estimator())
    ca, asy = out["carbon-aware"], out["async"]
    assert ca.rounds == asy.rounds                   # same update budget
    assert ca.carbon.total_kg < 0.85 * asy.carbon.total_kg
    # honest convergence: the filter cannot distort learning progress
    assert ca.final_perplexity == pytest.approx(asy.final_perplexity,
                                                rel=0.05)
    # the selection bias is visible in the logged country mix: the mean
    # static intensity of carbon-aware sessions sits well below async's
    def mean_ci(res):
        b = res.log.columns()
        ci = np.asarray([CARBON_INTENSITY[c] for c in b.country_names])
        return float(ci[b.country_idx].mean())
    assert mean_ci(ca) < 0.75 * mean_ci(asy)


def test_carbon_aware_exploration_floor_keeps_all_countries():
    """With a nonzero exploration floor every country keeps appearing in
    the cohort mix; explore=1.0 disables the filter entirely."""
    env = Environment.preset("diurnal")
    run = RunConfig(target_perplexity=175.0, max_rounds=40)
    fed = FederatedConfig(mode="carbon-aware", concurrency=64,
                          aggregation_goal=48, carbon_topk=3,
                          carbon_explore=0.15)
    res = get_strategy("carbon-aware").run(
        CFG, fed, run, SurrogateLearner(CFG, fed, run),
        sampler=env.sampler(CFG, fed, 64), estimator=env.estimator())
    b = res.log.columns()
    seen = set(np.asarray(b.country_names)[np.unique(b.country_idx)])
    assert seen == set(env.country_mix)          # nobody starved
    # explore=1.0: every dispatch takes the unscreened candidate
    fed_all = FederatedConfig(mode="carbon-aware", concurrency=64,
                              aggregation_goal=48, carbon_explore=1.0)
    res_all = get_strategy("carbon-aware").run(
        CFG, fed_all, run, SurrogateLearner(CFG, fed_all, run),
        sampler=env.sampler(CFG, fed_all, 64), estimator=env.estimator())
    ci = np.asarray([CARBON_INTENSITY[c]
                     for c in res_all.log.columns().country_names])
    mean_all = float(ci[res_all.log.columns().country_idx].mean())
    ci_b = np.asarray([CARBON_INTENSITY[c] for c in b.country_names])
    mean_filtered = float(ci_b[b.country_idx].mean())
    assert mean_filtered < mean_all              # the filter was doing work


def test_carbon_aware_time_shifts_selection_with_the_clock():
    """With schedules whose curves cross, the allowed country set at the
    current clock rotates across the day — time shifting, not just geo.
    (The default diurnal preset scales every country by the same relative
    shape, so there the *ranking* is phase-stable by design; crossing
    requires curves like a solar-heavy vs a coal-baseload grid.)"""
    from repro.federated.runtime import carbon_pick_ids
    env = Environment(
        country_mix={"US": 0.4, "IN": 0.4, "FR": 0.2},
        intensity_schedule={"US": [20.0, 500.0], "IN": [500.0, 20.0]})
    model = env.estimator().intensity
    names = ("US", "IN", "FR")
    top = lambda t: set(                                   # noqa: E731
        np.asarray(names)[np.argsort(model.intensity_at(names, t))[:1]])
    assert top(6 * H) == {"US"} and top(18 * H) == {"IN"}
    # and picks are batch-shape independent (row-local determinism)
    env = Environment.preset("diurnal")
    model = env.estimator().intensity
    fed = FederatedConfig(mode="carbon-aware", concurrency=8,
                          aggregation_goal=8)
    sampler = env.sampler(CFG, fed, 64)
    slots = np.arange(64, dtype=np.int64)
    gens = np.ones(64, np.int64)
    starts = np.linspace(0, 48 * H, 64)
    whole = carbon_pick_ids(sampler, model, fed, slots, gens, starts, 3)
    parts = np.concatenate(
        [carbon_pick_ids(sampler, model, fed, slots[i:i + 7],
                         gens[i:i + 7], starts[i:i + 7], 3)
         for i in range(0, 64, 7)])
    assert np.array_equal(whole, parts)


# ---------------------------------------------------------------- presets
def test_environment_fleet_presets():
    flag = Environment.preset("flagship-only")
    assert all(p.train_gflops >= 5.0 for p in flag.fleet)
    assert 0 < len(flag.fleet) < len(FLEET)
    heavy = Environment.preset("entry-heavy")
    assert len(heavy.fleet) == len(FLEET)
    base_w = {p.name: p.weight for p in FLEET}
    for p in heavy.fleet:
        if p.train_gflops < 2.0:
            assert p.weight == pytest.approx(3.0 * base_w[p.name])
        elif p.train_gflops >= 5.0:
            assert p.weight == pytest.approx(0.5 * base_w[p.name])
    with pytest.raises(ValueError, match="unknown Environment preset"):
        Environment.preset("nope")
    # presets compose with overrides
    env = Environment.preset("diurnal", pue=1.3)
    assert env.pue == 1.3 and env.intensity_model().is_dynamic()


def test_entry_heavy_fleet_shifts_compute_share():
    """Entry-heavy fleets spend longer on low-power silicon; flagship
    fleets finish fast at high power — the fig5 balance moves."""
    run = RunConfig(target_perplexity=175.0, max_rounds=12)
    shares = {}
    for name in ("flagship-only", "entry-heavy"):
        env = Environment.preset(name)
        fed = FederatedConfig(mode="sync", concurrency=40,
                              aggregation_goal=32)
        res = get_strategy("sync").run(
            CFG, fed, run, SurrogateLearner(CFG, fed, run),
            sampler=env.sampler(CFG, fed, 64), estimator=env.estimator())
        shares[name] = res.carbon.shares()["client_compute"]
    assert shares["entry-heavy"] != shares["flagship-only"]


# ------------------------------------------------------------- round-trip
def test_intensity_schedule_spec_json_roundtrip():
    env = Environment.preset("diurnal")
    spec = _spec("carbon-aware", 20, 16, env, max_rounds=6)
    re_spec = ExperimentSpec.from_json(spec.to_json())
    assert re_spec.environment.to_dict() == env.to_dict()
    assert re_spec.federated.carbon_topk == spec.federated.carbon_topk
    assert Experiment(re_spec).run().summary() == \
        Experiment(spec).run().summary()
