"""Sharding rules: divisibility fallbacks, serve vs train rules, batch spec.
Uses a handful of forced host devices in a subprocess-free way: these tests
only construct Meshes over the single real device via mesh abstractions, so
we test spec RESOLUTION (pure logic), not lowering (covered by dryrun)."""
import numpy as np
import pytest

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro import sharding as sh
from repro.configs import get_config
from repro.models import param_shapes_and_axes


class FakeMesh:
    """Duck-typed mesh exposing .shape mapping for spec_for."""
    def __init__(self, shape):
        self.shape = shape


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_basic_tp_and_fsdp():
    spec = sh.spec_for(("embed", "ffn"), (5120, 14336), SINGLE)
    assert spec == P("data", "model")
    spec = sh.spec_for(("vocab", "embed"), (131072, 5120), SINGLE)
    assert spec == P("model", "data")


def test_indivisible_head_fallback():
    # smollm: 9 heads on a 16-way model axis -> replicated heads
    spec = sh.spec_for(("embed", "heads", "head_dim"), (576, 9, 64), SINGLE)
    assert spec == P("data")
    # stablelm 32 heads -> sharded
    spec = sh.spec_for(("embed", "heads", "head_dim"), (2560, 32, 80), SINGLE)
    assert spec == P("data", "model")


def test_expert_priority_over_ffn():
    # granite: 32 experts % 16 == 0 -> experts take the model axis
    spec = sh.spec_for(("experts", "embed", "ffn"), (32, 1024, 512), SINGLE)
    assert spec == P("model", "data")
    # mixtral: 8 experts -> ffn takes the model axis
    spec = sh.spec_for(("experts", "embed", "ffn"), (8, 6144, 16384), SINGLE)
    assert spec == P(None, "data", "model")


def test_multi_pod_fsdp_spans_pod_and_data():
    spec = sh.spec_for(("embed", "ffn"), (5120, 14336), MULTI)
    assert spec == P(("pod", "data"), "model")
    # d_model=576: 576 % 32 == 0 -> still 2-axis FSDP
    spec = sh.spec_for(("embed", "ffn"), (576, 1536), MULTI)
    assert spec == P(("pod", "data"), "model")


def test_serve_rules_keep_weights_resident_2d():
    spec = sh.spec_for(("embed", "ffn"), (6144, 16384), SINGLE,
                       sh.SERVE_RULES)
    assert spec == P(None, ("model", "data"))
    # granite expert-parallel + data-sharded ffn
    spec = sh.spec_for(("experts", "embed", "ffn"), (32, 1024, 512), SINGLE,
                       sh.SERVE_RULES)
    assert spec == P("model", None, "data")
    # odd vocab replicates
    spec = sh.spec_for(("embed", "vocab"), (2048, 92553), SINGLE,
                       sh.SERVE_RULES)
    assert spec == P()


def test_batch_spec_divisibility():
    class M(FakeMesh):
        pass
    m = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert sh.batch_spec(m, 2, shape=(256, 10)) == P(("pod", "data"), None)
    # batch=1 -> unsharded
    assert sh.batch_spec(m, 2, shape=(1, 10)) == P(None, None)
    # batch=16 -> falls back to data-only
    assert sh.batch_spec(m, 2, shape=(16, 10)) == P(("data",), None)


@pytest.mark.parametrize("name", ["mistral-nemo-12b", "mixtral-8x22b",
                                  "rwkv6-7b", "recurrentgemma-2b",
                                  "seamless-m4t-medium"])
def test_all_param_leaves_resolve(name):
    cfg = get_config(name)
    shapes, axes = param_shapes_and_axes(cfg)
    for k in shapes:
        spec = sh.spec_for(axes[k], shapes[k].shape, SINGLE)
        # every placed axis must divide the dim
        for dim, entry in zip(shapes[k].shape, tuple(spec)):
            if entry is None:
                continue
            axes_t = (entry,) if isinstance(entry, str) else entry
            n = int(np.prod([SINGLE.shape[a] for a in axes_t]))
            assert dim % n == 0, (name, k, spec)
