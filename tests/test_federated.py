"""Federated substrate: client updates, aggregation, FedBuff weights,
compression round-trip, and FedAvg==centralized equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import FederatedConfig, RunConfig, get_config, reduced
from repro.data import FederatedDataset
from repro.federated import aggregation
from repro.federated.client import make_client_update, stack_batches
from repro.federated.real import RealLearner
from repro.optim import adam, momentum, server_optimizer, sgd

RNG = jax.random.PRNGKey(0)


def _tiny_charlm():
    cfg0 = get_config("paper-charlm")
    return dataclasses.replace(
        reduced(cfg0, layers=1, d_model=32, d_ff=32, vocab=128),
        lstm_hidden=32, max_context=8)


def test_client_update_is_sgd():
    """One local step with one batch == a plain SGD step."""
    from repro.models import get_model
    cfg = _tiny_charlm()
    model = get_model(cfg)
    params, _ = model.init(RNG)
    ds = FederatedDataset(vocab_size=cfg.vocab_size, seq_len=8,
                          char_vocab=cfg.char_vocab,
                          max_word_len=cfg.max_word_len)
    batches = ds.client_batches(7, batch_size=4, local_epochs=1)[:1]
    upd = make_client_update(model.loss, client_lr=0.1,
                             max_grad_norm=1e9)
    stacked, mask = stack_batches(batches, 1)
    delta, _ = upd(params, stacked, mask)
    g = jax.grad(lambda p: model.loss(p, jax.tree.map(
        lambda a: a[0], stacked))[0])(params)
    for k in params:
        np.testing.assert_allclose(np.asarray(delta[k]),
                                   -0.1 * np.asarray(g[k]),
                                   atol=1e-5)


def test_padding_steps_are_noops():
    from repro.models import get_model
    cfg = _tiny_charlm()
    model = get_model(cfg)
    params, _ = model.init(RNG)
    ds = FederatedDataset(vocab_size=cfg.vocab_size, seq_len=8,
                          char_vocab=cfg.char_vocab,
                          max_word_len=cfg.max_word_len)
    batches = ds.client_batches(7, batch_size=4, local_epochs=1)[:1]
    upd = make_client_update(model.loss, client_lr=0.1)
    s1, m1 = stack_batches(batches, 1)
    s4, m4 = stack_batches(batches, 4)          # 3 padded steps
    d1, _ = upd(params, s1, m1)
    d4, _ = upd(params, s4, m4)
    for k in d1:
        np.testing.assert_allclose(np.asarray(d1[k]), np.asarray(d4[k]),
                                   atol=1e-6)


def test_weighted_mean_deltas():
    deltas = {"w": jnp.asarray([[1.0, 1.0], [3.0, 3.0]])}
    out = aggregation.weighted_mean_deltas(deltas, jnp.asarray([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(out["w"]), [2.5, 2.5])


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=1, max_size=10),
       st.floats(0.1, 1.0))
def test_fedbuff_weights_monotone(staleness, alpha):
    w = aggregation.fedbuff_weights(staleness, alpha)
    assert (w <= 1.0 + 1e-12).all() and (w > 0).all()
    s = np.asarray(staleness, np.float64)
    order = np.argsort(s)
    assert (np.diff(w[order]) <= 1e-12).all()


def test_compression_roundtrip_small_error():
    x = {"a": jax.random.normal(RNG, (1000,)) * 0.01}
    y = aggregation.compress_roundtrip(x, block=256)
    err = float(jnp.max(jnp.abs(x["a"] - y["a"])))
    amax = float(jnp.max(jnp.abs(x["a"])))
    assert err <= amax / 127.0


def test_fedavg_single_client_equals_centralized():
    """concurrency=1, E=1, server SGD lr=1 => server params move exactly by
    the client delta (FedAvg == centralized local SGD)."""
    from repro.models import get_model
    cfg = _tiny_charlm()
    ds = FederatedDataset(vocab_size=cfg.vocab_size, seq_len=8,
                          char_vocab=cfg.char_vocab,
                          max_word_len=cfg.max_word_len)
    fed = FederatedConfig(mode="sync", concurrency=1, aggregation_goal=1,
                          client_lr=0.05, server_lr=1.0,
                          server_optimizer="sgd", client_batch_size=4)
    run = RunConfig(max_rounds=1)
    lr = RealLearner(cfg, fed, run, ds, max_client_steps=2)
    p0 = jax.device_get(lr.params)
    d, w = lr.client_delta(42, None)
    lr.apply([d], [w])
    p1 = jax.device_get(lr.params)
    for k in p0:
        np.testing.assert_allclose(p1[k], p0[k] + d[k], atol=1e-5)


def test_optimizers():
    params = {"w": jnp.asarray([1.0, 2.0])}
    g = {"w": jnp.asarray([0.1, -0.2])}
    p1, _ = sgd(0.5).update(g, sgd(0.5).init(params), params)
    np.testing.assert_allclose(np.asarray(p1["w"]), [0.95, 2.1])
    # adam first step = lr * sign-ish
    opt = adam(0.001)
    st_ = opt.init(params)
    p2, st2 = opt.update(g, st_, params)
    np.testing.assert_allclose(np.asarray(p2["w"]),
                               [1.0 - 0.001, 2.0 + 0.001], atol=1e-5)
    assert int(st2["step"]) == 1
    m = momentum(0.1, 0.9)
    p3, st3 = m.update(g, m.init(params), params)
    np.testing.assert_allclose(np.asarray(p3["w"]), [0.99, 2.02], atol=1e-6)
    with pytest.raises(ValueError):
        server_optimizer("nope", 0.1)
