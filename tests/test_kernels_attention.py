"""Attention kernels: Pallas (interpret=True) and the blocked pure-JAX
production path, both swept against the naive O(S^2) oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.swa_attention.kernel import flash_attention_pallas
from repro.kernels.swa_attention.ref import attention_ref
from repro.models import common as cm


def _qkv(B, S, Hq, Hkv, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    return q, k, v


CASES = [
    # B, S, Hq, Hkv, D, window
    (1, 64, 2, 2, 32, 0),
    (2, 128, 4, 2, 64, 0),
    (2, 128, 4, 1, 64, 32),      # MQA + SWA
    (1, 256, 6, 3, 32, 96),      # window not multiple of block
    (2, 64, 8, 8, 16, 16),
]


@pytest.mark.parametrize("B,S,Hq,Hkv,D,window", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_flash_vs_oracle(B, S, Hq, Hkv, D, window, dtype):
    q, k, v = _qkv(B, S, Hq, Hkv, D, dtype)
    want = attention_ref(q, k, v, causal=True, window=window)
    got = flash_attention_pallas(q, k, v, causal=True, window=window,
                                 block_q=32, block_kv=32, interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)


@pytest.mark.parametrize("B,S,Hq,Hkv,D,window", CASES)
def test_blocked_jax_vs_oracle(B, S, Hq, Hkv, D, window):
    q, k, v = _qkv(B, S, Hq, Hkv, D, jnp.float32, seed=1)
    want = attention_ref(q, k, v, causal=True, window=window)
    got = cm.flash_attention(q, k, v, causal=True, window=window,
                             block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_non_causal_matches():
    q, k, v = _qkv(2, 64, 4, 4, 32, jnp.float32, seed=2)
    want = attention_ref(q, k, v, causal=False)
    got = cm.flash_attention(q, k, v, causal=False, block_q=32, block_kv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)
    gp = flash_attention_pallas(q, k, v, causal=False, block_q=32,
                                block_kv=32, interpret=True)
    np.testing.assert_allclose(np.asarray(gp), np.asarray(want), atol=2e-5)


DECODE_CASES = [
    # B, C, Hq, Hkv, D, valid
    (2, 128, 4, 2, 64, "full"),
    (3, 256, 8, 1, 32, "ragged"),
    (1, 64, 2, 2, 128, "one"),
]


@pytest.mark.parametrize("B,C,Hq,Hkv,D,valid", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_decode_vs_oracle(B, C, Hq, Hkv, D, valid, dtype):
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, Hq, D), dtype)
    kc = jax.random.normal(ks[1], (B, C, Hkv, D), dtype)
    vc = jax.random.normal(ks[2], (B, C, Hkv, D), dtype)
    if valid == "full":
        vl = jnp.asarray(C)
    elif valid == "one":
        vl = jnp.asarray(1)
    else:
        vl = jnp.arange(B) * (C // 2) + 1
    want = decode_attention_ref(q, kc, vc, vl)
    got = decode_attention_pallas(q, kc, vc, vl, block_c=32, interpret=True)
    ours = cm.decode_attention(q, kc, vc, vl)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol)
    np.testing.assert_allclose(np.asarray(ours, np.float32),
                               np.asarray(want, np.float32), atol=tol)


def test_decode_consistent_with_prefill_attention():
    """Decoding position S-1 must equal row S-1 of full causal attention."""
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 32
    q, k, v = _qkv(B, S, Hq, Hkv, D, jnp.float32, seed=9)
    full = attention_ref(q, k, v, causal=True)
    dec = cm.decode_attention(q[:, -1], k, v, S)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               atol=1e-5)
