"""Columnar engine equivalence: the vectorized sampler / estimator /
strategies must reproduce the scalar reference path seed-for-seed (to
float tolerance), plus golden summary numbers for one sync and one async
spec so engine drift is caught across PRs."""
import numpy as np
import pytest

from repro.configs import FederatedConfig, RunConfig, get_config
from repro.core.estimator import CarbonEstimator
from repro.core.telemetry import (OUTCOMES, ClientSession, SessionBatch,
                                  TaskLog)
from repro.federated.events import SessionSampler
from repro.federated.reference import run_scalar
from repro.federated.runtime import get_strategy
from repro.federated.surrogate import SurrogateLearner

CFG = get_config("paper-charlm")
RUN = RunConfig(target_perplexity=175.0)


def _sampler(**fed_kw):
    fed_kw.setdefault("aggregation_goal",
                      max(1, int(fed_kw.get("concurrency", 100) * 0.8)))
    fed = FederatedConfig(**fed_kw)
    return SessionSampler(CFG, fed, 64), fed


def _ids(n=512, seed=7):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 5_000_000, size=n).astype(np.int64)


# ------------------------------------------------------- sampler equivalence
@pytest.mark.parametrize("fed_kw", [dict(), dict(compression="int8"),
                                    dict(local_epochs=5, seed=3)])
def test_plan_batch_matches_scalar(fed_kw):
    s, _ = _sampler(**fed_kw)
    ids = _ids()
    pb = s.plan_batch(ids, round_idx=4)
    for i in (0, 1, 17, 100, 511):
        ref = s.plan_scalar(int(ids[i]), 4)
        assert s.fleet[pb.device_idx[i]] is ref.device
        assert s.country_names[pb.country_idx[i]] == ref.country
        assert int(pb.n_examples[i]) == ref.n_examples
        np.testing.assert_allclose(
            [pb.download_s[i], pb.compute_s[i], pb.upload_s[i]],
            [ref.download_s, ref.compute_s, ref.upload_s], rtol=1e-12)


@pytest.mark.parametrize("deadline", [None, 2000.0])
def test_resolve_batch_matches_scalar(deadline):
    s, _ = _sampler(dropout_rate=0.2)     # force plenty of drop branches
    ids = _ids()
    pb = s.plan_batch(ids, 4)
    batch, ok = s.resolve_batch(pb, 4, 100.0, deadline=deadline)
    outcomes = set()
    for i in range(len(ids)):
        kw, ok_ref = s.resolve_scalar(s.plan_scalar(int(ids[i]), 4), 4,
                                      100.0, deadline=deadline)
        assert OUTCOMES[batch.outcome[i]] == kw["outcome"]
        assert bool(ok[i]) == ok_ref
        outcomes.add(kw["outcome"])
        np.testing.assert_allclose(
            [batch.download_s[i], batch.compute_s[i], batch.upload_s[i],
             batch.bytes_down[i], batch.bytes_up[i], batch.end_t[i]],
            [kw["download_s"], kw["compute_s"], kw["upload_s"],
             kw["bytes_down"], kw["bytes_up"], kw["end_t"]],
            rtol=1e-9, atol=1e-9)
    assert "completed" in outcomes and "dropped" in outcomes


def test_scalar_plan_resolve_wrappers_are_batch_of_one():
    s, _ = _sampler()
    for cid in (0, 99, 4_999_999):
        p_b, p_s = s.plan(cid, 2), s.plan_scalar(cid, 2)
        assert (p_b.device, p_b.country, p_b.n_examples) == \
            (p_s.device, p_s.country, p_s.n_examples)
        for f in ("download_s", "compute_s", "upload_s"):
            assert getattr(p_b, f) == pytest.approx(getattr(p_s, f),
                                                    rel=1e-12)
        kw_b, ok_b = s.resolve(s.plan(cid, 2), 2, 10.0)
        kw_s, ok_s = s.resolve_scalar(s.plan_scalar(cid, 2), 2, 10.0)
        assert ok_b == ok_s
        assert kw_b.keys() == kw_s.keys()
        for k in kw_s:
            if isinstance(kw_s[k], float):
                assert kw_b[k] == pytest.approx(kw_s[k], rel=1e-9)
            else:
                assert kw_b[k] == kw_s[k]


def test_dropped_mid_download_prorates_bytes_down():
    """Satellite fix: a client cut mid-download is charged only the
    downloaded fraction, not the full payload."""
    s, _ = _sampler(dropout_rate=0.0)
    pb = s.plan_batch(_ids(64), 0)
    # a deadline inside the first download cuts everyone mid-download
    cut = float(pb.download_s.min()) * 0.5
    batch, ok = s.resolve_batch(pb, 0, 0.0, deadline=cut)
    assert not ok.any()
    # sessions that hit the compute timeout finished their download first
    # and are rightly charged in full; the deadline-dropped ones prorate
    dropped = batch.outcome == OUTCOMES.index("dropped")
    assert dropped.any()
    frac = batch.download_s / pb.download_s
    np.testing.assert_allclose(batch.bytes_down, pb.bytes_down * frac,
                               rtol=1e-12)
    assert (batch.bytes_down[dropped] < pb.bytes_down[dropped]).all()
    assert (batch.bytes_up == 0).all()


# ----------------------------------------------------- estimator equivalence
def test_vectorized_estimator_matches_scalar_loop():
    s, fed = _sampler(dropout_rate=0.15)
    log = TaskLog()
    for r in range(3):
        batch, _ = s.resolve_batch(s.plan_batch(_ids(256, seed=r), r), r,
                                   100.0 * r, deadline=100.0 * r + 5000.0)
        log.log_batch(batch)
    log.duration_s = 5000.0
    est = CarbonEstimator()
    vec, ref = est.estimate(log), est.estimate_scalar(log)
    for k, v in vec.as_dict().items():
        assert v == pytest.approx(ref.as_dict()[k], rel=1e-9)
    assert vec.total_kg > 0


def test_estimator_handles_row_oriented_and_empty_logs():
    est = CarbonEstimator()
    log = TaskLog()
    assert est.estimate(log).total_kg == 0.0
    log.log_session(ClientSession(
        client_id=1, round_idx=0, device="pixel-3", country="US",
        download_s=10.0, compute_s=60.0, upload_s=30.0, bytes_down=64e6,
        bytes_up=64e6, start_t=0.0, end_t=100.0, outcome="completed"))
    log.duration_s = 3600.0
    vec, ref = est.estimate(log), est.estimate_scalar(log)
    assert vec.total_kg == pytest.approx(ref.total_kg, rel=1e-9)


# ------------------------------------------------------- slot-stream ids
def test_slot_stream_ids_scalar_batch_agree_and_are_decoupled():
    """The async engine's replacement identity: slot s's g-th replacement
    id is a pure function of (seed, s, g) — scalar and batch agree, and
    neighbouring slots/generations give distinct streams."""
    from repro.federated.events import slot_stream_id, slot_stream_ids
    slots = np.repeat(np.arange(8), 16)
    gens = np.tile(np.arange(1, 17), 8)
    ids = slot_stream_ids(3, slots, gens, 5_000_000)
    assert ids.min() >= 0 and ids.max() < 5_000_000
    for i in (0, 7, 63, 127):
        assert slot_stream_id(3, int(slots[i]), int(gens[i]),
                              5_000_000) == ids[i]
    # one stream per (slot, generation): no systematic collisions
    assert len(set(ids.tolist())) == len(ids)
    # a different seed is a different stream
    assert (slot_stream_ids(4, slots, gens, 5_000_000) != ids).any()


# --------------------------------------------------- cancelled in-flight
def test_async_flushes_in_flight_sessions_as_cancelled():
    """Satellite fix: when the task ends (budget/target), the in-flight
    cohort is truncated at the final clock and logged as cancelled instead
    of being silently discarded (energy under-counting)."""
    fed = FederatedConfig(mode="async", concurrency=64, aggregation_goal=48)
    run = RunConfig(target_perplexity=175.0, max_rounds=25)
    res = get_strategy("async").run(CFG, fed, run,
                                    SurrogateLearner(CFG, fed, run))
    parts = res.log.participation()
    assert parts.get("cancelled", 0) > 0
    b = res.log.columns()
    cancelled = b.outcome == OUTCOMES.index("cancelled")
    t_final = res.duration_h * 3600.0
    # truncated at the final task clock, uplink never charged
    assert (b.end_t[cancelled] <= t_final + 1e-9).all()
    assert (b.bytes_up[cancelled] == 0).all()
    burned = (b.download_s[cancelled] + b.compute_s[cancelled]
              + b.upload_s[cancelled])
    assert (b.start_t[cancelled] + burned <= t_final + 1e-9).all()
    # the flushed sessions carry real energy (not all zero-duration)
    assert burned.sum() > 0
    # and the reference oracle flushes identically (equivalence)
    ref = run_scalar(CFG, fed, run, SurrogateLearner(CFG, fed, run))
    assert ref.log.participation() == parts
    assert ref.carbon.total_kg == pytest.approx(res.carbon.total_kg,
                                                rel=1e-9)


# ------------------------------------------------------ strategy equivalence
@pytest.mark.parametrize("mode,conc", [("sync", 120), ("async", 120),
                                       ("async", 37)])
def test_strategy_matches_scalar_reference_engine(mode, conc):
    fed = FederatedConfig(mode=mode, concurrency=conc,
                          aggregation_goal=int(conc * 0.8))
    vec = get_strategy(mode).run(CFG, fed, RUN,
                                 SurrogateLearner(CFG, fed, RUN))
    ref = run_scalar(CFG, fed, RUN, SurrogateLearner(CFG, fed, RUN))
    assert vec.rounds == ref.rounds
    assert vec.log.n_sessions == ref.log.n_sessions
    assert vec.log.participation() == ref.log.participation()
    assert vec.carbon.total_kg == pytest.approx(ref.carbon.total_kg,
                                                rel=1e-9)
    for k, v in vec.carbon.as_dict().items():
        assert v == pytest.approx(ref.carbon.as_dict()[k], rel=1e-9)
    assert vec.duration_h == pytest.approx(ref.duration_h, rel=1e-9)
    assert vec.log.mean_staleness() == pytest.approx(
        ref.log.mean_staleness(), rel=1e-9)


# ------------------------------------------------------------ golden numbers
def test_golden_sync_summary():
    fed = FederatedConfig(mode="sync", concurrency=100, aggregation_goal=80)
    res = get_strategy("sync").run(CFG, fed, RUN,
                                   SurrogateLearner(CFG, fed, RUN))
    s = res.summary()
    assert s["rounds"] == 503
    assert s["sessions"] == 50300.0
    assert s["carbon_total_kg"] == pytest.approx(4.232699224439, rel=1e-6)
    assert s["duration_h"] == pytest.approx(37.612267073554, rel=1e-6)


def test_golden_async_summary():
    # Regenerated once for PR 3 (window-batched async merge): replacement
    # client ids moved from the shared rng stream to per-slot splitmix64
    # streams (identity decoupled from pop rank), and sessions still in
    # flight at task end are now logged as "cancelled" instead of being
    # discarded — so rounds/duration shift slightly and `sessions` grows
    # by the flushed in-flight cohort. Previous goldens: rounds=599,
    # sessions=56733, carbon=4.149319672258 kg, duration=23.728930396052 h.
    fed = FederatedConfig(mode="async", concurrency=100, aggregation_goal=80)
    res = get_strategy("async").run(CFG, fed, RUN,
                                    SurrogateLearner(CFG, fed, RUN))
    s = res.summary()
    assert s["rounds"] == 599
    assert s["sessions"] == 56718.0
    assert s["carbon_total_kg"] == pytest.approx(4.158560108788, rel=1e-6)
    assert s["duration_h"] == pytest.approx(23.651763113075, rel=1e-6)
    assert res.log.participation()["cancelled"] == 99


# ----------------------------------------------------------- columnar store
def test_sessionbatch_roundtrip_and_concat():
    s, _ = _sampler()
    b1, _ = s.resolve_batch(s.plan_batch(_ids(32, seed=1), 0), 0, 0.0)
    b2, _ = s.resolve_batch(s.plan_batch(_ids(32, seed=2), 1), 1, 50.0)
    cat = SessionBatch.concat([b1, b2])
    assert len(cat) == 64
    rebuilt = SessionBatch.from_sessions(cat.to_sessions())
    assert rebuilt.to_sessions() == cat.to_sessions()
    log = TaskLog()
    log.log_batch(b1)
    log.log_session(b2.to_sessions()[0])     # mixed columnar + row appends
    assert log.n_sessions == 33
    assert len(log.sessions) == 33
    assert log.sessions[32] == b2.to_sessions()[0]
    assert sum(log.participation().values()) == 33
