"""GreenAdvisor (paper C4): recommendations obey the paper's recipe."""
import pytest

from repro.configs import FederatedConfig, RunConfig, get_config
from repro.core.advisor import GreenAdvisor, Recommendation


@pytest.fixture(scope="module")
def advisor():
    return GreenAdvisor(get_config("paper-charlm"),
                        RunConfig(target_perplexity=175.0))


GRID = dict(mode=("sync",), concurrency=(50, 200),
            local_epochs=(1, 10), compression=("none", "int8"))


def test_recommendation_reaches_target(advisor):
    best = advisor.recommend(grid=GRID)
    assert best.reached_target
    assert best.carbon_kg > 0


def test_recipe_low_concurrency_and_epochs(advisor):
    recs = advisor.search(grid=GRID)
    best = recs[0]
    assert best.fed.concurrency <= 200          # paper: keep it small
    assert best.fed.local_epochs <= 3           # paper §5.2: E in 1..3
    assert best.carbon_kg <= recs[-1].carbon_kg


def test_deadline_constraint(advisor):
    recs = advisor.search(grid=GRID)
    uncon = recs[0]
    limit = uncon.duration_h * 0.6
    con = advisor.search(grid=GRID, max_hours=limit)
    feasible = [r for r in recs if r.reached_target and r.duration_h <= limit]
    if feasible:
        assert con[0].duration_h <= limit + 1e-6
        assert con[0].carbon_kg >= uncon.carbon_kg - 1e-9
    else:
        # fallback list is carbon-sorted over everything
        assert con[0].carbon_kg <= recs[-1].carbon_kg


def test_pareto_front_monotone(advisor):
    recs = advisor.search(grid=GRID)
    front = GreenAdvisor.pareto(recs)
    assert len(front) >= 1
    for a, b in zip(front, front[1:]):
        assert a.duration_h <= b.duration_h or a.carbon_kg >= b.carbon_kg
    assert "concurrency" in front[0].why()


def test_compression_helps(advisor):
    recs = advisor.search(grid=GRID)
    assert recs[0].fed.compression == "int8"    # int8 strictly greener here


def test_vmapped_cohort_equals_sequential():
    """RealLearner.client_deltas (vmap) == per-client client_delta."""
    import dataclasses
    import numpy as np
    from repro.data import FederatedDataset
    from repro.federated import RealLearner
    from repro.configs import get_config, reduced
    cfg = dataclasses.replace(
        reduced(get_config("paper-charlm"), layers=1, d_model=32, d_ff=32,
                vocab=128), lstm_hidden=32, max_context=8)
    ds = FederatedDataset(vocab_size=cfg.vocab_size, seq_len=8,
                          char_vocab=cfg.char_vocab,
                          max_word_len=cfg.max_word_len)
    fed = FederatedConfig(mode="sync", concurrency=3, aggregation_goal=2,
                          client_lr=0.1, client_batch_size=4)
    lr = RealLearner(cfg, fed, RunConfig(max_rounds=1), ds,
                     max_client_steps=2)
    ids = [5, 9]
    batch_d, batch_w = lr.client_deltas(ids)
    for i, cid in enumerate(ids):
        d, w = lr.client_delta(cid)
        assert w == batch_w[i]
        for k in d:
            np.testing.assert_allclose(batch_d[i][k], d[k], atol=2e-5)
