"""End-to-end behaviour: real federated training improves perplexity and the
full telemetry -> carbon -> predictor pipeline closes the loop (the paper's
workflow in miniature)."""
import dataclasses

import numpy as np
import pytest

from repro.configs import FederatedConfig, RunConfig, get_config, reduced
from repro.core.predictor import CarbonPredictor
from repro.data import FederatedDataset
from repro.federated import RealLearner, SurrogateLearner, run_task


def _tiny_charlm():
    cfg0 = get_config("paper-charlm")
    return dataclasses.replace(
        reduced(cfg0, layers=1, d_model=64, d_ff=64, vocab=256),
        lstm_hidden=64, max_context=16)


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = _tiny_charlm()
    ds = FederatedDataset(vocab_size=cfg.vocab_size, seq_len=16,
                          char_vocab=cfg.char_vocab,
                          max_word_len=cfg.max_word_len)
    return cfg, ds


def test_e2e_sync_training_reduces_perplexity(tiny_setup):
    cfg, ds = tiny_setup
    fed = FederatedConfig(mode="sync", concurrency=6, aggregation_goal=4,
                          client_lr=0.3, server_lr=0.02, client_batch_size=8)
    run = RunConfig(target_perplexity=5.0, max_rounds=10, max_hours=1e6)
    learner = RealLearner(cfg, fed, run, ds)
    ppl0 = learner.eval_perplexity()
    res = run_task(cfg, fed, run, learner, seq_len=16)
    assert res.final_perplexity < 0.7 * ppl0
    assert res.carbon.total_kg > 0
    assert res.log.completed_sessions() >= 10


def test_e2e_async_with_true_staleness(tiny_setup):
    cfg, ds = tiny_setup
    fed = FederatedConfig(mode="async", concurrency=6, aggregation_goal=3,
                          client_lr=0.3, server_lr=0.02, staleness_cap=8)
    run = RunConfig(target_perplexity=5.0, max_rounds=8, max_hours=1e6)
    learner = RealLearner(cfg, fed, run, ds)
    ppl0 = learner.eval_perplexity()
    res = run_task(cfg, fed, run, learner, seq_len=16)
    assert res.final_perplexity < 0.8 * ppl0
    assert res.rounds == 8


def test_paper_workflow_predict_then_measure():
    """§5.3: fit the predictor on a few cheap (surrogate) runs, then check it
    forecasts a held-out configuration within 2x."""
    cfg = get_config("paper-charlm")
    run = RunConfig(target_perplexity=175.0)
    xs, kgs = [], []
    for conc in (50, 100, 200, 400):
        fed = FederatedConfig(mode="sync", concurrency=conc,
                              aggregation_goal=int(conc * 0.8))
        r = run_task(cfg, fed, run, SurrogateLearner(cfg, fed, run))
        xs.append((conc, r.rounds))
        kgs.append(r.carbon.total_kg)
    pred = CarbonPredictor.from_measurements(
        "sync", [x[0] for x in xs], [x[1] for x in xs], kgs)
    fed = FederatedConfig(mode="sync", concurrency=300,
                          aggregation_goal=240)
    r = run_task(cfg, fed, run, SurrogateLearner(cfg, fed, run))
    forecast = pred.predict_kg(300, r.rounds)
    assert 0.5 < forecast / r.carbon.total_kg < 2.0
