"""Streaming telemetry subsystem (PR 6): constant-memory folds must
reproduce the materialized path — summaries **bit for bit** (exact
summation on every schedule, static and diurnal), the reservoir sample a
pure function of (seed, global session index) invariant to chunking,
lane packing and worker count — plus the ExactSum machinery itself."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.federated.runtime as rt
from repro.api import Environment, Experiment, ExperimentSpec, ModelRef, sweep
from repro.configs import FederatedConfig, RunConfig
from repro.core.estimator import CarbonEstimator, ExactSum, exact_sum
from repro.core.network import NetworkEnergyModel
from repro.core.profiles import FLEET
from repro.core.streaming import StreamedLog, StreamingAccumulator
from repro.core.telemetry import OUTCOMES, SessionBatch, TaskLog
from repro.federated.events import reservoir_keys

_ENVS = (Environment(),
         Environment(download_bps=20e6, upload_bps=5e6,
                     network=NetworkEnergyModel(e_access_nj=80.0),
                     fleet=FLEET[:3], pue=1.3,
                     carbon_intensity={"WORLD": 300.0, "US": 100.0}),
         Environment.preset("diurnal"))

_MODES = ("sync", "async", "carbon-aware")


def _spec(mode: str, conc: int, goal_frac: float, seed: int,
          max_rounds: int, env_idx: int = 0, telemetry: str = "full",
          sample: int = 100, dropout: float = 0.05) -> ExperimentSpec:
    return ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(
            mode=mode, concurrency=conc,
            aggregation_goal=max(1, int(conc * goal_frac)),
            seed=seed, dropout_rate=dropout),
        run=RunConfig(target_perplexity=175.0, max_rounds=max_rounds,
                      telemetry=telemetry, telemetry_sample=sample),
        environment=_ENVS[env_idx % len(_ENVS)], learner="surrogate")


# ------------------------------------------------------------------ ExactSum
def test_exact_sum_matches_fsum():
    rng = np.random.default_rng(0)
    for scale in (1.0, 1e-12, 1e150):
        x = rng.standard_normal(5000) * scale
        x[::7] *= 1e9           # mixed magnitudes force cancellation error
        assert exact_sum(x) == math.fsum(x.tolist())


def test_exact_sum_chunking_and_merge_are_bit_exact():
    rng = np.random.default_rng(1)
    x = rng.standard_normal(10_000) * np.exp(rng.uniform(-40, 40, 10_000))
    whole = exact_sum(x)
    for nchunks in (2, 3, 7, 100):
        acc = ExactSum()
        for part in np.array_split(x, nchunks):
            acc.add(part)
        assert acc.value() == whole
    # merge of independent accumulators, any order
    a, b = ExactSum().add(x[:777]), ExactSum().add(x[777:])
    assert b.merge(a).value() == whole
    # permutation invariance (true exactness, not pairwise-tree luck)
    assert exact_sum(x[rng.permutation(len(x))]) == whole


def test_exact_sum_edges():
    assert exact_sum(np.zeros(5)) == 0.0
    assert ExactSum().value() == 0.0
    assert exact_sum(np.asarray([1e308, 1e308, -1e308])) == 1e308
    assert exact_sum(np.asarray([1.0, 2.0 ** -60, -1.0])) == 2.0 ** -60
    with pytest.raises(ValueError):
        exact_sum(np.asarray([1.0, np.nan]))


# -------------------------------------------------------- streaming parity
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_streaming_matches_full_property(seed0):
    """Random specs x all three modes x static/diurnal envs: the
    streaming summary equals the materialized one bit for bit, on the
    serial AND the lane-batched path (exact summation makes even the
    diurnal schedules exact, beating the <=1e-9 contract)."""
    rng = np.random.default_rng(seed0)
    specs_f, specs_s = [], []
    for mode in _MODES:
        kw = dict(mode=mode, conc=int(rng.integers(8, 48)),
                  goal_frac=float(rng.uniform(0.3, 1.0)),
                  seed=int(rng.integers(0, 2 ** 31)),
                  max_rounds=int(rng.integers(5, 30)),
                  env_idx=int(rng.integers(len(_ENVS))),
                  dropout=float(rng.choice([0.0, 0.05, 0.3])))
        specs_f.append(_spec(telemetry="full", **kw))
        specs_s.append(_spec(telemetry="streaming", **kw))
    full = [Experiment(s).run() for s in specs_f]
    stream = [Experiment(s).run() for s in specs_s]
    lanes = sweep(specs_s, workers=1, vectorize=True)
    for sf, ss, sl in zip(full, stream, lanes):
        a, b, c = sf.summary(), ss.summary(), sl.summary()
        assert a == b, {k: (a[k], b[k]) for k in a if a[k] != b[k]}
        assert a == c, {k: (a[k], c[k]) for k in a if a[k] != c[k]}
        assert isinstance(ss.log, StreamedLog)
        assert sf.log.participation() == ss.log.participation()
        assert sf.log.mean_staleness() == ss.log.mean_staleness()
        assert sf.log.completed_sessions() == ss.log.completed_sessions()
        tb_f, tb_s = sf.log.total_bytes(), ss.log.total_bytes()
        for k in tb_f:       # exact vs pairwise sums: ulp-level agreement
            assert tb_s[k] == pytest.approx(tb_f[k], rel=1e-12)


# --------------------------------------------------- reservoir determinism
@settings(max_examples=4, deadline=None)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=7, max_value=200))
def test_reservoir_invariant_to_chunking_and_lanes(monkeypatch, seed0,
                                                   chunk):
    """The retained session set is a pure function of (seed, global
    index): identical across dispatch chunk sizes and serial vs
    lane_loop, for every mode — and it IS the bottom-k of
    events.reservoir_keys."""
    rng = np.random.default_rng(seed0)
    for mode in _MODES:
        kw = dict(mode=mode, conc=int(rng.integers(8, 40)),
                  goal_frac=float(rng.uniform(0.4, 1.0)),
                  seed=int(rng.integers(0, 2 ** 31)),
                  max_rounds=int(rng.integers(4, 20)),
                  env_idx=int(rng.integers(len(_ENVS))),
                  telemetry="streaming", sample=int(rng.integers(5, 60)))
        spec = _spec(**kw)
        serial = Experiment(spec).run()
        monkeypatch.setattr(rt, "_DISPATCH_CHUNK", chunk)
        chunked = Experiment(spec).run()
        monkeypatch.setattr(rt, "_DISPATCH_CHUNK", 1 << 17)
        lane = sweep([spec, _spec(mode=mode, conc=9, goal_frac=1.0,
                                  seed=3, max_rounds=5,
                                  telemetry="streaming")],
                     workers=1, vectorize=True)[0]
        idx_serial = serial.log._acc.sample_indices()
        assert np.array_equal(idx_serial, chunked.log._acc.sample_indices())
        assert np.array_equal(idx_serial, lane.log._acc.sample_indices())
        # derived bottom-k check against the key stream itself
        n = serial.log.n_sessions
        keys = reservoir_keys(spec.federated.seed, np.arange(n))
        k = min(n, spec.run.telemetry_sample)
        expect = np.sort(np.lexsort((np.arange(n), keys))[:k])
        assert np.array_equal(idx_serial, expect)
        # the sampled columns agree row-for-row across paths
        a, b = serial.log.columns(), lane.log.columns()
        for f in ("client_id", "start_t", "end_t", "outcome", "staleness"):
            assert np.array_equal(getattr(a, f), getattr(b, f)), (mode, f)


def test_reservoir_invariant_to_workers():
    specs = [_spec("async", 30, 0.8, s, 12, telemetry="streaming",
                   sample=40) for s in (0, 9)]
    r1 = sweep(specs, workers=1, vectorize=True)
    r2 = sweep(specs, workers=2, vectorize=True)
    for a, b in zip(r1, r2):
        assert a.summary() == b.summary()
        assert np.array_equal(a.log._acc.sample_indices(),
                              b.log._acc.sample_indices())
        assert np.array_equal(a.log.columns().client_id,
                              b.log.columns().client_id)


def test_reservoir_covers_population_when_large_enough():
    """sample >= n_sessions: columns() is the whole population, equal
    session-for-session to the materialized log (decoded — the two vocab
    orderings may differ)."""
    kw = dict(mode="async", conc=20, goal_frac=0.8, seed=4, max_rounds=8,
              env_idx=2)
    full = Experiment(_spec(telemetry="full", **kw)).run()
    stream = Experiment(_spec(telemetry="streaming", sample=10 ** 6,
                              **kw)).run()
    assert not stream.log.sampled
    assert full.log.columns().to_sessions() == \
        stream.log.columns().to_sessions()
    assert stream.log.sessions == full.log.sessions


# ------------------------------------------------------- log surface edges
def test_empty_streamed_log():
    est = CarbonEstimator()
    log = StreamedLog(est, ("pixel-7",), ("US",), seed=0, sample=8)
    assert log.n_sessions == 0 and len(log) == 0
    assert not log.sampled
    assert log.participation() == {}
    assert log.mean_staleness() == 0.0
    assert log.total_bytes() == {"up": 0.0, "down": 0.0}
    assert len(log.columns()) == 0
    bd = est.estimate(log)
    assert bd.total_kg == 0.0
    log.duration_s = 3600.0
    assert est.estimate(log).server_kg > 0.0


def test_streamed_log_rejects_foreign_estimator():
    env = Environment.preset("diurnal")
    log = Experiment(_spec("async", 16, 1.0, 0, 5, env_idx=2,
                           telemetry="streaming")).run().log
    other = Environment(pue=2.0).estimator()
    with pytest.raises(ValueError):
        other.estimate(log)
    # an equal estimator re-reads the sums fine
    assert env.estimator().estimate(log).total_kg > 0.0


def test_streamed_log_log_session_and_unknown_vocab():
    est = CarbonEstimator()
    log = StreamedLog(est, ("pixel-7",), ("US",), seed=0, sample=8)
    from repro.core.telemetry import ClientSession
    s = ClientSession(client_id=1, round_idx=0, device="pixel-7",
                      country="US", download_s=1.0, compute_s=2.0,
                      upload_s=1.0, bytes_down=10.0, bytes_up=5.0,
                      start_t=0.0, end_t=4.0, outcome="completed")
    log.log_session(s)
    assert log.n_sessions == 1
    assert log.columns().to_sessions() == [s]
    bad = ClientSession(client_id=2, round_idx=0, device="galaxy-s21",
                        country="US", download_s=1.0, compute_s=1.0,
                        upload_s=1.0, bytes_down=1.0, bytes_up=1.0,
                        start_t=0.0, end_t=3.0, outcome="completed")
    with pytest.raises(ValueError):
        log.log_session(bad)


def test_breakdown_table_consistent_with_exact_totals():
    """The grouped (country, segment, outcome) table is float64 running
    sums (documented as not bit-pinned); its totals still agree with the
    exact component sums to ~1e-9 and its counts/bytes exactly."""
    res = Experiment(_spec("carbon-aware", 40, 0.8, 2, 15, env_idx=2,
                           telemetry="streaming")).run()
    log = res.log
    rows = log.breakdown_table()
    assert rows and all(r["country"] and r["outcome"] in OUTCOMES
                        for r in rows)
    comp = log.carbon_components(log._acc.estimator)
    total = (comp["client_compute_kg"] + comp["upload_kg"]
             + comp["download_kg"])
    assert sum(r["co2e_kg"] for r in rows) == pytest.approx(total, rel=1e-9)
    # the contributed/wasted split partitions the same rows
    assert comp["ok_kg"] + comp["waste_kg"] == pytest.approx(total, rel=1e-9)
    assert sum(r["count"] for r in rows) == log.n_sessions
    tb = log.total_bytes()
    assert sum(r["bytes"] for r in rows) == pytest.approx(
        tb["up"] + tb["down"], rel=1e-9)
    # diurnal env: sessions actually land in distinct schedule segments
    assert len({r["segment"] for r in rows}) > 1


def test_run_config_validates_telemetry():
    with pytest.raises(AssertionError):
        RunConfig(telemetry="columnar")
    with pytest.raises(AssertionError):
        RunConfig(telemetry_sample=0)


def test_streaming_spec_roundtrip_reproduces_summary(tmp_path):
    spec = _spec("async", 24, 0.8, 1, 10, telemetry="streaming", sample=32)
    p = tmp_path / "s.json"
    spec.save(str(p))
    spec2 = ExperimentSpec.load(str(p))
    assert spec2.run.telemetry == "streaming"
    assert Experiment(spec).run().summary() == \
        Experiment(spec2).run().summary()
