"""Per-architecture smoke tests (REDUCED variants, CPU) + decode/forward
consistency. Full configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, reduced
from repro.models import get_model, param_count, step_flops

RNG = jax.random.PRNGKey(0)


def _reduced(name):
    cfg0 = get_config(name)
    layers = 3 if cfg0.family == "hybrid" else 2
    return reduced(cfg0, layers=layers)


def _batch(cfg, B=2, S=16):
    b = {"tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size)}
    if cfg.family in ("vlm", "audio"):
        b["frontend"] = jax.random.normal(
            RNG, (B, cfg.num_frontend_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "charlm":
        b["chars"] = jax.random.randint(RNG, (B, S, cfg.max_word_len), 0,
                                        cfg.char_vocab)
    return b


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_and_train_step(name):
    """One forward + one SGD step on the reduced config: shapes + finiteness."""
    cfg = _reduced(name)
    model = get_model(cfg)
    params, axes = model.init(RNG)
    assert set(axes) == set(params)
    for k, v in params.items():
        assert len(axes[k]) == v.ndim, k
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    new = {k: params[k] - 0.01 * grads[k] for k in params}
    loss2, _ = jax.jit(model.loss)(new, batch)
    assert np.isfinite(float(loss2))
    assert new["embed" if "embed" in new else list(new)[0]].shape == \
        params["embed" if "embed" in params else list(params)[0]].shape


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_decode_path(name):
    cfg = _reduced(name)
    model = get_model(cfg)
    params, _ = model.init(RNG)
    batch = _batch(cfg, B=2, S=12)
    if cfg.family == "charlm":
        lg, cache = model.prefill(params, batch["tokens"], chars=batch["chars"])
        step_in = batch["chars"][:, -1]
    elif cfg.family in ("vlm", "audio"):
        lg, cache = model.prefill(params, batch["tokens"], batch["frontend"])
        step_in = batch["tokens"][:, -1]
    else:
        lg, cache = model.prefill(params, batch["tokens"])
        step_in = batch["tokens"][:, -1]
    assert lg.shape == (2, cfg.vocab_size)
    lg2, cache2 = jax.jit(model.decode_step)(params, cache, step_in)
    assert lg2.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg2, np.float32)).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("name", ["smollm-135m", "rwkv6-7b",
                                  "recurrentgemma-2b", "mixtral-8x22b"])
def test_decode_matches_full_forward(name):
    """Prefill(t[:-1]) + decode(t[-1]) logits == full-forward last logits."""
    cfg = _reduced(name)
    model = get_model(cfg)
    if getattr(model, "is_moe", False):
        # dropless routing on both paths so the equivalence is exact
        model.capacity_factor = float(cfg.moe.num_experts)
    params, _ = model.init(RNG)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 12), 0,
                              cfg.vocab_size)
    # full forward logits at the last position
    if cfg.family in ("dense", "moe", "vlm"):
        x = model._embed(params, toks)
        x, _, _ = model._stack(params, x)
        full = model.logits(params, x[:, -1:, :])[:, 0]
    elif cfg.family == "ssm":
        x = params["embed"][toks]
        states, _ = model._zero_states(2, x.dtype)
        x, _ = model._stack(params, x, states)
        import repro.models.common as cm
        x = cm.rms_norm(x[:, -1:], params["final_norm"])
        full = jnp.einsum("bsd,dv->bsv", x, params["unembed"])[:, 0]
    else:  # hybrid
        x = params["embed"][toks]
        states, _ = model._zero_rec_states(2, x.dtype)
        x, _, _ = model._stack(params, x, states)
        import repro.models.common as cm
        x = cm.rms_norm(x[:, -1:], params["final_norm"])
        full = jnp.einsum("bsd,dv->bsv", x, model._unembed(params))[:, 0]

    _, cache = model.prefill(params, toks[:, :-1], pad_to=16)
    dec, _ = model.decode_step(params, cache, toks[:, -1])
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_param_counts_in_expected_band():
    expect = {
        "mistral-nemo-12b": (11e9, 13.5e9),
        "mixtral-8x22b": (135e9, 145e9),
        "smollm-135m": (0.12e9, 0.17e9),
        "rwkv6-7b": (7e9, 8.2e9),
        "granite-moe-1b-a400m": (1.2e9, 1.5e9),
        "recurrentgemma-2b": (2.4e9, 2.9e9),
    }
    for name, (lo, hi) in expect.items():
        n = param_count(get_config(name))
        assert lo <= n <= hi, (name, n)
    # MoE active params
    mix = get_config("mixtral-8x22b")
    assert param_count(mix, active_only=True) < 0.35 * param_count(mix)


def test_step_flops_sane():
    cfg = get_config("smollm-135m")
    f_train = step_flops(cfg, 256, 4096, "train")
    f_prefill = step_flops(cfg, 256, 4096, "prefill")
    assert f_train > 2.5 * f_prefill
    f_dec = step_flops(cfg, 128, 32768, "decode")
    assert f_dec < f_prefill
