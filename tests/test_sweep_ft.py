"""Fault-tolerant sweep (PR 9): worker death, wedged workers and raised
failures must cost retries, not the whole sweep. Crash injection
(``repro.core.snapshot._CrashInjector``, armed through REPRO_CRASH_* env
vars that the worker processes inherit) kills real workers mid-run;
these tests prove detection, retry-with-backoff, pack salvage, partial
results and a truthful ``SweepReport`` — and that retried results stay
identical to a crash-free serial run."""
import importlib

import pytest

from repro.api import (Environment, Experiment, ExperimentSpec, ModelRef,
                       sweep)

# the submodule, not the same-named function re-exported by the package
sweep_mod = importlib.import_module("repro.api.sweep")
from repro.api.sweep import SweepReport
from repro.configs import FederatedConfig, RunConfig


def _spec(seed: int, mode: str = "sync", conc: int = 6,
          max_rounds: int = 8, arch: str = "paper-charlm"
          ) -> ExperimentSpec:
    return ExperimentSpec(
        model=ModelRef(arch),
        federated=FederatedConfig(mode=mode, concurrency=conc,
                                  aggregation_goal=max(1, int(conc * 0.8)),
                                  seed=seed),
        run=RunConfig(target_perplexity=1.0, max_rounds=max_rounds),
        environment=Environment(), learner="surrogate")


def _summaries(results):
    return [None if r is None else r.summary() for r in results]


@pytest.fixture
def crash_env(monkeypatch, tmp_path):
    """Arm the crash injector for exactly one spec of a sweep; returns a
    setter so each test picks round/kind/seed. The once-marker lives in
    tmp_path, so the retried attempt succeeds."""
    def arm(at_round, kind, seed, once=True):
        monkeypatch.setenv("REPRO_CRASH_ROUND", str(at_round))
        monkeypatch.setenv("REPRO_CRASH_KIND", kind)
        monkeypatch.setenv("REPRO_CRASH_SEED", str(seed))
        if once:
            monkeypatch.setenv("REPRO_CRASH_ONCE",
                               str(tmp_path / "crash.once"))
    return arm


# ----------------------------------------------------------- clean runs
def test_ft_clean_sweep_reports_all_ok():
    specs = [_spec(s) for s in (1, 2, 3)]
    baseline = [Experiment(s).run().summary() for s in specs]
    results, report = sweep(specs, workers=2, return_report=True)
    assert _summaries(results) == baseline    # process isolation is free
    assert isinstance(report, SweepReport) and report.all_ok
    assert report.counts() == {"ok": 3}
    assert all(r.attempts == 1 and r.error is None for r in report.specs)
    assert all(r.wall_s > 0 for r in report.specs)


def test_ft_empty_sweep():
    results, report = sweep([], return_report=True)
    assert results == [] and report.specs == [] and report.all_ok


# -------------------------------------------------- death and detection
def test_ft_killed_worker_is_retried_and_result_is_identical(crash_env):
    """A worker hard-exiting mid-run (os._exit — no exception, no
    result) is detected by exit code, retried, and the retried spec's
    result matches the crash-free serial baseline exactly."""
    specs = [_spec(s) for s in (10, 11, 12)]
    baseline = [Experiment(s).run().summary() for s in specs]
    crash_env(4, "kill", seed=11)
    failures = []
    results, report = sweep(
        specs, workers=2, retry_limit=2, retry_backoff_s=0.01,
        on_failure=lambda i, e, att: failures.append(
            (i, type(e).__name__, att)),
        return_report=True)
    assert _summaries(results) == baseline
    assert report.counts() == {"ok": 2, "retried": 1}
    rep = report.specs[1]
    assert rep.status == "retried" and rep.attempts == 2
    assert "_WorkerDied" in rep.error
    assert failures == [(1, "_WorkerDied", 1)]


def test_ft_hung_worker_times_out_and_is_retried(crash_env):
    specs = [_spec(s) for s in (20, 21)]
    crash_env(2, "hang", seed=21)
    results, report = sweep(
        specs, workers=2, timeout_s=2.0, retry_limit=1,
        retry_backoff_s=0.01, return_report=True)
    assert all(r is not None for r in results)
    assert report.counts() == {"ok": 1, "retried": 1}
    assert "timeout_s" in report.specs[1].error


def test_ft_exhausted_retries_leave_partial_results(crash_env):
    """retry_limit exhausted -> that spec's slot stays None, status goes
    terminal, and every OTHER spec still returns — partial results
    instead of all-or-nothing."""
    specs = [_spec(s) for s in (30, 31, 32)]
    crash_env(3, "kill", seed=31, once=False)    # crashes EVERY attempt
    results, report = sweep(specs, workers=2, retry_limit=1,
                            retry_backoff_s=0.01, return_report=True)
    assert results[1] is None
    assert results[0] is not None and results[2] is not None
    assert not report.all_ok
    rep = report.specs[1]
    assert rep.status == "failed" and rep.attempts == 2
    assert report.counts() == {"ok": 2, "failed": 1}


def test_ft_raised_failure_without_report_still_returns_partial():
    """Arming FT via on_failure alone (no report asked, no retries)
    returns the plain results list with None in the failed slot."""
    specs = [_spec(40), _spec(41, arch="no-such-arch"), _spec(42)]
    results = sweep(specs, workers=2, on_failure=lambda *a: None)
    assert results[1] is None
    assert results[0] is not None and results[2] is not None


def test_ft_on_result_fires_exactly_once_per_spec(crash_env):
    specs = [_spec(s) for s in (50, 51, 52)]
    crash_env(3, "raise", seed=52)
    seen = []
    results, _ = sweep(specs, workers=2, retry_limit=1,
                       retry_backoff_s=0.01, return_report=True,
                       on_result=lambda i, r: seen.append(i))
    assert sorted(seen) == [0, 1, 2]
    assert all(r is not None for r in results)


# ----------------------------------------------------------- pack salvage
def test_ft_pack_salvage_reruns_survivors_and_isolates_culprit():
    """A lane pack whose crash names a guilty lane: the survivors are
    re-chunked into a fresh sub-pack (outside the retry budget — the
    failure was not theirs), the culprit retries alone and fails; the
    survivors' results match serial baselines."""
    specs = [_spec(60), _spec(61, arch="no-such-arch"),
             _spec(62), _spec(63)]
    good = [0, 2, 3]
    baseline = {i: Experiment(specs[i]).run().summary() for i in good}
    results, report = sweep(specs, workers=1, vectorize=True,
                            retry_limit=1, retry_backoff_s=0.01,
                            return_report=True)
    assert results[1] is None
    assert {i: results[i].summary() for i in good} == baseline
    assert report.counts() == {"retried": 3, "failed": 1}
    assert "spec index 1" in report.specs[1].error
    assert report.specs[1].attempts == 2


# ------------------------------------------- serial fallback + annotation
def test_ft_serial_fallback_when_processes_unavailable(monkeypatch,
                                                       crash_env):
    """No worker processes (restricted env): FT falls back in-process
    with a warning; retries still work, and the failure annotation names
    the sweep spec index exactly like the pool path does."""
    def no_pool(*a, **k):
        raise OSError("no processes here")
    monkeypatch.setattr(sweep_mod, "_sweep_ft_pool", no_pool)
    crash_env(3, "raise", seed=71)
    specs = [_spec(70), _spec(71)]
    with pytest.warns(RuntimeWarning, match="in-process"):
        results, report = sweep(specs, retry_limit=1,
                                retry_backoff_s=0.01, return_report=True)
    assert all(r is not None for r in results)
    assert report.counts() == {"ok": 1, "retried": 1}
    assert "sweep spec index 1" in report.specs[1].error


def test_legacy_serial_fallback_failure_names_spec_index(monkeypatch):
    """Regression (satellite): the LEGACY pool-fallback serial rerun must
    annotate a failing spec with the same index context the pool path
    attaches — the traceback names the spec whichever path ran it."""
    def no_pool(*a, **k):
        raise OSError("no pool")
    monkeypatch.setattr(sweep_mod, "_sweep_pool", no_pool)
    specs = [_spec(80), _spec(81, arch="no-such-arch")]
    with pytest.warns(RuntimeWarning, match="in-process"):
        with pytest.raises(KeyError, match="sweep spec index 1"):
            sweep(specs, workers=2)


def test_legacy_serial_failure_names_spec_index():
    specs = [_spec(90), _spec(91, arch="no-such-arch")]
    with pytest.raises(KeyError, match="sweep spec index 1"):
        sweep(specs, workers=1)


def test_legacy_sweep_semantics_unchanged():
    """Without any FT knob the all-or-nothing contract stands: results in
    spec order, no report, first failure propagates."""
    specs = [_spec(s) for s in (100, 101)]
    results = sweep(specs, workers=1)
    assert [r.summary() for r in results] \
        == [Experiment(s).run().summary() for s in specs]
