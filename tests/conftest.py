"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 devices."""
import os

# keep test threads polite on shared CI boxes
os.environ.setdefault("XLA_FLAGS", "")

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    np.set_printoptions(precision=4, suppress=True)
