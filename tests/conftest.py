"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 devices."""
import os

# keep test threads polite on shared CI boxes
os.environ.setdefault("XLA_FLAGS", "")

try:
    import hypothesis  # noqa: F401  — real package, if the image has it
except ImportError:  # fall back to the deterministic stub in this dir
    import _hypothesis_stub
    _hypothesis_stub.install()

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    np.set_printoptions(precision=4, suppress=True)
