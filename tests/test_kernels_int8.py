"""int8 quant kernel: shape/dtype sweeps vs the pure-jnp oracle +
hypothesis property tests on the codec's error bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.int8_quant import ops, ref
from repro.kernels.int8_quant.kernel import (dequant_accumulate_pallas,
                                             quantize_pallas)

SHAPES = [(64,), (1000,), (128, 128), (3, 7, 11), (2048, 33)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("block", [128, 256])
def test_pallas_quantize_matches_ref(shape, dtype, block):
    x = jax.random.normal(jax.random.PRNGKey(7), shape, dtype)
    q1, s1 = quantize_pallas(x, block=block, interpret=True)
    q0, s0 = ref.quantize_ref(x, block)
    nb = q0.shape[0]
    np.testing.assert_array_equal(np.asarray(q1)[:nb], np.asarray(q0))
    np.testing.assert_allclose(np.asarray(s1)[:nb], np.asarray(s0), rtol=1e-6)
    # padding rows must be exactly zero-scale-one
    assert (np.asarray(q1)[nb:] == 0).all()


@pytest.mark.parametrize("shape", [(512,), (64, 48)])
def test_pallas_dequant_accumulate(shape):
    x = jax.random.normal(jax.random.PRNGKey(3), shape)
    acc = jax.random.normal(jax.random.PRNGKey(4), shape)
    q, s = quantize_pallas(x, block=128, interpret=True)
    got = ops.dequant_accumulate(acc, q, s, 0.25, block=128, use_pallas=True)
    want = ref.dequant_accumulate_ref(
        acc, *ref.quantize_ref(x, 128), 0.25, block=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 2000), st.integers(0, 2**31 - 1),
       st.floats(1e-3, 1e3))
def test_roundtrip_error_bound(n, seed, scale):
    """|x - dq(q(x))| <= block_amax / 254 + eps, per element."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed), (n,))) * scale
    y = np.asarray(ref.quant_dequant_ref(jnp.asarray(x), 256))
    xb = np.pad(x, (0, (-n) % 256)).reshape(-1, 256)
    amax = np.abs(xb).max(axis=1)
    bound = np.repeat(amax / 254.0 + 1e-6, 256)[:n] * (1 + 1e-3)
    assert (np.abs(x - y) <= bound + 1e-7).all()


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000))
def test_accumulate_linearity(seed):
    """acc' = acc + w*dq is exactly linear in w."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (300,))
    acc = jnp.zeros((300,))
    q, s = ref.quantize_ref(x, 256)
    a1 = ref.dequant_accumulate_ref(acc, q, s, 1.0)
    a2 = ref.dequant_accumulate_ref(acc, q, s, 2.0)
    np.testing.assert_allclose(np.asarray(a2), 2 * np.asarray(a1), rtol=1e-6)


def test_wire_bytes():
    assert ops.wire_bytes(256) == 256 + 4
    assert ops.wire_bytes(257) == 257 + 8
    # 4x smaller than f32 for big tensors (modulo scale overhead)
    n = 1_000_000
    assert ops.wire_bytes(n) < 4 * n / 3.8
