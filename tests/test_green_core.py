"""Green core: energy/carbon/network models, estimator, predictor."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import carbon
from repro.core.energy import (client_session_energy, server_energy_j,
                               SERVER_TASK_POWER_W)
from repro.core.estimator import CarbonEstimator
from repro.core.network import DEFAULT_NETWORK, NetworkEnergyModel
from repro.core.predictor import CarbonPredictor, fit_linear
from repro.core.profiles import FLEET, COUNTRY_MIX
from repro.core.telemetry import ClientSession, TaskLog


def _session(device="pixel-3", country="US", compute=60.0, up=30.0, dn=10.0,
             outcome="completed"):
    return ClientSession(
        client_id=1, round_idx=0, device=device, country=country,
        download_s=dn, compute_s=compute, upload_s=up,
        bytes_down=64e6, bytes_up=64e6, start_t=0.0, end_t=100.0,
        outcome=outcome)


def test_device_power_from_profile_fields():
    p = FLEET[0]
    # Watt's law: (active + cluster + cores*core) mA * 3.8 V
    want = (p.cpu_active_ma + p.cpu_cluster_ma
            + p.big_cores * p.cpu_core_ma) / 1000 * 3.8
    assert abs(p.cpu_power_w - want) < 1e-9
    assert 0.5 < p.cpu_power_w < 8.0          # phone-plausible
    assert p.wifi_tx_power_w > p.wifi_rx_power_w


def test_session_energy_linear_in_durations():
    p = FLEET[0]
    e1 = client_session_energy(p, 10, 5, 2)
    e2 = client_session_energy(p, 20, 10, 4)
    assert abs(e2.total_j - 2 * e1.total_j) < 1e-9


def test_network_energy_per_bit():
    m = DEFAULT_NETWORK
    assert 50e-9 < m.energy_per_bit_j < 500e-9       # literature band
    assert m.transfer_energy_j(1e6) == pytest.approx(
        8e6 * m.energy_per_bit_j)


def test_carbon_intensity_table():
    assert carbon.intensity("NO") < carbon.intensity("WORLD") < \
        carbon.intensity("IN")
    assert carbon.intensity("??") == carbon.intensity("WORLD")
    dc = carbon.datacenter_intensity()
    assert 200 < dc < 450          # US-heavy mix


def test_co2_units():
    # 1 kWh at 1000 g/kWh = 1 kg
    assert carbon.co2e_kg(3.6e6, 1000.0) == pytest.approx(1.0)


def test_datacenter_intensity_empty_locations_falls_back():
    """Satellite fix: an empty (or zero-weighted) datacenter fleet must
    fall back to the model's fallback intensity, not divide by zero."""
    m = carbon.IntensityModel(datacenter_locations={})
    assert m.datacenter_intensity() == m.intensity("WORLD")
    z = carbon.IntensityModel(datacenter_locations={"US": 0})
    assert z.datacenter_intensity() == z.intensity("WORLD")
    custom = carbon.IntensityModel(datacenter_locations={},
                                   table={"WORLD": 475.0, "X": 10.0},
                                   fallback="X")
    assert custom.datacenter_intensity() == 10.0
    # and the estimator path survives it end to end
    from repro.core.estimator import CarbonEstimator
    est = CarbonEstimator(intensity=m)
    log = TaskLog()
    log.log_session(_session())
    log.duration_s = 3600.0
    assert est.estimate(log).server_kg > 0


def test_estimator_components_and_accounting_of_dropouts():
    est = CarbonEstimator()
    log = TaskLog()
    log.log_session(_session())
    log.log_session(_session(outcome="dropped", up=0.0))
    log.duration_s = 3600.0
    br = est.estimate(log)
    assert br.total_kg > 0
    sh = br.shares()
    assert abs(sum(sh.values()) - 1.0) < 1e-9
    # dropped session still contributed compute carbon
    est2 = CarbonEstimator()
    log2 = TaskLog()
    log2.log_session(_session())
    log2.duration_s = 3600.0
    br2 = est2.estimate(log2)
    assert br.client_compute_kg > br2.client_compute_kg


def test_server_energy_pue():
    assert server_energy_j(3600.0) == pytest.approx(
        2 * SERVER_TASK_POWER_W * 1.09 * 3600)


@settings(max_examples=25, deadline=None)
@given(st.floats(0.1, 100.0), st.floats(-5.0, 5.0), st.integers(0, 10**6))
def test_predictor_recovers_linear_law(slope, intercept, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(10, 1e4, size=40)
    y = slope * x + intercept * 100 + rng.normal(0, 1e-6, size=40)
    fit = fit_linear(x, y)
    assert fit.r2 > 0.9999
    assert fit.slope == pytest.approx(slope, rel=1e-3)


def test_carbon_predictor_api():
    pred = CarbonPredictor.from_measurements(
        "sync", concurrency=[100, 200, 400, 800],
        rounds_or_hours=[500, 400, 300, 250],
        carbon_kg=[3.0, 4.8, 7.2, 12.0])
    kg = pred.predict_kg(1000, 240)
    assert 10 < kg < 20
    assert pred.fit.r2 > 0.9


def test_country_mix_normalized():
    assert abs(sum(COUNTRY_MIX.values()) - 1.0) < 1e-9
    assert all(v >= 0 for v in COUNTRY_MIX.values())
