"""Fault injection & recovery (PR 7): correlated failure bursts,
retry/backoff re-dispatch, quorum starvation and the carbon price of
wasted work.

The contract under test:

* an all-zero ``FaultModel`` is **bit-for-bit** today's fault-free engine
  (static AND diurnal intensity schedules — the goldens in
  ``test_columnar.py`` pin the absolute numbers, here we pin equality);
* with faults enabled, the columnar engines, lane packs and the scalar
  oracle agree seed for seed (summaries/participation exact between the
  columnar paths; oracle durations to the usual libm-ulp tolerance);
* ``contributed + wasted`` carbon sums exactly to total CO2e in streaming
  and materialized telemetry alike — including cancelled in-flight
  cohorts;
* retry/backoff, quorum starvation and task abort behave as configured,
  and every construction-time knob validates with a ``ValueError``.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import (Environment, Experiment, ExperimentSpec, ModelRef,
                       sweep)
from repro.configs import FederatedConfig, RunConfig, get_config
from repro.core.carbon import UTC_OFFSET_H
from repro.core.faults import FaultModel, wave_hazard_schedule
from repro.core.streaming import StreamedLog
from repro.core.telemetry import OUTCOMES
from repro.federated.events import SessionSampler
from repro.federated.reference import run_scalar
from repro.federated.runtime import get_strategy
from repro.federated.surrogate import SurrogateLearner

CFG = get_config("paper-charlm")

_COLS = ("client_id", "round_idx", "device_idx", "country_idx",
         "download_s", "compute_s", "upload_s", "bytes_down", "bytes_up",
         "start_t", "end_t", "outcome", "staleness")

_COUNTRIES = ("US", "FR", "BR", "IN", "SE", "NO")

_BURSTY = FaultModel(hazard={"US": 0.12, "FR": 0.08, "WORLD": 0.06},
                     burst_rate_per_day=6.0, burst_duration_s=2400.0,
                     burst_fail_prob=0.6, seed=3)
_DIURNAL_HAZARD = FaultModel(
    hazard_schedule=wave_hazard_schedule(_COUNTRIES, base=0.10),
    hazard_phase_h={c: UTC_OFFSET_H.get(c, 0.0) for c in _COUNTRIES},
    burst_rate_per_day=4.0, burst_fail_prob=0.5, seed=7)

_FAULTS = (_BURSTY, _DIURNAL_HAZARD)

_MODES = ("sync", "async", "carbon-aware")


def _spec(mode: str, conc: int, goal_frac: float, seed: int,
          max_rounds: int, fault: FaultModel = _BURSTY,
          env_kw: dict = None, telemetry: str = "full",
          **fed_kw) -> ExperimentSpec:
    return ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(
            mode=mode, concurrency=conc,
            aggregation_goal=max(1, int(conc * goal_frac)),
            seed=seed, dropout_rate=0.05, **fed_kw),
        run=RunConfig(target_perplexity=175.0, max_rounds=max_rounds,
                      telemetry=telemetry, telemetry_sample=64),
        environment=Environment(fault=fault, **(env_kw or {})),
        learner="surrogate")


def _assert_same(res_a, res_b, cols: bool = True) -> None:
    sa, sb = res_a.summary(), res_b.summary()
    assert sa == sb, {k: (sa[k], sb[k]) for k in sa if sa[k] != sb[k]}
    assert res_a.log.participation() == res_b.log.participation()
    assert res_a.log.starved_rounds == res_b.log.starved_rounds
    if cols:
        ca, cb = res_a.log.columns(), res_b.log.columns()
        for f in _COLS:
            assert np.array_equal(getattr(ca, f), getattr(cb, f)), f


# ------------------------------------------------------- zero-rate identity
@pytest.mark.parametrize("mode", list(_MODES))
@pytest.mark.parametrize("diurnal", [False, True])
def test_zero_rate_fault_model_is_bit_identical(mode, diurnal):
    """An all-zero FaultModel (even with retry/quorum knobs armed) takes
    the fault-free fast path untouched: summaries AND session columns are
    bit-for-bit the no-fault run, on static and diurnal schedules."""
    env_kw = {"intensity_schedule": Environment.preset("diurnal")
              .intensity_schedule} if diurnal else {}
    base = _spec(mode, 24, 0.8, 11, 8, fault=FaultModel(), env_kw=env_kw,
                 retry_limit=3, retry_backoff_s=60.0,
                 min_report_fraction=0.0, starvation_patience=0)
    plain = base.replace(environment=Environment(**(env_kw or {})))
    ra, rb = Experiment(base).run(), Experiment(plain).run()
    assert not FaultModel().enabled
    _assert_same(ra, rb)
    assert ra.log.participation().get("failed", 0) == 0
    assert ra.log.participation().get("retried", 0) == 0


# -------------------------------------------------- serial == lane == oracle
@settings(max_examples=5, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_faulty_lane_pack_matches_serial_property(seed0):
    """Randomized faulty packs (all three modes, both fault models, mixed
    retry/quorum knobs, diurnal hazards) are bit-for-bit equal to per-spec
    serial runs — summary scalars AND session columns."""
    rng = np.random.default_rng(seed0)
    specs = []
    for j in range(int(rng.integers(3, 6))):
        specs.append(_spec(
            mode=_MODES[int(rng.integers(len(_MODES)))],
            conc=int(rng.integers(10, 40)),
            goal_frac=float(rng.uniform(0.4, 1.0)),
            seed=int(rng.integers(0, 2 ** 31)),
            max_rounds=int(rng.integers(4, 14)),
            fault=_FAULTS[int(rng.integers(len(_FAULTS)))],
            retry_limit=int(rng.integers(0, 4)),
            retry_backoff_s=float(rng.choice([0.0, 15.0, 45.0])),
            min_report_fraction=float(rng.choice([0.0, 0.3, 0.7])),
            starvation_patience=int(rng.integers(0, 4))))
    serial = [Experiment(s).run() for s in specs]
    lane = sweep(specs, workers=1, vectorize=True)
    saw_faults = False
    for rl, rs in zip(lane, serial):
        _assert_same(rl, rs)
        assert rl.aborted == rs.aborted
        p = rl.log.participation()
        if p.get("failed") or p.get("retried"):
            saw_faults = True
    assert saw_faults


@pytest.mark.parametrize("mode", ["sync", "async"])
def test_faulty_engine_matches_scalar_oracle(mode):
    """With faults + retries live, the columnar engine replays the scalar
    oracle seed for seed: identical ids/outcomes/rounds/starts, durations
    to the scalar-vs-vector libm tolerance (same bar as the fault-free
    oracle tests), carbon split included."""
    fed = FederatedConfig(mode=mode, concurrency=30, aggregation_goal=20,
                          seed=5, retry_limit=2, retry_backoff_s=15.0,
                          min_report_fraction=0.4, starvation_patience=4)
    run = RunConfig(target_perplexity=175.0, max_rounds=15)
    mk = lambda: SessionSampler(CFG, fed, 64, fault=_DIURNAL_HAZARD)
    vec = get_strategy(mode).run(CFG, fed, run,
                                 SurrogateLearner(CFG, fed, run),
                                 sampler=mk())
    ref = run_scalar(CFG, fed, run, SurrogateLearner(CFG, fed, run),
                     sampler=mk())
    assert vec.rounds == ref.rounds
    assert vec.log.participation() == ref.log.participation()
    assert vec.log.participation().get("retried", 0) > 0
    assert vec.log.starved_rounds == ref.log.starved_rounds
    assert vec.aborted == ref.aborted
    for k, v in vec.carbon.as_dict().items():
        assert v == pytest.approx(ref.carbon.as_dict()[k], rel=1e-9), k
    bv, br = vec.log.columns(), ref.log.columns()
    # the oracle's vocab is built in order of appearance — remap
    dmap = np.asarray([bv.device_names.index(x) for x in br.device_names])
    cmap = np.asarray([bv.country_names.index(x) for x in br.country_names])
    assert np.array_equal(bv.client_id, br.client_id)
    assert np.array_equal(bv.round_idx, br.round_idx)
    assert np.array_equal(bv.outcome, br.outcome)
    assert np.array_equal(bv.staleness, br.staleness)
    assert np.array_equal(bv.device_idx, dmap[br.device_idx])
    assert np.array_equal(bv.country_idx, cmap[br.country_idx])
    for f in ("download_s", "compute_s", "upload_s", "bytes_down",
              "bytes_up", "start_t", "end_t"):
        np.testing.assert_allclose(getattr(bv, f), getattr(br, f),
                                   rtol=1e-9, atol=1e-12, err_msg=f)


# ------------------------------------------------- contributed + wasted split
@pytest.mark.parametrize("mode", ["sync", "async"])
def test_contributed_plus_wasted_sums_exactly_to_total(mode):
    """The carbon split partitions sessions by completion: contributed +
    wasted == total **exactly** (not approx) in materialized AND streaming
    telemetry, the two paths agree bit-for-bit, and a faulty run wastes
    strictly more than zero."""
    spec = _spec(mode, 28, 0.7, 9, 10, retry_limit=2)
    full = Experiment(spec).run()
    stream = Experiment(spec.replace(run=dataclasses.replace(
        spec.run, telemetry="streaming"))).run()
    for res in (full, stream):
        c = res.carbon
        assert c.contributed_kg + c.wasted_kg == c.total_kg   # exact
        assert c.wasted_kg > 0
        assert c.contributed_kg > c.server_kg > 0
    assert isinstance(stream.log, StreamedLog)
    assert full.summary() == stream.summary()                 # bit-for-bit
    # the split matches a per-session reference reduction
    b = full.log.columns()
    est = spec.environment.estimator()
    scalar = est.estimate_scalar(full.log)
    assert full.carbon.contributed_kg == pytest.approx(
        scalar.contributed_kg, rel=1e-9)
    assert full.carbon.wasted_kg == pytest.approx(scalar.wasted_kg,
                                                  rel=1e-9)
    assert (b.outcome != OUTCOMES.index("completed")).any()


def test_streaming_cancelled_cohort_carbon_accounting():
    """Satellite: an async task cut at the round cap leaves a cancelled
    in-flight cohort; under ``telemetry="streaming"`` its truncated energy
    must land in ``wasted_kg`` exactly as the materialized path charges
    it (the PR 5 cancel-flush, folded instead of stored)."""
    spec = _spec("async", 48, 0.8, 4, 8, fault=FaultModel(),
                 telemetry="streaming")
    stream = Experiment(spec).run()
    full = Experiment(spec.replace(run=dataclasses.replace(
        spec.run, telemetry="full"))).run()
    parts = stream.log.participation()
    assert parts.get("cancelled", 0) > 0
    assert stream.summary() == full.summary()
    assert stream.carbon.wasted_kg == full.carbon.wasted_kg > 0
    assert stream.carbon.contributed_kg + stream.carbon.wasted_kg \
        == stream.carbon.total_kg
    # cancelled energy is real (not all-zero rows) and counted as waste:
    # dropping the cancelled rows out of the materialized log must shrink
    # wasted_kg
    from repro.core.telemetry import TaskLog
    sub = TaskLog()
    for s in full.log.sessions:
        if s.outcome != "cancelled":
            sub.log_session(s)
    sub.duration_s = full.log.duration_s
    est = spec.environment.estimator()
    assert est.estimate(sub).wasted_kg < full.carbon.wasted_kg


# --------------------------------------------------------- recovery behavior
def test_retry_labels_and_backoff():
    """Failures below the attempt budget are logged ``retried`` (a retry
    went out), only final-attempt failures stay ``failed``; with
    ``retry_limit=0`` nothing is ever relabeled. Backoff delays are
    visible as retry sessions starting strictly after the failure that
    spawned them."""
    with_retry = Experiment(_spec("async", 24, 0.8, 2, 12, retry_limit=2,
                                  retry_backoff_s=30.0)).run()
    p = with_retry.log.participation()
    assert p.get("retried", 0) > 0
    no_retry = Experiment(_spec("async", 24, 0.8, 2, 12,
                                retry_limit=0)).run()
    p0 = no_retry.log.participation()
    assert p0.get("retried", 0) == 0 and p0.get("failed", 0) > 0
    # sync: every attempt is charged — the faulty run logs MORE sessions
    # than concurrency*rounds (the retry waves ride along)
    sy = Experiment(_spec("sync", 20, 0.8, 3, 10, retry_limit=3)).run()
    assert sy.log.n_sessions > 20 * sy.rounds
    assert sy.log.participation().get("retried", 0) > 0


def test_starvation_quorum_and_abort():
    """A hazard-saturated sync task under a full quorum starves every
    round and aborts after ``starvation_patience`` rounds — surfaced on
    Result.aborted and the summary — identically in serial and lane runs.
    Async never starves per-round (no round deadline), so the same config
    runs to its cap un-aborted."""
    dead = FaultModel(hazard={"WORLD": 1.0})   # every survivor fails
    spec = _spec("sync", 12, 1.0, 1, 50, fault=dead,
                 min_report_fraction=1.0, starvation_patience=3,
                 retry_limit=1)
    spec = spec.replace(federated=dataclasses.replace(
        spec.federated, dropout_rate=0.0))
    res = Experiment(spec).run()
    assert res.aborted and res.summary()["aborted"] == 1.0
    assert res.rounds == 3                       # patience, then abort
    assert res.log.starved_rounds == 3
    assert res.log.participation().get("completed", 0) == 0
    lane = sweep([spec], workers=1, vectorize=True)[0]
    _assert_same(lane, res)
    assert lane.aborted
    oracle = run_scalar(CFG, spec.federated, spec.run,
                        SurrogateLearner(CFG, spec.federated, spec.run),
                        sampler=spec.environment.sampler(CFG, spec.federated,
                                                         spec.seq_len))
    assert oracle.aborted and oracle.rounds == 3
    assert oracle.log.starved_rounds == 3
    # async: same saturation, no per-round quorum -> no abort (the
    # duration budget, not starvation, ends a task that never aggregates)
    aspec = spec.replace(
        federated=dataclasses.replace(spec.federated, mode="async"),
        run=dataclasses.replace(spec.run, max_hours=0.5))
    ares = Experiment(aspec).run()
    assert not ares.aborted
    assert ares.log.participation().get("completed", 0) == 0
    # without patience, the sync task starves forever but still walks to
    # its round cap instead of aborting
    pspec = spec.replace(federated=dataclasses.replace(
        spec.federated, starvation_patience=0),
        run=dataclasses.replace(spec.run, max_rounds=6))
    pres = Experiment(pspec).run()
    assert not pres.aborted and pres.rounds == 6
    assert pres.log.starved_rounds == 6


# ------------------------------------------------------- validation + wiring
def test_construction_time_validation():
    """Satellite: bad knobs fail loudly at construction, not mid-run."""
    with pytest.raises(ValueError, match="dropout_rate"):
        FederatedConfig(dropout_rate=-0.1)
    with pytest.raises(ValueError, match="aggregation_goal"):
        FederatedConfig(aggregation_goal=0)
    with pytest.raises(ValueError, match="concurrency"):
        FederatedConfig(concurrency=0)
    with pytest.raises(ValueError, match="retry_limit"):
        FederatedConfig(retry_limit=-1)
    with pytest.raises(ValueError, match="retry_backoff_s"):
        FederatedConfig(retry_backoff_s=-5.0)
    with pytest.raises(ValueError, match="min_report_fraction"):
        FederatedConfig(min_report_fraction=1.5)
    with pytest.raises(ValueError, match="starvation_patience"):
        FederatedConfig(starvation_patience=-2)
    with pytest.raises(ValueError, match="carbon_topk"):
        FederatedConfig(carbon_topk=0)
    with pytest.raises(ValueError, match="hazard"):
        FaultModel(hazard={"US": 1.5})
    with pytest.raises(ValueError, match="hazard_schedule"):
        FaultModel(hazard_schedule={"US": ()})
    with pytest.raises(ValueError, match="burst_rate_per_day"):
        FaultModel(burst_rate_per_day=-1.0)
    with pytest.raises(ValueError, match="burst_fail_prob"):
        FaultModel(burst_fail_prob=2.0)
    with pytest.raises(ValueError, match="country_mix"):
        Environment(country_mix={"US": -1.0})
    # carbon_topk wider than the participation vocabulary: caught when
    # the sampler binds the config to an Environment's country mix
    fed = FederatedConfig(mode="carbon-aware", carbon_topk=6)
    env = Environment(country_mix={"US": 0.5, "FR": 0.5})
    with pytest.raises(ValueError, match="carbon_topk"):
        env.sampler(CFG, fed, 64)


def test_fault_model_json_round_trip():
    """FaultModel (and the whole faulty Environment) survives the spec
    JSON round trip — and the round-tripped spec reruns bit-for-bit."""
    spec = _spec("async", 16, 0.8, 6, 6, fault=_DIURNAL_HAZARD,
                 retry_limit=2, min_report_fraction=0.25)
    back = ExperimentSpec.from_json(spec.to_json())
    assert back.environment.fault == spec.environment.fault
    assert back.federated.retry_limit == 2
    assert FaultModel.from_dict(FaultModel().to_dict()) == FaultModel()
    _assert_same(Experiment(back).run(), Experiment(spec).run())


def test_sweep_failures_name_the_lane_and_spec():
    """Satellite: a spec that dies inside a lane pack is annotated with
    its lane and sweep index (the pool fallback already names the
    remaining spec indices)."""
    good = _spec("carbon-aware", 10, 0.8, 0, 4, fault=FaultModel())
    bad = good.replace(environment=Environment(
        country_mix={"US": 0.5, "FR": 0.5}))   # carbon_topk 6 > 2 countries
    with pytest.raises(ValueError, match=r"lane 1 \(spec index 1\)"):
        sweep([good, bad, good.replace(
            federated=dataclasses.replace(good.federated, seed=1))],
            workers=1, vectorize=True)


def test_sweep_fallback_warning_names_spec_indices(monkeypatch):
    """The serial-fallback warning now says WHICH specs it reruns."""
    import importlib
    sweep_mod = importlib.import_module("repro.api.sweep")
    specs = [_spec("sync", 8, 0.8, s, 3, fault=FaultModel())
             for s in range(3)]

    def broken_pool(jobs, specs_, n, deliver):
        deliver([0], [sweep_mod.run_spec(specs_[0])])
        raise OSError("pool vanished")

    monkeypatch.setattr(sweep_mod, "_sweep_pool", broken_pool)
    with pytest.warns(RuntimeWarning,
                      match=r"spec indices \[1, 2\]"):
        results = sweep(specs, workers=3)
    assert all(r is not None for r in results)
