"""Engine snapshots (PR 9): a checkpointed run killed at an arbitrary
round and resumed must reproduce the uninterrupted run **bit for bit** —
summary scalars AND session columns — on every strategy × telemetry ×
environment combination. Plus the serialization primitives underneath
(ExactSum state round-trip), the forward-compat guards (unknown snapshot
version, wrong-spec resume), and the test-only crash injector that
drives the property tests and the fault-tolerant sweep suite."""
import json
import math
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.api import Environment, Experiment, ExperimentSpec, ModelRef
from repro.configs import FederatedConfig, RunConfig
from repro.core.estimator import ExactSum
from repro.core.network import NetworkEnergyModel
from repro.core.profiles import FLEET
from repro.core.snapshot import (InjectedCrash, SNAPSHOT_VERSION,
                                 _CrashInjector, load_snapshot)
from repro.core.telemetry import _ACC_DTYPES

_ENVS = (Environment(),
         Environment(download_bps=20e6, upload_bps=5e6,
                     network=NetworkEnergyModel(e_access_nj=80.0),
                     fleet=FLEET[:3], pue=1.3,
                     carbon_intensity={"WORLD": 300.0, "US": 100.0}),
         Environment.preset("diurnal"))

_MODES = ("sync", "async", "carbon-aware")


def _spec(mode: str, seed: int = 99, env_idx: int = 0,
          telemetry: str = "full", conc: int = 8,
          max_rounds: int = 20) -> ExperimentSpec:
    # target_perplexity=1.0 is unreachable: runs always go the full
    # max_rounds, so an injected crash round < max_rounds always fires
    return ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(mode=mode, concurrency=conc,
                                  aggregation_goal=max(1, int(conc * 0.8)),
                                  seed=seed, dropout_rate=0.05),
        run=RunConfig(target_perplexity=1.0, max_rounds=max_rounds,
                      telemetry=telemetry, telemetry_sample=50),
        environment=_ENVS[env_idx % len(_ENVS)], learner="surrogate")


def _assert_same_columns(got, want):
    assert got.device_names == want.device_names
    assert got.country_names == want.country_names
    for f in _ACC_DTYPES:
        a, b = getattr(got, f), getattr(want, f)
        assert a.dtype == b.dtype, f
        assert np.array_equal(a, b), f


def _crash_and_resume(monkeypatch, tmp_path, spec, crash_at, every=4):
    """Run with checkpointing until the injected crash, then resume."""
    path = str(tmp_path / "snap.npz")
    monkeypatch.setenv("REPRO_CRASH_ROUND", str(crash_at))
    monkeypatch.setenv("REPRO_CRASH_KIND", "raise")
    with pytest.raises(InjectedCrash):
        Experiment(spec).run(checkpoint_path=path,
                             checkpoint_every_rounds=every)
    monkeypatch.delenv("REPRO_CRASH_ROUND")
    assert os.path.exists(path)
    return path, Experiment.resume(path)


# -------------------------------------------------- bit-for-bit resume
@pytest.mark.parametrize("telemetry", ("full", "streaming"))
@pytest.mark.parametrize("mode", _MODES)
@settings(max_examples=3, deadline=None)
@given(st.integers(min_value=6, max_value=18),
       st.integers(min_value=0, max_value=10_000))
def test_killed_and_resumed_run_is_bit_exact(mode, telemetry, monkeypatch,
                                             tmp_path, crash_at, seed0):
    """The property the whole subsystem exists for: kill at a random
    round, resume from the last checkpoint, get the identical experiment
    — summaries `==` and every session column array_equal (dtype
    included) — for static and diurnal schedules alike."""
    rng = np.random.default_rng(seed0)
    spec = _spec(mode, seed=int(rng.integers(0, 2 ** 31)),
                 env_idx=int(rng.integers(len(_ENVS))), telemetry=telemetry)
    base = Experiment(spec).run()
    assert base.rounds == spec.run.max_rounds     # crash round was live
    _, res = _crash_and_resume(monkeypatch, tmp_path, spec, crash_at)
    assert res.summary() == base.summary()
    _assert_same_columns(res.log.columns(), base.log.columns())


def test_resume_keeps_checkpointing_to_the_same_file(monkeypatch,
                                                     tmp_path):
    """By default `Experiment.resume` continues the checkpoint cadence it
    found in the snapshot, so a resumed run that crashes AGAIN loses at
    most `every` rounds — the file must advance past the crash round."""
    spec = _spec("sync")
    path, res = _crash_and_resume(monkeypatch, tmp_path, spec,
                                  crash_at=10, every=4)
    assert res.rounds == spec.run.max_rounds
    final = load_snapshot(path)
    assert final.round_idx > 10
    assert final.every == 4


def test_checkpoint_file_round_trips_spec(monkeypatch, tmp_path):
    """The spec travels inside the header: a loaded snapshot rebuilds an
    ExperimentSpec equal to the producer's, so `resume(path)` needs no
    other argument."""
    spec = _spec("async", env_idx=2, telemetry="streaming")
    path, _ = _crash_and_resume(monkeypatch, tmp_path, spec, crash_at=9)
    snap = load_snapshot(path)
    assert snap.spec().to_dict() == spec.to_dict()
    assert snap.spec_hash == spec.content_hash()


# ---------------------------------------------------- guards and errors
def test_unknown_snapshot_version_is_a_clear_error(monkeypatch, tmp_path):
    spec = _spec("sync")
    path, _ = _crash_and_resume(monkeypatch, tmp_path, spec, crash_at=8)
    with np.load(path, allow_pickle=False) as data:
        arrays = {k: data[k] for k in data.files if k != "header"}
        header = json.loads(str(data["header"][()]))
    header["version"] = 999
    np.savez(path, header=np.asarray(json.dumps(header)), **arrays)
    with pytest.raises(ValueError) as ei:
        load_snapshot(path)
    # the error names BOTH the found and the supported version
    assert "999" in str(ei.value)
    assert str(SNAPSHOT_VERSION) in str(ei.value)


def test_non_snapshot_file_is_rejected(tmp_path):
    path = str(tmp_path / "junk.npz")
    np.savez(path, x=np.arange(3))
    with pytest.raises(ValueError, match="no header"):
        load_snapshot(path)
    np.savez(path, header=np.asarray(json.dumps({"format": "other"})))
    with pytest.raises(ValueError, match="format tag"):
        load_snapshot(path)


def test_wrong_spec_resume_names_both_hashes(monkeypatch, tmp_path):
    spec = _spec("sync", seed=7)
    path, _ = _crash_and_resume(monkeypatch, tmp_path, spec, crash_at=8)
    other = _spec("sync", seed=8)
    with pytest.raises(ValueError) as ei:
        Experiment(other).run(resume_from=path)
    msg = str(ei.value)
    assert spec.content_hash() in msg       # the checkpoint's spec
    assert other.content_hash() in msg      # the resuming spec
    # and the matching spec still resumes fine
    assert Experiment(spec).run(resume_from=path).rounds \
        == spec.run.max_rounds


def test_checkpoint_knob_validation():
    spec = _spec("sync")
    with pytest.raises(ValueError, match="checkpoint_every_rounds"):
        Experiment(spec).run(checkpoint_path="/tmp/never.npz")
    real = ExperimentSpec(model=ModelRef("paper-charlm", reduced=True),
                          federated=FederatedConfig(mode="sync"),
                          run=RunConfig(max_rounds=1), learner="real")
    with pytest.raises(ValueError, match="surrogate"):
        Experiment(real).run(checkpoint_path="/tmp/never.npz",
                             checkpoint_every_rounds=1)


# -------------------------------------------------------- crash injector
def test_crash_injector_env_arming(tmp_path):
    assert _CrashInjector.from_env({}) is None
    ci = _CrashInjector.from_env({"REPRO_CRASH_ROUND": "5"})
    assert ci.at_round == 5 and ci.kind == "raise"
    ci.tick(4)                               # below the trigger: no-op
    with pytest.raises(InjectedCrash, match="round 5"):
        ci.tick(5)
    # REPRO_CRASH_SEED targets one spec of a sweep
    env = {"REPRO_CRASH_ROUND": "5", "REPRO_CRASH_SEED": "42",
           "REPRO_CRASH_KIND": "kill"}
    assert _CrashInjector.from_env(env, seed=41) is None
    armed = _CrashInjector.from_env(env, seed=42)
    assert armed is not None and armed.kind == "kill"


def test_crash_injector_once_marker_disarms_the_retry(tmp_path):
    marker = str(tmp_path / "crashed.once")
    ci = _CrashInjector(3, "raise", once_path=marker)
    with pytest.raises(InjectedCrash):
        ci.tick(3)
    assert os.path.exists(marker)            # created BEFORE crashing
    _CrashInjector(3, "raise", once_path=marker).tick(7)   # retry survives


# --------------------------------------------------- ExactSum round-trip
def test_exact_sum_state_round_trip():
    """state()/from_state() must preserve the *exact* accumulator — the
    restored object keeps folding and stays bit-identical to one that
    never stopped, including negative totals and huge exponent spread."""
    rng = np.random.default_rng(3)
    x = rng.standard_normal(4000) * np.exp(rng.uniform(-60, 60, 4000))
    a = ExactSum().add(x[:1500])
    b = ExactSum.from_state(a.state())
    assert b.value() == a.value()
    assert b.add(x[1500:]).value() == ExactSum().add(x).value() \
        == math.fsum(x.tolist())
    neg = ExactSum().add(np.asarray([-1e300, 1.0, -2.0 ** -40]))
    assert ExactSum.from_state(neg.state()).value() == neg.value()
    empty = ExactSum()
    assert ExactSum.from_state(empty.state()).value() == 0.0
    # states are JSON-safe (that is how they travel in the header)
    assert ExactSum.from_state(
        json.loads(json.dumps(a.state()))).value() == a.value()


def test_exact_sum_state_version_guard():
    bad = dict(ExactSum().state(), version=99)
    with pytest.raises(ValueError, match="99"):
        ExactSum.from_state(bad)
