"""WKV recurrence kernel: shape/dtype sweeps vs the pure-jnp oracle, plus
consistency with the model's chunked two-level scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.wkv.kernel import wkv_pallas
from repro.kernels.wkv.ref import wkv_ref


def _inputs(BH, T, D, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = (jax.random.normal(ks[0], (BH, T, D)) * 0.3).astype(dtype)
    k = (jax.random.normal(ks[1], (BH, T, D)) * 0.3).astype(dtype)
    v = (jax.random.normal(ks[2], (BH, T, D)) * 0.3).astype(dtype)
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (BH, T, D))).astype(dtype)
    u = (jax.random.normal(ks[4], (BH, D)) * 0.1).astype(dtype)
    s0 = (jax.random.normal(ks[5], (BH, D, D)) * 0.1).astype(jnp.float32)
    return r, k, v, w, u, s0


CASES = [(1, 32, 16, 16), (2, 64, 32, 32), (3, 128, 64, 64), (2, 96, 32, 32)]


@pytest.mark.parametrize("BH,T,D,chunk", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv_pallas_vs_ref(BH, T, D, chunk, dtype):
    r, k, v, w, u, s0 = _inputs(BH, T, D, dtype)
    o_p, sT_p = wkv_pallas(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    for b in range(BH):
        o_r, sT_r = wkv_ref(r[b], k[b], v[b], w[b], u[b], s0[b])
        np.testing.assert_allclose(np.asarray(o_p[b], np.float32),
                                   np.asarray(o_r), atol=tol)
        np.testing.assert_allclose(np.asarray(sT_p[b]), np.asarray(sT_r),
                                   atol=tol)


def test_wkv_matches_model_path():
    """The RWKV6 model's chunked two-level scan == the kernel oracle."""
    from repro.configs import get_config, reduced
    from repro.models import get_model
    cfg = reduced(get_config("rwkv6-7b"))
    model = get_model(cfg)
    B, S = 2, 24
    H, hd = model.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.3
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.3
    v = jax.random.normal(ks[2], (B, S, H, hd)) * 0.3
    w = jax.nn.sigmoid(jax.random.normal(ks[3], (B, S, H, hd)))
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    out_m, sT_m = model._wkv(r, k, v, w, u, s0, chunk=8)
    for b in range(B):
        for h in range(H):
            o_r, sT_r = wkv_ref(r[b, :, h], k[b, :, h], v[b, :, h],
                                w[b, :, h], u[h], s0[b, h])
            np.testing.assert_allclose(np.asarray(out_m[b, :, h]),
                                       np.asarray(o_r), atol=2e-4)
            np.testing.assert_allclose(np.asarray(sT_m[b, h]),
                                       np.asarray(sT_r), atol=2e-4)
