"""Precompiled schedule-segment screening (PR 10).

The carbon-aware screen and the availability machinery now run off
compiled per-schedule segment tables (``_VocabSchedule.segment_table`` /
``allowed_masks`` / ``exit_table``) instead of per-row per-country
recomputation. Invariants under test:

* the global breakpoint grid + per-segment value matrix reproduce the
  direct ``at``/``intensity_at`` lookup exactly on random schedules,
  phases and clocks — including cycle-wrap boundaries (hypothesis);
* the per-k allowed masks equal the direct "value <= k-th smallest"
  partition screen, tied intensities included (hypothesis);
* the vectorized ``exit_times`` descent finds exactly the boundary the
  sequential segment scan finds (hypothesis);
* ``carbon_pick_ids`` is bit-identical to the pre-compile per-row
  screen (a literal reimplementation of the old path), and the ``skip``
  mask only blanks the rows it names;
* static-schedule and ``k >= len(names)`` runs keep their fast paths —
  the segment machinery is never invoked (spied) and summaries stay
  bit-for-bit.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.api import Environment, Experiment, ExperimentSpec, ModelRef
from repro.configs import FederatedConfig, RunConfig
from repro.core.availability import AvailabilityModel, exit_times
from repro.core.carbon import (CARBON_INTENSITY, SECONDS_PER_DAY,
                               IntensityModel, _VocabSchedule)
from repro.federated.events import probe_uniforms
from repro.federated.runtime import (_CARBON_PROBES, _POPULATION,
                                     carbon_pick_ids)

# nseg values whose segment length 86400/nseg is an exact integer, and
# quarter-hour phases: with integer (or half-integer) clocks all the
# mod/floor attribution arithmetic below is float-exact, so the compiled
# grid and the direct lookup must agree to the bit, boundaries included
_NSEGS = (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 96, 288)
# small value pool so tied intensities are common (the screen's value
# threshold must treat ties identically on both paths)
_VALS = (45.0, 100.0, 200.0, 200.0, 300.0, 300.0, 475.0)
_NAMES = tuple(list(CARBON_INTENSITY)[1:9])


@st.composite
def _schedules(draw):
    scheds = {}
    phases = {}
    for c in _NAMES:
        if draw(st.booleans()):
            n = draw(st.sampled_from(_NSEGS))
            scheds[c] = tuple(
                draw(st.sampled_from(_VALS)) for _ in range(n))
            phases[c] = draw(st.integers(-48, 56)) * 0.25   # quarter hours
    return IntensityModel(schedule=scheds, phase_h=phases)


@st.composite
def _clocks(draw, model):
    tab = model.vocab_schedule(_NAMES)
    breaks, _ = tab.segment_table()
    base = draw(st.lists(st.integers(0, 5 * 86400), min_size=1,
                         max_size=40))
    t = np.asarray(base, np.float64)
    if draw(st.booleans()):
        t = t + 0.5
    # always exercise exact breakpoints and the cycle-wrap edge
    day = draw(st.integers(0, 4)) * SECONDS_PER_DAY
    return np.concatenate([t, breaks + day, [0.0, SECONDS_PER_DAY]])


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_segment_table_matches_direct_lookup_property(data):
    model = data.draw(_schedules())
    t = data.draw(_clocks(model))
    tab = model.vocab_schedule(_NAMES)
    breaks, vals_seg = tab.segment_table()
    assert breaks[0] == 0.0 and np.all(np.diff(breaks) > 0)
    direct = model.intensity_at(_NAMES, t[:, None])          # (n, V)
    gathered = vals_seg[tab.segment_at(t)]
    assert np.array_equal(direct, gathered)
    k = data.draw(st.integers(1, len(_NAMES)))
    tau = np.partition(direct, k - 1, axis=1)[:, k - 1:k]
    assert np.array_equal(direct <= tau,
                          tab.allowed_masks(k)[tab.segment_at(t)])


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_exit_times_descent_matches_sequential_scan_property(data):
    model = data.draw(_schedules())
    tab = model.vocab_schedule(_NAMES)
    n = data.draw(st.integers(1, 60))
    seed = data.draw(st.integers(0, 2**31))
    rng = np.random.default_rng(seed)
    # the engine only queries dynamic rows (static rows are masked out
    # before the call) — match that contract
    dyn = np.nonzero(tab.dynamic)[0]
    if len(dyn) == 0:
        return
    idx = rng.choice(dyn, n)
    # eligibility-style draws, biased into the value pool so exact
    # <=-at-a-tie crossings are exercised too
    u = np.where(rng.random(n) < 0.3,
                 rng.choice(np.asarray(_VALS), n), rng.uniform(0, 500, n))
    start = rng.integers(0, 4 * 86400, n).astype(np.float64)
    got = exit_times(tab, idx, u, start)

    # sequential reference: walk every boundary of one full cycle
    r = np.mod(start + tab.phase_s[idx], SECONDS_PER_DAY)
    j0 = tab._segment(idx, r)
    seg = tab.seg_s[idx]
    nseg = tab.nseg[idx]
    ref = np.full(n, np.inf)
    for i in range(n):
        for k in range(1, int(nseg[i]) + 1):
            if tab.vals[idx[i], (j0[i] + k) % nseg[i]] <= u[i]:
                ref[i] = start[i] + ((j0[i] + k) * seg[i] - r[i])
                break
    assert np.array_equal(np.isinf(ref), np.isinf(got))
    fin = np.isfinite(ref)
    assert np.array_equal(ref[fin], got[fin])


# --------------------------------------------------------- pick identity
class _Sampler:
    """Minimal stand-in for the pick path: deterministic country draw,
    no availability (the screen's availability leg is covered by the
    engine-level tests in test_availability.py)."""
    country_names = _NAMES
    has_avail = False

    def country_draw(self, ids, version):
        return (np.asarray(ids) % len(_NAMES)).astype(np.int32)


def _legacy_pick_ids(sampler, intensity, fed, slots, gens, starts, version):
    """The pre-compile per-row screen, verbatim: (n, V) intensity_at +
    partition per row."""
    slots = np.asarray(slots, np.int64)
    gens = np.asarray(gens, np.int64)
    n = len(slots)
    u = probe_uniforms(fed.seed, slots, gens, _CARBON_PROBES + 1)
    cand = (u[:, 1:] * _POPULATION).astype(np.int64)
    names = sampler.country_names
    k = min(int(fed.carbon_topk), len(names))
    starts = np.broadcast_to(np.asarray(starts, np.float64), (n,))
    ctry = sampler.country_draw(cand.reshape(-1), version) \
        .reshape(n, _CARBON_PROBES)
    ci = intensity.intensity_at(names, starts[:, None])
    tau = np.partition(ci, k - 1, axis=1)[:, k - 1:k]
    allowed = (ci <= tau)[np.arange(n)[:, None], ctry]
    j = np.where(allowed.any(axis=1), np.argmax(allowed, axis=1), 0)
    j[u[:, 0] < fed.carbon_explore] = 0
    return cand[np.arange(n), j]


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_compiled_pick_matches_legacy_per_row_screen_property(data):
    model = data.draw(_schedules())
    fed = FederatedConfig(mode="carbon-aware",
                          carbon_topk=data.draw(st.integers(1, 7)),
                          carbon_explore=data.draw(
                              st.sampled_from([0.0, 0.1, 0.5])),
                          seed=data.draw(st.integers(0, 10_000)))
    n = data.draw(st.integers(1, 50))
    rng = np.random.default_rng(fed.seed + 1)
    slots = rng.integers(0, 512, n)
    gens = rng.integers(1, 40, n)
    starts = rng.integers(0, 3 * 86400, n).astype(np.float64) \
        + data.draw(st.sampled_from([0.0, 0.5]))
    s = _Sampler()
    new = carbon_pick_ids(s, model, fed, slots, gens, starts, 3)
    old = _legacy_pick_ids(s, model, fed, slots, gens, starts, 3)
    assert np.array_equal(new, old)
    # skip only blanks the rows it names (they take the first probe);
    # every other row is untouched — batch composition never leaks
    skip = rng.random(n) < 0.4
    skipped = carbon_pick_ids(s, model, fed, slots, gens, starts, 3,
                              skip=skip)
    assert np.array_equal(skipped[~skip], new[~skip])
    u = probe_uniforms(fed.seed, np.asarray(slots, np.int64),
                       np.asarray(gens, np.int64), _CARBON_PROBES + 1)
    first = (u[:, 1:] * _POPULATION).astype(np.int64)[:, 0]
    assert np.array_equal(skipped[skip], first[skip])


# ------------------------------------------------------ fast-path spies
def _spec(env, topk=3, seed=7):
    return ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(mode="carbon-aware", concurrency=40,
                                  aggregation_goal=30, seed=seed,
                                  carbon_topk=topk),
        run=RunConfig(target_perplexity=175.0, max_rounds=10),
        environment=env, learner="surrogate")


def _count_calls(monkeypatch, cls, names):
    counts = {m: 0 for m in names}
    for m in names:
        orig = getattr(cls, m)

        def spy(self, *a, _m=m, _orig=orig, **kw):
            counts[_m] += 1
            return _orig(self, *a, **kw)

        monkeypatch.setattr(cls, m, spy)
    return counts


def test_static_schedule_keeps_fast_path_and_stays_bit_identical(
        monkeypatch):
    base = Experiment(_spec(Environment())).run().summary()
    counts = _count_calls(monkeypatch, _VocabSchedule,
                          ["segment_table", "segment_at", "allowed_masks"])
    spied = Experiment(_spec(Environment())).run().summary()
    assert spied == base
    assert counts == {"segment_table": 0, "segment_at": 0,
                      "allowed_masks": 0}


def test_diurnal_schedule_does_use_the_segment_tables(monkeypatch):
    counts = _count_calls(monkeypatch, _VocabSchedule,
                          ["segment_at", "allowed_masks"])
    Experiment(_spec(Environment.preset("diurnal"))).run()
    assert counts["segment_at"] > 0 and counts["allowed_masks"] > 0


def test_topk_covering_vocab_skips_screening_entirely(monkeypatch):
    env = Environment.preset("diurnal")
    # topk == the full country vocabulary: nothing to screen
    spec = _spec(env, topk=len(env.country_mix))
    base = Experiment(spec).run().summary()
    from repro.federated.events import SessionSampler
    counts = _count_calls(monkeypatch, SessionSampler,
                          ["country_draw", "admission_uniforms"])
    seg_counts = _count_calls(monkeypatch, _VocabSchedule, ["segment_at"])
    spied = Experiment(spec).run().summary()
    assert spied == base
    assert counts == {"country_draw": 0, "admission_uniforms": 0}
    assert seg_counts == {"segment_at": 0}


def test_eligibility_segment_gather_matches_at():
    av = AvailabilityModel(
        eligibility_schedule={c: (0.95, 0.9, 0.5, 0.3, 0.4, 0.6, 0.8, 0.9)
                              for c in _NAMES[:5]},
        eligibility_phase_h={c: i * 0.5
                             for i, c in enumerate(_NAMES[:5])})
    tab = av.eligibility_table(_NAMES)
    rng = np.random.default_rng(3)
    t = rng.integers(0, 5 * 86400, 2000).astype(np.float64)
    ctry = rng.integers(0, len(_NAMES), 2000)
    _, evals = tab.segment_table()
    assert np.array_equal(tab.at(ctry, t), evals[tab.segment_at(t), ctry])
