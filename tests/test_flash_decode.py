"""shard_map flash-decoding (§Perf H1) vs the dense oracle, on a CPU mesh."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import common as cm

_CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.models import common as cm
mesh = jax.make_mesh((2, 4), ("data", "model"))
B, C, Hkv, Hq, D = 4, 32, 2, 4, 16
ks = jax.random.split(jax.random.PRNGKey(0), 5)
q = jax.random.normal(ks[0], (B, Hq, D))
kc = jax.random.normal(ks[1], (B, C, Hkv, D))
vc = jax.random.normal(ks[2], (B, C, Hkv, D))
kn = jax.random.normal(ks[3], (B, Hkv, D))
vn = jax.random.normal(ks[4], (B, Hkv, D))
for wp_v, vl_v in ((20, 21), (0, 1), (31, 32)):
    kc2 = jax.lax.dynamic_update_slice_in_dim(kc, kn[:, None], wp_v, axis=1)
    vc2 = jax.lax.dynamic_update_slice_in_dim(vc, vn[:, None], wp_v, axis=1)
    want = cm.decode_attention(q, kc2, vc2, vl_v)
    with mesh:
        got, kc3, vc3 = jax.jit(cm.flash_decode_attention)(
            q, kc, vc, kn, vn, jnp.asarray(wp_v), jnp.asarray(vl_v))
    assert float(jnp.max(jnp.abs(got - want))) < 1e-5, wp_v
    assert float(jnp.max(jnp.abs(kc3 - kc2))) == 0.0
    assert float(jnp.max(jnp.abs(vc3 - vc2))) == 0.0
print("OK")
"""


def test_flash_decode_matches_oracle_on_mesh():
    """Runs in a subprocess: needs 8 forced host devices, which must not
    leak into the other tests' single-device jax runtime."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", _CODE], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_flash_decode_fallback_without_mesh():
    """Outside a mesh context the op must equal update+dense attention."""
    B, C, Hkv, Hq, D = 2, 16, 2, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    q = jax.random.normal(ks[0], (B, Hq, D))
    kc = jax.random.normal(ks[1], (B, C, Hkv, D))
    vc = jax.random.normal(ks[2], (B, C, Hkv, D))
    kn = jax.random.normal(ks[3], (B, Hkv, D))
    vn = jax.random.normal(ks[4], (B, Hkv, D))
    got, kc2, vc2 = cm.flash_decode_attention(q, kc, vc, kn, vn, 7, 8)
    kc_ref = jax.lax.dynamic_update_slice_in_dim(kc, kn[:, None], 7, axis=1)
    want = cm.decode_attention(q, kc_ref, jax.lax.dynamic_update_slice_in_dim(
        vc, vn[:, None], 7, axis=1), 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(kc2), np.asarray(kc_ref))


def test_sort_moe_grad_finite():
    """Sort-based MoE must be differentiable end-to-end."""
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    T, d, E, f, k = 32, 8, 4, 16, 2
    x = jax.random.normal(ks[0], (T, d))
    rw = jax.random.normal(ks[1], (d, E))
    wg = jax.random.normal(ks[2], (E, d, f)) * 0.1
    wu = jax.random.normal(ks[3], (E, d, f)) * 0.1
    wd = jax.random.normal(ks[4], (E, f, d)) * 0.1

    def loss(x):
        out, aux = cm.moe_block(x, rw, wg, wu, wd, top_k=k)
        return jnp.sum(out ** 2) + aux

    g = jax.grad(loss)(x)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.max(jnp.abs(g))) > 0


def test_q8_kv_cache_numerics():
    """int8 KV cache attention error stays in the quantization envelope."""
    import jax.numpy as jnp
    B, C, Hkv, Hq, D = 2, 24, 2, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, Hq, D))
    kc_f = jax.random.normal(ks[1], (B, C, Hkv, D))
    vc_f = jax.random.normal(ks[2], (B, C, Hkv, D))
    kn = jax.random.normal(ks[3], (B, Hkv, D))
    vn = jax.random.normal(ks[4], (B, Hkv, D))
    kq, kss = cm.quantize_kv(kc_f)
    vq, vss = cm.quantize_kv(vc_f)
    # roundtrip bound per element
    err = jnp.max(jnp.abs(cm.dequantize_kv(kq, kss) - kc_f))
    assert float(err) <= float(jnp.max(jnp.abs(kc_f))) / 127.0 + 1e-6
    kc2 = jax.lax.dynamic_update_slice_in_dim(kc_f, kn[:, None], 10, axis=1)
    vc2 = jax.lax.dynamic_update_slice_in_dim(vc_f, vn[:, None], 10, axis=1)
    want = cm.decode_attention(q, kc2, vc2, 11)
    got, *_ = cm.flash_decode_attention_q8(
        q, kq, vq, kss, vss, kn, vn, jnp.asarray(10), jnp.asarray(11))
    assert float(jnp.max(jnp.abs(got - want))) < 0.05


def test_q8_decoder_step():
    """DecoderLM with kv_quant runs a full decode step."""
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.models import get_model
    cfg = reduced(get_config("smollm-135m"))
    m = get_model(cfg)
    m.flash_decode = True
    m.kv_quant = True
    params, _ = m.init(jax.random.PRNGKey(0))
    cache, axes = m.init_cache(2, 16, dtype=jnp.bfloat16)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    tok = jnp.asarray([1, 2], jnp.int32)
    lg, cache2 = jax.jit(m.decode_step)(params, cache, tok)
    assert lg.shape == (2, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert cache2["k"].dtype == jnp.int8
