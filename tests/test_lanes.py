"""Lane-batched sweep engine (PR 4): lane packs must reproduce per-spec
serial runs **seed for seed** — same summary scalars, same session
columns, bit for bit — for sync and async packs alike, plus the
satellite pieces that ride along (BatchAccumulator growth buffers,
LaneAccumulator splitting, fused estimator pass, sweep's pool fallback
and pack grouping)."""
import warnings

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import importlib

sweep_mod = importlib.import_module("repro.api.sweep")

from repro.api import (Environment, Experiment, ExperimentSpec, LaneRunner,
                       ModelRef, sweep)
from repro.configs import FederatedConfig, RunConfig, get_config
from repro.core.availability import diurnal_availability
from repro.core.estimator import CarbonEstimator
from repro.core.network import NetworkEnergyModel
from repro.core.profiles import FLEET
from repro.core.telemetry import (OUTCOMES, BatchAccumulator,
                                  LaneAccumulator, SessionBatch, TaskLog)
from repro.federated.events import SessionSampler

CFG = get_config("paper-charlm")

_COLS = ("client_id", "round_idx", "device_idx", "country_idx",
         "download_s", "compute_s", "upload_s", "bytes_down", "bytes_up",
         "start_t", "end_t", "outcome", "staleness")

_ENVS = (Environment(),
         Environment(download_bps=20e6, upload_bps=5e6,
                     network=NetworkEnergyModel(e_access_nj=80.0),
                     fleet=FLEET[:3], pue=1.3,
                     carbon_intensity={"WORLD": 300.0, "US": 100.0}),
         Environment(country_mix={"US": 0.3, "FR": 0.2, "BR": 0.15,
                                  "IN": 0.15, "SE": 0.1, "NO": 0.1}),
         Environment.preset("diurnal"),
         # availability-gated lanes pack against availability-free ones
         Environment(availability=diurnal_availability(
             tuple(Environment().country_mix))),
         Environment.preset("diurnal", availability=diurnal_availability(
             tuple(Environment().country_mix))))

_MODES = ("sync", "async", "carbon-aware")


def _spec(mode: str, conc: int, goal_frac: float, seed: int,
          max_rounds: int, env_idx: int = 0,
          dropout: float = 0.05) -> ExperimentSpec:
    return ExperimentSpec(
        model=ModelRef("paper-charlm"),
        federated=FederatedConfig(
            mode=mode, concurrency=conc,
            aggregation_goal=max(1, int(conc * goal_frac)),
            seed=seed, dropout_rate=dropout),
        run=RunConfig(target_perplexity=175.0, max_rounds=max_rounds),
        environment=_ENVS[env_idx % len(_ENVS)], learner="surrogate")


def _assert_lane_equals_serial(spec: ExperimentSpec, lane_res,
                               serial_res) -> None:
    ss, sl = serial_res.summary(), lane_res.summary()
    assert ss == sl, {k: (ss[k], sl[k]) for k in ss if ss[k] != sl[k]}
    cs, cl = serial_res.log.columns(), lane_res.log.columns()
    assert cs.device_names == cl.device_names
    assert cs.country_names == cl.country_names
    for f in _COLS:
        assert np.array_equal(getattr(cs, f), getattr(cl, f)), (spec, f)
    # derived views agree too
    assert serial_res.log.participation() == lane_res.log.participation()
    assert serial_res.log.eval_history == lane_res.log.eval_history


# --------------------------------------------------------- lane equivalence
@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=3, max_value=8),
       st.integers(min_value=0, max_value=10_000))
def test_lane_pack_matches_serial_property(n_specs, seed0):
    """Randomized heterogeneous packs (sync, async AND carbon-aware;
    mixed concurrency/goals/seeds/environments incl. diurnal intensity
    schedules, runs short enough that async-family lanes end with
    cancelled in-flight sessions) are bit-for-bit equal to per-spec
    serial runs through the public sweep API."""
    rng = np.random.default_rng(seed0)
    specs = []
    for j in range(n_specs):
        specs.append(_spec(
            mode=_MODES[int(rng.integers(len(_MODES)))],
            conc=int(rng.integers(8, 48)),
            goal_frac=float(rng.uniform(0.3, 1.0)),
            seed=int(rng.integers(0, 2 ** 31)),
            max_rounds=int(rng.integers(5, 40)),
            env_idx=int(rng.integers(len(_ENVS))),
            dropout=float(rng.choice([0.0, 0.05, 0.3]))))
    serial = [Experiment(s).run() for s in specs]
    lane = sweep(specs, workers=1, vectorize=True)
    saw_cancelled = False
    for spec, rl, rs in zip(specs, lane, serial):
        _assert_lane_equals_serial(spec, rl, rs)
        if rl.log.participation().get("cancelled"):
            saw_cancelled = True
    if any(s.federated.mode != "sync" for s in specs):
        # capped-round async-family runs always leave a cohort in flight
        assert saw_cancelled


@pytest.mark.parametrize("mode", list(_MODES))
def test_lane_pack_matches_serial_deterministic(mode):
    """Fixed heterogeneous pack per mode — including a lane that reaches
    the perplexity target and a lane that dies on the round cap — checked
    through LaneRunner directly (the runtime-level API)."""
    from repro.federated.runtime import LaneTask
    from repro.federated.surrogate import SurrogateLearner
    specs = [_spec(mode, 40, 0.8, 0, 10_000),
             _spec(mode, 25, 1.0, 7, 25, env_idx=3),
             _spec(mode, 60, 0.5, 3, 10_000, env_idx=1, dropout=0.2)]
    serial = [Experiment(s).run() for s in specs]
    tasks = []
    for s in specs:
        cfg = s.model.resolve()
        tasks.append(LaneTask(
            model_cfg=cfg, fed=s.federated, run=s.run,
            learner=SurrogateLearner(cfg, s.federated, s.run),
            sampler=s.environment.sampler(cfg, s.federated, s.seq_len),
            estimator=s.environment.estimator()))
    lane = LaneRunner(mode).run(tasks)
    for spec, rl, rs in zip(specs, lane, serial):
        ss, sl = rs.summary(), rl.summary()
        assert ss == sl, {k: (ss[k], sl[k]) for k in ss if ss[k] != sl[k]}
        cs, cl = rs.log.columns(), rl.log.columns()
        for f in _COLS:
            assert np.array_equal(getattr(cs, f), getattr(cl, f)), f
    assert any(r.reached_target for r in lane)
    assert any(not r.reached_target for r in lane)


def test_lane_round_events_match_serial():
    """Per-round streaming survives lane batching: each lane's RoundEvent
    sequence equals its serial run's."""
    from repro.federated.runtime import LaneTask
    from repro.federated.surrogate import SurrogateLearner
    spec = _spec("async", 30, 0.8, 5, 20)
    cfg = spec.model.resolve()
    serial_ev, lane_ev = [], []
    Experiment(spec).run(on_round=serial_ev.append)
    task = LaneTask(
        model_cfg=cfg, fed=spec.federated, run=spec.run,
        learner=SurrogateLearner(cfg, spec.federated, spec.run),
        sampler=spec.environment.sampler(cfg, spec.federated, spec.seq_len),
        estimator=spec.environment.estimator(), on_round=lane_ev.append)
    LaneRunner("async").run([task])
    assert len(serial_ev) == len(lane_ev)
    for a, b in zip(serial_ev, lane_ev):
        assert (a.round_idx, a.n_sessions, a.mode) == \
            (b.round_idx, b.n_sessions, b.mode)
        assert a.t_s == b.t_s and a.perplexity == b.perplexity


def test_sweep_vectorize_pack_grouping():
    """Mixed-mode sweeps split into one pack per mode; real-learner specs
    are left to the per-spec path; spec order is preserved."""
    specs = [_spec("sync", 10, 0.8, 0, 5), _spec("async", 10, 0.8, 1, 5),
             _spec("sync", 12, 0.8, 2, 5)]
    jobs = sweep_mod._group_packs(specs)
    assert [(k, idxs) for k, idxs in jobs] == \
        [("pack", [0, 2]), ("pack", [1])]
    real = specs[0].replace(learner="real")
    jobs = sweep_mod._group_packs([real, specs[1]])
    assert jobs[0] == ("spec", [0]) and jobs[1] == ("pack", [1])
    # order is preserved end-to-end through the vectorized path
    res = sweep(specs, workers=1, vectorize=True)
    for s, r in zip(specs, res):
        assert r.spec is s


def test_pack_chunking_composes_with_workers():
    """With workers>1 a pack splits into up to `workers` sub-packs (pool
    utilization); workers=1 keeps one pack per mode (max amortization).
    Either way results stay identical."""
    jobs = sweep_mod._group_packs(
        [_spec("sync", 10, 0.8, s, 5) for s in range(8)])
    assert jobs == [("pack", list(range(8)))]
    chunked = sweep_mod._chunk_packs(jobs, 4)
    assert [idxs for _, idxs in chunked] == [[0, 1], [2, 3], [4, 5], [6, 7]]
    assert sweep_mod._chunk_packs(jobs, 1) == jobs
    # oversubscribed: singleton chunks, never empty ones
    assert [len(i) for _, i in sweep_mod._chunk_packs(jobs, 99)] == [1] * 8
    specs = [_spec(m, 10, 0.8, s, 5) for m in ("sync", "async")
             for s in range(3)]
    r1 = sweep(specs, workers=1, vectorize=True)
    r4 = sweep(specs, workers=4, vectorize=True)
    assert all(a.summary() == b.summary() for a, b in zip(r1, r4))


def test_lane_sampler_piecewise_matches_serial_and_fused():
    """The piecewise LaneSampler plan_batch/resolve_batch (the building
    blocks for future strategies' lane loops, incl. per-row deadlines)
    match each lane's own SessionSampler bit for bit, and the fused
    plan_resolve matches the piecewise pair."""
    from repro.federated.events import LaneSampler
    feds = [FederatedConfig(seed=3, dropout_rate=0.2),
            FederatedConfig(seed=11, compression="int8", local_epochs=5)]
    samplers = [SessionSampler(CFG, f, 64) for f in feds]
    ls = LaneSampler(samplers)
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 5_000_000, size=160).astype(np.int64)
    lane = np.repeat([0, 1], 80)
    starts = rng.uniform(0, 50.0, size=160)
    deadline = np.full(160, 3000.0)
    pb = ls.plan_batch(lane, ids, 4)
    cols, ok = ls.resolve_batch(pb, lane, 4, starts, deadline=deadline)
    for i, s in enumerate(samplers):
        sl = slice(80 * i, 80 * (i + 1))
        ref_pb = s.plan_batch(ids[sl], 4)
        ref, ref_ok = s.resolve_batch(ref_pb, 4, starts[sl],
                                      deadline=3000.0)
        assert np.array_equal(pb.device_idx[sl], ref_pb.device_idx)
        assert np.array_equal(pb.compute_s[sl], ref_pb.compute_s)
        assert np.array_equal(ok[sl], ref_ok)
        for f in ("download_s", "compute_s", "upload_s", "bytes_down",
                  "bytes_up", "start_t", "end_t", "outcome"):
            assert np.array_equal(cols[f][sl], getattr(ref, f)), f
    # fused path == piecewise path (no deadline), incl. apply_deadline
    pb2, cols2, ok2 = ls.plan_resolve(lane, ids, 4, starts.copy())
    base, base_ok = ls.resolve_batch(pb, lane, 4, starts)
    for f in cols2:
        assert np.array_equal(cols2[f], base[f]), f
    ls.apply_deadline(pb2, cols2, ok2, deadline)
    for f in cols2:
        assert np.array_equal(cols2[f], cols[f]), f
    assert np.array_equal(ok2, ok)


def test_pack_key_requires_explicit_lane_loop(monkeypatch):
    """A registered strategy subclass that overrides _loop but merely
    inherits lane_loop must NOT be lane-batched (its serial semantics
    could differ from the parent's lane loop)."""
    from repro.federated import runtime as rt

    class Custom(rt.SyncStrategy):
        def _loop(self, *a, **kw):            # pragma: no cover
            raise NotImplementedError

    monkeypatch.setitem(rt.STRATEGIES, "sync", Custom)
    spec = _spec("sync", 10, 0.8, 0, 5)
    assert sweep_mod._pack_key(spec) is None
    assert sweep_mod._group_packs([spec]) == [("spec", [0])]


# ----------------------------------------------------- sweep pool fallback
def test_sweep_pool_fallback_delivers_each_result_exactly_once(monkeypatch):
    """Satellite: when the pool dies mid-sweep, the serial fallback warns
    (RuntimeWarning) and re-runs ONLY the unfinished specs — on_result
    fires exactly once per spec and results stay in spec order."""
    specs = [_spec("sync", 10, 0.8, s, 5) for s in range(4)]

    def broken_pool(jobs, specs_, n, deliver):
        # finish spec 1, then die like a clobbered /dev/shm would
        deliver([1], [sweep_mod.run_spec(specs_[1])])
        raise OSError("pool vanished")

    monkeypatch.setattr(sweep_mod, "_sweep_pool", broken_pool)
    seen = []
    with pytest.warns(RuntimeWarning, match="running the remaining 3/4"):
        results = sweep(specs, workers=4,
                        on_result=lambda i, r: seen.append(i))
    assert sorted(seen) == [0, 1, 2, 3]          # exactly once each
    assert len(seen) == len(set(seen)) == 4
    for s, r in zip(specs, results):
        assert r.spec is s                        # spec-order results
        assert r.summary() == Experiment(s).run().summary()


def test_sweep_experiment_failure_propagates(monkeypatch):
    """An experiment's own exception must NOT trigger the serial fallback
    (it would run the failing spec twice) — it propagates as-is."""
    specs = [_spec("sync", 10, 0.8, 0, 5)] * 2

    def exploding_pool(jobs, specs_, n, deliver):
        raise sweep_mod._TaskFailed(ValueError("boom"))

    monkeypatch.setattr(sweep_mod, "_sweep_pool", exploding_pool)
    with pytest.raises(ValueError, match="boom"):
        sweep(specs, workers=2)


# ------------------------------------------------------------ accumulators
def test_batch_accumulator_doubling_buffers_match_concat():
    """Satellite: the preallocated-buffer accumulator reproduces the old
    append+concat semantics exactly, across many growth cycles."""
    s = SessionSampler(CFG, FederatedConfig(), 64)
    acc = BatchAccumulator(s.device_names, s.country_names)
    ref = []
    rng = np.random.default_rng(0)
    for r in range(40):
        ids = rng.integers(0, 5_000_000, size=int(rng.integers(1, 200)))
        b, _ = s.resolve_batch(s.plan_batch(ids.astype(np.int64), r), r,
                               10.0 * r)
        ref.append(b)
        acc.append(client_id=b.client_id, round_idx=b.round_idx,
                   device_idx=b.device_idx, country_idx=b.country_idx,
                   download_s=b.download_s, compute_s=b.compute_s,
                   upload_s=b.upload_s, bytes_down=b.bytes_down,
                   bytes_up=b.bytes_up, start_t=b.start_t, end_t=b.end_t,
                   outcome=b.outcome, staleness=b.staleness)
    cat = SessionBatch.concat(ref)
    got = acc.to_batch()
    assert len(acc) == len(cat) == len(got)
    for f in _COLS:
        assert np.array_equal(getattr(got, f), getattr(cat, f)), f
    # to_batch copies out of the live buffers: later appends don't alias
    got2 = got.client_id.copy()
    acc.append(client_id=cat.client_id, round_idx=cat.round_idx,
               device_idx=cat.device_idx, country_idx=cat.country_idx,
               download_s=cat.download_s, compute_s=cat.compute_s,
               upload_s=cat.upload_s, bytes_down=cat.bytes_down,
               bytes_up=cat.bytes_up, start_t=cat.start_t, end_t=cat.end_t,
               outcome=cat.outcome, staleness=cat.staleness)
    assert np.array_equal(got.client_id, got2)


def test_lane_accumulator_split_preserves_order_and_vocab():
    lanes = LaneAccumulator([("a-dev",), ("b-dev", "c-dev")],
                            [("US",), ("FR", "BR")])
    assert lanes.split()[0].client_id.shape == (0,)   # empty store
    z = np.zeros(3)
    for lane, cid0 in ((1, 10), (0, 20), (1, 30)):
        lanes.append(lane=np.full(3, lane, np.int32),
                     client_id=np.arange(cid0, cid0 + 3),
                     round_idx=np.zeros(3, np.int64),
                     device_idx=np.zeros(3, np.int32),
                     country_idx=np.zeros(3, np.int32),
                     download_s=z, compute_s=z, upload_s=z, bytes_down=z,
                     bytes_up=z, start_t=z, end_t=z,
                     outcome=np.zeros(3, np.int8),
                     staleness=np.zeros(3, np.int32))
    b0, b1 = lanes.split()
    assert b0.device_names == ("a-dev",)
    assert b1.device_names == ("b-dev", "c-dev")
    assert b0.client_id.tolist() == [20, 21, 22]
    assert b1.client_id.tolist() == [10, 11, 12, 30, 31, 32]  # append order


# -------------------------------------------------------------- estimator
def test_batch_carbon_empty_task_log_is_all_zero_but_server():
    """Satellite: the fused batch_carbon handles the empty edge cases
    explicitly — empty batch, empty TaskLog, zero-duration server."""
    est = CarbonEstimator()
    d = est.batch_carbon(SessionBatch.empty())
    assert d == {"client_compute_kg": 0.0, "upload_kg": 0.0,
                 "download_kg": 0.0, "ok_kg": 0.0, "waste_kg": 0.0,
                 "salvaged_kg": 0.0, "lost_kg": 0.0}
    log = TaskLog()
    bd = est.estimate(log)
    assert bd.total_kg == 0.0 and bd.server_kg == 0.0
    log.duration_s = 3600.0          # server charged even with no sessions
    bd = est.estimate(log)
    assert bd.server_kg > 0 and bd.client_compute_kg == 0.0
    assert bd.total_kg == bd.server_kg


def test_empty_batch_accumulator_to_batch_is_well_formed():
    """Satellite: a never-appended BatchAccumulator (e.g. an async run
    whose first window is still in flight at the round cap) yields a
    zero-length SessionBatch that batch_carbon reduces to all-zero."""
    acc = BatchAccumulator(("pixel-7",), ("US",))
    b = acc.to_batch()
    assert isinstance(b, SessionBatch) and len(b) == 0
    est = CarbonEstimator()
    assert est.batch_carbon(b) == {"client_compute_kg": 0.0,
                                   "upload_kg": 0.0, "download_kg": 0.0,
                                   "ok_kg": 0.0, "waste_kg": 0.0,
                                   "salvaged_kg": 0.0, "lost_kg": 0.0}
    log = TaskLog()
    log.log_batch(b)
    assert log.n_sessions == 0 and est.estimate(log).total_kg == 0.0


def test_streaming_and_full_specs_pack_separately():
    """Streaming and full-telemetry lanes use different session stores,
    so a mixed sweep splits them into separate packs — and both halves
    still match their per-spec serial runs."""
    import dataclasses
    from repro.core.streaming import StreamedLog
    full = [_spec("async", 14, 0.8, s, 6, env_idx=s) for s in range(2)]
    stream = [s.replace(run=dataclasses.replace(
        s.run, telemetry="streaming", telemetry_sample=32)) for s in full]
    mixed = [full[0], stream[0], full[1], stream[1]]
    jobs = sweep_mod._group_packs(mixed)
    assert jobs == [("pack", [0, 2]), ("pack", [1, 3])]
    res = sweep(mixed, workers=1, vectorize=True)
    serial = [Experiment(s).run() for s in mixed]
    for s, rl, rs in zip(mixed, res, serial):
        assert rl.summary() == rs.summary()
        assert isinstance(rl.log, StreamedLog) == \
            (s.run.telemetry == "streaming")


def test_lane_carbon_matches_per_lane_batch_carbon():
    """The segment-reduction lane estimator equals per-lane batch_carbon
    bit for bit (pairwise sums over identical row order)."""
    from repro.core.estimator import lane_carbon
    specs = [_spec("sync", 20, 0.8, s, 6, env_idx=s % 3) for s in range(3)]
    serial = [Experiment(s).run() for s in specs]
    lane = sweep(specs, workers=1, vectorize=True)
    for rs, rl in zip(serial, lane):
        for k, v in rs.carbon.as_dict().items():
            assert rl.carbon.as_dict()[k] == v, k
